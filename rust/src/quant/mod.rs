//! Host-side quantization substrate + the Table 3 memory accounting.
//!
//! Per-tensor symmetric int8 (`q = clip(round(x/s), -127, 127)`), matching
//! the L1 `qdq` kernels bit-for-bit so host-prepared tensors agree with the
//! compiled pipeline.  The footprint model reproduces the paper's §3.2.2
//! observation: intermediates stay fp32 in memory in *both* precisions
//! (quantized operators read one precision and write the other; scales stay
//! fp32), so resident memory is nearly constant across precisions — what
//! int8 saves is *bandwidth*, and weights.

use crate::manifest::{Bundle, Manifest};
use crate::runtime::{DType, TensorData};

pub const QMAX: f32 = 127.0;

/// Per-tensor symmetric scale from the absolute maximum.
///
/// Non-finite calibration samples (NaN from a bad divide, ±inf from an
/// overflowed activation) are excluded: an inf amax would otherwise drive
/// the scale to inf and quantize the whole tensor to zero, and the paper's
/// calibration protocol (abs-max over sampled activations) assumes finite
/// data.  All-non-finite input degrades to the epsilon scale.
pub fn abs_max_scale(values: &[f32]) -> f32 {
    let amax = values
        .iter()
        .filter(|v| v.is_finite())
        .fold(0f32, |m, v| m.max(v.abs()));
    (amax.max(1e-8)) / QMAX
}

/// fp32 → int8 at `scale`.
pub fn quantize(values: &[f32], scale: f32) -> Vec<i8> {
    values
        .iter()
        .map(|v| (v / scale).round().clamp(-QMAX, QMAX) as i8)
        .collect()
}

/// int8 → fp32 at `scale`.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|v| *v as f32 * scale).collect()
}

/// Quantize a whole tensor (the host half of the prefix operator).
pub fn quantize_tensor(t: &TensorData, scale: f32) -> anyhow::Result<TensorData> {
    let q = quantize(&t.as_f32()?, scale);
    TensorData::from_i8(t.shape.clone(), &q)
}

/// Round-trip error metrics for a quantization choice.
#[derive(Debug, Clone, Copy)]
pub struct QuantError {
    pub max_abs: f32,
    pub rmse: f32,
    pub sqnr_db: f32,
}

pub fn quant_error(values: &[f32], scale: f32) -> QuantError {
    let deq = dequantize(&quantize(values, scale), scale);
    let mut max_abs = 0f32;
    let mut se = 0f64;
    let mut sig = 0f64;
    for (a, b) in values.iter().zip(&deq) {
        let e = (a - b).abs();
        max_abs = max_abs.max(e);
        se += (e as f64) * (e as f64);
        sig += (*a as f64) * (*a as f64);
    }
    let n = values.len().max(1) as f64;
    QuantError {
        max_abs,
        rmse: ((se / n) as f32).sqrt(),
        sqnr_db: (10.0 * (sig / se.max(1e-30)).log10()) as f32,
    }
}

// ---------------------------------------------------------------------------
// Memory footprint (Table 3's Memory column)
// ---------------------------------------------------------------------------

/// Byte accounting for one bundle at one batch size.
#[derive(Debug, Clone, Copy)]
pub struct MemoryFootprint {
    /// Parameters at the bundle's precision.
    pub weight_bytes: u64,
    /// Peak simultaneously-live activation bytes (static plan arena).
    pub activation_arena_bytes: u64,
    /// Sum of all boundary activations with no reuse (the VM's cost).
    pub activation_unshared_bytes: u64,
    /// Extra q/dq staging buffers an int8 pipeline carries (int8 copies of
    /// boundary tensors) — why the paper's int8 rows use slightly *more*
    /// memory (5331 vs 5279 MiB at batch 1).
    pub qdq_overhead_bytes: u64,
}

impl MemoryFootprint {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.activation_arena_bytes + self.qdq_overhead_bytes
    }
}

pub fn footprint(_manifest: &Manifest, bundle: &Bundle) -> MemoryFootprint {
    let plan = crate::memplan::StaticPlan::for_chain(&bundle.modules);
    // Boundary tensors are what the executors move; inside fused modules the
    // intermediates are fp32 both ways (§3.2.2), captured by scaling the
    // boundary bytes to fp32 width.
    let widen = |bytes: usize, dtype: &str| -> u64 {
        match dtype {
            "s8" => bytes as u64 * 4, // stored fp32 internally
            _ => bytes as u64,
        }
    };
    let mut arena = 0u64;
    let mut unshared = 0u64;
    let mut qdq = 0u64;
    for (p, m) in plan.placements.iter().zip(&bundle.modules) {
        let w = widen(p.bytes, &m.output.dtype);
        unshared += w;
        if m.output.dtype == "s8" {
            // the int8 copy exists alongside the fp32 working tensor
            qdq += p.bytes as u64;
        }
        arena = arena.max(w);
    }
    // Linear chain: at steady state two boundary tensors are live (in+out).
    MemoryFootprint {
        weight_bytes: bundle.weight_bytes,
        activation_arena_bytes: arena * 2,
        activation_unshared_bytes: unshared,
        qdq_overhead_bytes: qdq,
    }
}

/// Bandwidth accounting: bytes that must cross memory per inference — the
/// quantity whose reduction drives Table 3's growing int8 advantage.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthModel {
    pub weight_bytes: u64,
    pub activation_bytes: u64,
}

impl BandwidthModel {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.activation_bytes
    }
}

pub fn bandwidth(bundle: &Bundle) -> BandwidthModel {
    let act: usize = bundle
        .modules
        .iter()
        .map(|m| {
            m.inputs.iter().map(|i| i.byte_len()).sum::<usize>() + m.output.byte_len()
        })
        .sum();
    BandwidthModel {
        weight_bytes: bundle.weight_bytes,
        activation_bytes: act as u64,
    }
}

/// Convenience: element dtype of a spec tag.
pub fn dtype_of(tag: &str) -> DType {
    DType::parse(tag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abs_max_scale_ignores_non_finite() {
        let clean = abs_max_scale(&[1.0, -2.0, 0.5]);
        let dirty = abs_max_scale(&[
            1.0,
            f32::NAN,
            -2.0,
            f32::INFINITY,
            0.5,
            f32::NEG_INFINITY,
        ]);
        assert_eq!(clean, dirty, "non-finite samples must not move the scale");
        assert_eq!(clean, 2.0 / QMAX);
        // Quantization at the guarded scale stays sane.
        let q = quantize(&[1.0, -2.0], dirty);
        assert_eq!(q, vec![64, -127]);
    }

    #[test]
    fn abs_max_scale_all_non_finite_degrades_to_epsilon() {
        let s = abs_max_scale(&[f32::NAN, f32::INFINITY]);
        assert!(s.is_finite() && s > 0.0);
        assert_eq!(s, 1e-8 / QMAX);
    }
}
