//! The checked scenario for the sharded coordinator's admission queue:
//! the **actual** protocol code — `q_push`, `q_pop`, `q_shutdown`,
//! `q_await_settled` from `coordinator::queue`, not a transcription —
//! run under the model scheduler over a small producers × consumers ×
//! items × bound configuration.
//!
//! Per execution, logical thread 0 plays the *closer* (it waits until
//! every offered item has settled — popped or shed — then signals
//! shutdown; that dependence is what turns a lost consumer wakeup into
//! a scheduler-convicted deadlock), threads `1..=producers` offer
//! disjoint item ids through the bounded admission gate, and the
//! remaining threads consume.  Properties:
//!
//! - **settled exactly once**: every offered item is either accepted and
//!   consumed exactly once, or shed exactly once — never both, never
//!   neither, never twice (multi-worker dispatch fairness: no item is
//!   duplicated to two workers or starved forever).
//! - **bounded depth**: the queue never holds more than `bound` items
//!   (asserted inside `q_push` itself).
//! - **termination / no lost wakeups**: the closer's settle-wait, every
//!   producer, and every consumer go home under every schedule; a
//!   stranded sleeper is a scheduler-reported deadlock.
//! - **worker-death failover** (`dead_consumer`): a consumer that exits
//!   after its first pop strands nothing — the surviving consumers
//!   drain every remaining accepted item.
//!
//! [`check_queue_with`] threads the same [`SabotageBug`] wake corruptors
//! the pool self-test uses — losing the push's `notify_one` or the
//! settle counters' done-wake must be convicted, or the green runs prove
//! nothing.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::queue::{q_await_settled, q_pop, q_push, q_shutdown, PushOutcome, QState};

use super::sched::{CheckFailure, Explorer, Report, Sabotage, SabotageBug};

/// One admission-queue scenario shape.
#[derive(Debug, Clone, Copy)]
pub struct QueueCheckConfig {
    /// Producer threads, each offering `items_per_producer` distinct ids.
    pub producers: usize,
    /// Consumer threads (the serving workers of the model).
    pub consumers: usize,
    pub items_per_producer: usize,
    /// Admission bound; offers beyond it shed.
    pub bound: usize,
    /// Consumer index (0-based, `< consumers`) that exits after its
    /// first pop — the worker-death failover scenario.  Requires at
    /// least 2 consumers so survivors exist.
    pub dead_consumer: Option<usize>,
}

/// Coverage plus cross-schedule protocol totals (per-schedule shed
/// counts are interleaving-dependent, so shed coverage is only
/// meaningful summed over the whole exploration).
#[derive(Debug, Clone, Copy)]
pub struct QueueReport {
    pub report: Report,
    /// Items shed at the admission gate, summed across every explored
    /// schedule.
    pub shed_total: u64,
    /// Items consumed, summed across every explored schedule.
    pub popped_total: u64,
}

/// Exhaustively (within `explorer`'s bounds) check the admission-queue
/// protocol over `cfg`.
pub fn check_queue(
    cfg: QueueCheckConfig,
    explorer: Explorer,
) -> Result<QueueReport, CheckFailure> {
    check_queue_with(cfg, explorer, None)
}

/// [`check_queue`] with an optional planted wake-dropping bug — expect
/// `Err` with a deadlock conviction when `bug` is `Some`.
pub fn check_queue_with(
    cfg: QueueCheckConfig,
    explorer: Explorer,
    bug: Option<SabotageBug>,
) -> Result<QueueReport, CheckFailure> {
    assert!(cfg.producers >= 1 && cfg.consumers >= 1 && cfg.items_per_producer >= 1);
    if let Some(d) = cfg.dead_consumer {
        assert!(
            d < cfg.consumers && cfg.consumers >= 2,
            "worker-death needs a valid victim and at least one survivor: {cfg:?}"
        );
    }
    let total = cfg.producers * cfg.items_per_producer;
    let shed_total = Arc::new(AtomicU64::new(0));
    let popped_total = Arc::new(AtomicU64::new(0));

    let report = explorer.run(
        || QState::<usize>::new(cfg.bound),
        |sched| {
            // Fresh per execution; thread bodies touch only these atomics
            // outside critical sections (the scheduler's sections-are-
            // atomic reduction requires commutative shared effects).
            let accepted: Arc<Vec<AtomicUsize>> =
                Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
            let shed: Arc<Vec<AtomicUsize>> =
                Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
            let hits: Arc<Vec<AtomicUsize>> =
                Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());

            // Thread 0: the closer — shutdown only after every offered
            // item settled, so nothing is cut short by the close itself.
            sched.spawn("closer", move |sync| {
                let sync = Sabotage::new(sync, bug);
                q_await_settled(&sync, total as u64);
                q_shutdown(&sync);
            });
            for p in 0..cfg.producers {
                let accepted = Arc::clone(&accepted);
                let shed = Arc::clone(&shed);
                sched.spawn(&format!("producer-{p}"), move |sync| {
                    let sync = Sabotage::new(sync, bug);
                    for k in 0..cfg.items_per_producer {
                        let id = p * cfg.items_per_producer + k;
                        match q_push(&sync, id) {
                            PushOutcome::Accepted => {
                                accepted[id].fetch_add(1, Ordering::Relaxed);
                            }
                            PushOutcome::Shed { depth } => {
                                assert!(depth <= cfg.bound, "shed at depth {depth} > bound");
                                shed[id].fetch_add(1, Ordering::Relaxed);
                            }
                            PushOutcome::Closed => {
                                panic!("queue closed while producers still offering")
                            }
                        }
                    }
                });
            }
            for c in 0..cfg.consumers {
                let hits = Arc::clone(&hits);
                let dies = cfg.dead_consumer == Some(c);
                sched.spawn(&format!("consumer-{c}"), move |sync| {
                    let sync = Sabotage::new(sync, bug);
                    while let Some(id) = q_pop(&sync) {
                        hits[id].fetch_add(1, Ordering::Relaxed);
                        if dies {
                            // Worker death: exit mid-stream without
                            // draining; the survivors must finish.
                            return;
                        }
                    }
                });
            }

            let shed_total = Arc::clone(&shed_total);
            let popped_total = Arc::clone(&popped_total);
            move || {
                for id in 0..total {
                    let a = accepted[id].load(Ordering::Relaxed);
                    let s = shed[id].load(Ordering::Relaxed);
                    let h = hits[id].load(Ordering::Relaxed);
                    if a + s != 1 {
                        return Err(format!(
                            "item {id} settled {a} accepts + {s} sheds (want exactly one)"
                        ));
                    }
                    if h != a {
                        return Err(format!(
                            "item {id} consumed {h} times but accepted {a} times \
                             (every accepted item exactly once, shed items never)"
                        ));
                    }
                    shed_total.fetch_add(s as u64, Ordering::Relaxed);
                    popped_total.fetch_add(h as u64, Ordering::Relaxed);
                }
                Ok(())
            }
        },
    )?;
    Ok(QueueReport {
        report,
        shed_total: shed_total.load(Ordering::Relaxed),
        popped_total: popped_total.load(Ordering::Relaxed),
    })
}
