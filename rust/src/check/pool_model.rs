//! The checked scenario: the arena pool's **actual** epoch protocol —
//! [`dispatch`], [`worker_loop`], [`signal_shutdown`] from
//! `executor::pool`, not a transcription — run under the model scheduler
//! over a small worker/band/epoch configuration.
//!
//! Per execution, logical thread 0 plays the dispatcher (`epochs`
//! back-to-back dispatches, then shutdown) and threads `1..=workers`
//! play pool workers.  Properties:
//!
//! - **covering exactly once**: every `(epoch, band)` pair runs exactly
//!   once, across every explored schedule (validated post-run from
//!   atomic hit counters).
//! - **no lost wakeups / termination**: every dispatch and the final
//!   shutdown complete under every schedule — a schedule that strands a
//!   sleeping thread is reported as a deadlock by the scheduler itself.
//! - **unwind soundness** (`panic_band`): the band that panics in epoch
//!   0 still acknowledges; the panic surfaces on the dispatcher exactly
//!   once; every later epoch runs clean.
//!
//! [`check_pool_with`] additionally threads a [`SabotageBug`] wake
//! corruptor between the protocol and the scheduler — the checker's
//! self-test: if it cannot convict a deliberately lost wakeup, its green
//! runs are worthless.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::executor::pool::{dispatch, signal_shutdown, worker_loop, Slot};

use super::sched::{CheckFailure, Explorer, Report, Sabotage, SabotageBug};

/// One pool scenario shape.
#[derive(Debug, Clone, Copy)]
pub struct PoolCheckConfig {
    /// Acknowledging pool workers (logical threads 1..=workers).
    pub workers: usize,
    /// Bands per dispatch; band 0 runs on the dispatcher.  Must satisfy
    /// `1 <= bands <= workers + 1`, as `WorkerPool::run` guarantees by
    /// clamping.
    pub bands: usize,
    /// Back-to-back dispatch epochs before shutdown.
    pub epochs: usize,
    /// Inject `panic!` into this band of epoch 0 (must be `< bands`);
    /// the scenario then asserts unwind soundness.
    pub panic_band: Option<usize>,
}

/// Exhaustively (within `explorer`'s bounds) check the pool protocol
/// over `cfg`.  `Ok(report)` means every explored schedule terminated
/// with full band coverage; `report.complete` says the schedule tree was
/// exhausted (not budget-truncated).  `Err` carries the first failing
/// schedule.
pub fn check_pool(cfg: PoolCheckConfig, explorer: Explorer) -> Result<Report, CheckFailure> {
    check_pool_with(cfg, explorer, None)
}

/// [`check_pool`] with an optional planted wake-dropping bug, used to
/// prove the checker detects real protocol violations (expect `Err` with
/// a deadlock report when `bug` is `Some`).
pub fn check_pool_with(
    cfg: PoolCheckConfig,
    explorer: Explorer,
    bug: Option<SabotageBug>,
) -> Result<Report, CheckFailure> {
    assert!(cfg.workers >= 1, "the protocol path needs at least one worker");
    assert!(
        cfg.bands >= 1 && cfg.bands <= cfg.workers + 1,
        "bands must be in 1..=workers+1 (WorkerPool::run clamps): {cfg:?}"
    );
    if let Some(b) = cfg.panic_band {
        assert!(b < cfg.bands, "panic_band {b} out of range for {} bands", cfg.bands);
    }
    if cfg.panic_band.is_some() {
        silence_injected_panics();
    }

    explorer.run(Slot::new, |sched| {
        // Fresh per execution; job bodies touch only these atomics, which
        // is what licenses the scheduler's sections-are-atomic reduction.
        let hits: Arc<Vec<AtomicUsize>> = Arc::new(
            (0..cfg.epochs * cfg.bands).map(|_| AtomicUsize::new(0)).collect(),
        );
        let dispatcher_panics = Arc::new(AtomicUsize::new(0));

        {
            let hits = Arc::clone(&hits);
            let dispatcher_panics = Arc::clone(&dispatcher_panics);
            sched.spawn("dispatch", move |sync| {
                let sync = Sabotage::new(sync, bug);
                for e in 0..cfg.epochs {
                    let hits = &hits;
                    let job = move |band: usize| {
                        hits[e * cfg.bands + band].fetch_add(1, Ordering::Relaxed);
                        if e == 0 && cfg.panic_band == Some(band) {
                            panic!("injected check panic");
                        }
                    };
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        dispatch(&sync, cfg.workers, cfg.bands, &job);
                    }));
                    if run.is_err() {
                        dispatcher_panics.fetch_add(1, Ordering::Relaxed);
                    }
                }
                signal_shutdown(&sync);
            });
        }
        for w in 1..=cfg.workers {
            sched.spawn(&format!("worker-{w}"), move |sync| {
                let sync = Sabotage::new(sync, bug);
                worker_loop(&sync, w);
            });
        }

        move || {
            for e in 0..cfg.epochs {
                for b in 0..cfg.bands {
                    let h = hits[e * cfg.bands + b].load(Ordering::Relaxed);
                    if h != 1 {
                        return Err(format!("epoch {e} band {b} ran {h} times (want exactly 1)"));
                    }
                }
            }
            let want = usize::from(cfg.panic_band.is_some());
            let got = dispatcher_panics.load(Ordering::Relaxed);
            if got != want {
                return Err(format!(
                    "dispatcher observed {got} epoch panics, want {want} \
                     (a worker panic must re-raise on the caller, exactly once)"
                ));
            }
            Ok(())
        }
    })
}

/// Unwind-soundness scenarios panic thousands of times across the DFS;
/// install (once, process-wide) a panic hook that swallows exactly the
/// injected messages and delegates everything else.
fn silence_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if msg.contains("injected check panic")
                || msg.contains("arena worker panicked while running a kernel band")
            {
                return;
            }
            prev(info);
        }));
    });
}
