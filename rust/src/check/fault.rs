//! Deterministic fault injection for the serving path.
//!
//! [`FaultyFactory`] wraps any [`EngineFactory`] and [`FaultyEngine`]
//! wraps each engine it builds; both consult a [`FaultPlan`] — a
//! scripted or seeded, fully deterministic source of faults — before
//! delegating.  Driven through `InferenceServer::start_with`, this turns
//! the coordinator's failure handling into something testable on demand
//! rather than hoped-for: `tests/fault_serving.rs` asserts that
//! per-request errors propagate without deadlock, that serving continues
//! after an engine panic, that errors are counted in `ServerStats`, and
//! that no `PendingReply` is ever lost — not even when the worker thread
//! is killed outright.
//!
//! Fault severities ([`Fault`]):
//!
//! - [`Fault::Error`] — the engine returns `Err`; the coordinator must
//!   fail exactly the affected batch and keep serving.
//! - [`Fault::Panic`] — the engine panics; the coordinator's
//!   `catch_unwind` must convert it to a per-batch error and keep the
//!   worker alive.
//! - [`Fault::Die`] — the engine panics with the [`FatalFault`] marker,
//!   which the coordinator deliberately re-raises: the worker thread
//!   dies, simulating an unrecoverable crash.  Outstanding and
//!   subsequent submissions must then error promptly (no hangs).
//! - [`Fault::Delay`] — the engine stalls before serving; for shutdown-
//!   with-in-flight-requests coverage.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::FatalFault;
use crate::executor::{EngineFactory, ExecSnapshot, Executor};
use crate::runtime::{DType, TensorData};
use crate::util::rng::Rng64;

/// One injected misbehavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Return an error from the faulted call.
    Error,
    /// Panic with a plain message (recoverable by the coordinator).
    Panic,
    /// Panic with the [`FatalFault`] marker — simulated worker death
    /// (the coordinator re-raises instead of recovering).
    Die,
    /// Sleep this long, then proceed normally.
    Delay(Duration),
}

enum Mode {
    /// Pop one step per call; exhausted script = no more faults.
    Script(Mutex<VecDeque<Option<Fault>>>),
    /// Draw per call: `fault` with probability `percent`/100.
    Seeded(Mutex<(Rng64, u32, Fault)>),
    /// Route each draw by the serving worker making it: worker `w` draws
    /// from `plans[w]`, any other thread (or `w >= plans.len()`) from the
    /// default plan.  This is what makes multi-worker fault scenarios
    /// deterministic — a shared scripted plan would hand its steps to
    /// whichever worker happens to run first.
    PerWorker(Vec<FaultPlan>, Box<FaultPlan>),
}

/// A deterministic schedule of faults: one [`FaultPlan::next`] draw per
/// intercepted call, shared (via `Arc`) by every engine the wrapped
/// factory builds — so with one engine per batch, scripted step `k`
/// faults batch `k`.
pub struct FaultPlan {
    mode: Mode,
}

impl FaultPlan {
    /// Never faults.
    pub fn none() -> Self {
        FaultPlan { mode: Mode::Script(Mutex::new(VecDeque::new())) }
    }

    /// Fault call `k` with `steps[k]` (`None` entries and every call past
    /// the end pass through clean).
    pub fn script<I: IntoIterator<Item = Option<Fault>>>(steps: I) -> Self {
        FaultPlan { mode: Mode::Script(Mutex::new(steps.into_iter().collect())) }
    }

    /// Fault each call independently with probability `percent`/100,
    /// from a seeded generator — reproducible soak pressure.
    pub fn seeded(seed: u64, percent: u32, fault: Fault) -> Self {
        FaultPlan {
            mode: Mode::Seeded(Mutex::new((Rng64::seed_from_u64(seed), percent.min(100), fault))),
        }
    }

    /// Per-worker routing: a draw made on serving worker `w` (per
    /// [`crate::coordinator::current_worker`]) comes from `plans[w]`;
    /// draws from any other thread — or a worker index past the end —
    /// come from `default`.  Use to target exactly one shard of a
    /// multi-worker server ("kill worker 1's third batch") without the
    /// nondeterminism of N workers racing for one shared script.
    pub fn per_worker<I: IntoIterator<Item = FaultPlan>>(plans: I, default: FaultPlan) -> Self {
        FaultPlan {
            mode: Mode::PerWorker(plans.into_iter().collect(), Box::new(default)),
        }
    }

    /// The fault (if any) for the next intercepted call.
    pub fn next(&self) -> Option<Fault> {
        match &self.mode {
            Mode::Script(q) => q
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .pop_front()
                .flatten(),
            Mode::Seeded(s) => {
                let mut g = s.lock().unwrap_or_else(PoisonError::into_inner);
                let st = &mut *g;
                if (st.0.range_usize(0, 99) as u32) < st.1 {
                    Some(st.2)
                } else {
                    None
                }
            }
            Mode::PerWorker(plans, default) => {
                match crate::coordinator::current_worker() {
                    Some(w) if w < plans.len() => plans[w].next(),
                    _ => default.next(),
                }
            }
        }
    }
}

/// Act out one drawn fault (or pass).  `what` names the faulted call in
/// the error/panic message.
fn trip(plan: &FaultPlan, what: &str) -> Result<()> {
    match plan.next() {
        None => Ok(()),
        Some(Fault::Error) => Err(anyhow!("injected {what} error")),
        Some(Fault::Panic) => panic!("injected {what} panic"),
        Some(Fault::Die) => std::panic::panic_any(FatalFault),
        Some(Fault::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
    }
}

/// An [`Executor`] that consults a [`FaultPlan`] before every `run` /
/// `run_into`, then delegates.
pub struct FaultyEngine {
    inner: Box<dyn Executor>,
    plan: Arc<FaultPlan>,
    name: String,
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn Executor>, plan: Arc<FaultPlan>) -> Self {
        let name = format!("faulty({})", inner.name());
        FaultyEngine { inner, plan, name }
    }
}

impl Executor for FaultyEngine {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        trip(&self.plan, "engine run")?;
        self.inner.run(input)
    }

    fn run_into(&self, input: &TensorData, out: &mut TensorData) -> Result<()> {
        trip(&self.plan, "engine run")?;
        self.inner.run_into(input, out)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        self.inner.input_desc()
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        self.inner.output_desc()
    }

    fn counters(&self) -> ExecSnapshot {
        self.inner.counters()
    }
}

/// An [`EngineFactory`] decorator: faults on `build` (startup-failure
/// coverage) and hands every built engine a shared run-fault plan.
pub struct FaultyFactory<F> {
    inner: F,
    build_plan: FaultPlan,
    run_plan: Arc<FaultPlan>,
}

impl<F: EngineFactory> FaultyFactory<F> {
    /// Wrap `inner` with no faults; add plans with the builders below.
    pub fn new(inner: F) -> Self {
        FaultyFactory {
            inner,
            build_plan: FaultPlan::none(),
            run_plan: Arc::new(FaultPlan::none()),
        }
    }

    /// Fault plan for `build` calls (one draw per bucket engine built).
    pub fn build_faults(mut self, plan: FaultPlan) -> Self {
        self.build_plan = plan;
        self
    }

    /// Fault plan for engine `run`/`run_into` calls (one draw per served
    /// batch, shared across all bucket engines in build order).
    pub fn run_faults(mut self, plan: FaultPlan) -> Self {
        self.run_plan = Arc::new(plan);
        self
    }
}

impl<F: EngineFactory> EngineFactory for FaultyFactory<F> {
    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        trip(&self.build_plan, "factory build")?;
        Ok(Box::new(FaultyEngine::new(self.inner.build(batch)?, Arc::clone(&self.run_plan))))
    }

    fn describe(&self) -> String {
        format!("faulty({})", self.inner.describe())
    }
}

/// Install (once, process-wide) a panic hook that swallows the injected
/// fault panics — thousands of deliberate panics across a fault soak
/// otherwise bury real test output — and delegates everything else.
/// Call at the top of fault-injection tests.
pub fn silence_injected_faults() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            if payload.is::<FatalFault>() {
                return;
            }
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if msg.starts_with("injected ") {
                return;
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_plan_pops_in_order_then_runs_dry() {
        let plan = FaultPlan::script([Some(Fault::Error), None, Some(Fault::Panic)]);
        assert_eq!(plan.next(), Some(Fault::Error));
        assert_eq!(plan.next(), None);
        assert_eq!(plan.next(), Some(Fault::Panic));
        assert_eq!(plan.next(), None, "exhausted script never faults again");
        assert_eq!(plan.next(), None);
    }

    #[test]
    fn per_worker_plan_routes_by_current_worker() {
        let plan = FaultPlan::per_worker(
            [
                FaultPlan::script([Some(Fault::Error)]),
                FaultPlan::script([Some(Fault::Die)]),
            ],
            FaultPlan::none(),
        );
        // Off-worker threads (and worker ids past the vec) hit the default.
        assert_eq!(plan.next(), None);
        std::thread::scope(|s| {
            let plan = &plan;
            s.spawn(move || {
                crate::coordinator::set_worker_id(Some(1));
                assert_eq!(plan.next(), Some(Fault::Die));
                assert_eq!(plan.next(), None, "worker 1's script is exhausted");
                crate::coordinator::set_worker_id(Some(5));
                assert_eq!(plan.next(), None, "unplanned worker uses the default");
            });
            s.spawn(move || {
                crate::coordinator::set_worker_id(Some(0));
                assert_eq!(plan.next(), Some(Fault::Error));
            });
        });
    }

    #[test]
    fn seeded_plan_is_deterministic_and_rate_bounded() {
        let a = FaultPlan::seeded(42, 30, Fault::Error);
        let b = FaultPlan::seeded(42, 30, Fault::Error);
        let draws_a: Vec<_> = (0..200).map(|_| a.next()).collect();
        let draws_b: Vec<_> = (0..200).map(|_| b.next()).collect();
        assert_eq!(draws_a, draws_b, "same seed, same fault schedule");
        let faults = draws_a.iter().filter(|d| d.is_some()).count();
        assert!(
            (20..=100).contains(&faults),
            "30% of 200 draws should fault roughly 60 times, got {faults}"
        );
        let never = FaultPlan::seeded(7, 0, Fault::Panic);
        assert!((0..100).all(|_| never.next().is_none()));
    }
}
