//! The deterministic model scheduler: cooperative logical threads whose
//! every synchronization decision belongs to a DFS explorer.
//!
//! ## Execution model
//!
//! An execution runs N *logical threads* (real OS threads, but at most
//! one ever executes at a time — a run token is handed around by the
//! scheduler).  The threads run the pool's **real protocol code**
//! ([`crate::executor::pool::dispatch`] / `worker_loop`), generic over
//! [`SyncOps`]; the model implementation ([`ModelSync`]) turns each
//! primitive into scheduler events:
//!
//! - **critical sections are atomic**: `locked`/`locked_wait` bodies run
//!   under the scheduler's own state mutex, so a section is one
//!   indivisible step.  This is the standard reduction — a mutex-guarded
//!   section with no internal blocking admits no observable internal
//!   interleaving — and it is what keeps the schedule space tractable.
//! - **a choice point precedes every critical-section entry** (and every
//!   [`SyncOps::yield_point`]): the scheduler decides whether the running
//!   thread proceeds or another runnable thread is scheduled first
//!   (a *preemption*, counted against the preemption bound).
//! - **condvar waits and thread exits force a switch**: the scheduler
//!   picks any runnable thread, at no preemption cost.  Waiters move
//!   back to runnable when a critical section requests the matching
//!   [`Wake`]; there are no spurious wakeups (modeling strictly fewer
//!   wakeups than std is conservative for *lost*-wakeup detection).
//!
//! Code between synchronization points is treated as atomic; scenario
//! jobs must confine shared effects to commutative atomics (counters),
//! which the pool harness does.
//!
//! ## Exploration
//!
//! Each choice is recorded as `(chosen index, admissible options)`.  A
//! schedule is the sequence of chosen indices; the explorer replays a
//! prefix, extends it greedily with option 0, and backtracks to the
//! deepest decision with an untried option — depth-first over the whole
//! schedule tree.  With a preemption bound `p`, choice points where the
//! running thread is runnable admit alternatives only while preemptions
//! remain, so the tree is the complete set of schedules with ≤ p
//! preemptions (plus all blocking-driven switches, which are free).
//! Exploration is **exhaustive within that bound** when it terminates
//! under the schedule budget; [`Report::complete`] says which.
//!
//! ## Failure handling
//!
//! A deadlock (no runnable thread, some alive), a decision-depth
//! overrun, or a panic on a logical thread fails the execution with the
//! offending schedule.  The scheduler then enters *drain mode*: token
//! discipline is suspended, the slot is poisoned toward shutdown
//! (`shutdown = true`, and `outstanding` forced to 0 only once every
//! alive thread is parked — never while a worker may still hold the
//! dispatched job reference, preserving the pool's job-containment
//! invariant even on failing runs), and every thread runs home so the
//! explorer can join them and report.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

use crate::executor::pool::{Cv, Slot, SyncOps, Wake};

const NONE: usize = usize::MAX;

/// A protocol state the scheduler can model-check: plain data mutated
/// only inside critical sections, plus a drain hook.  The scheduler is
/// generic over this, so the pool's epoch protocol ([`Slot`]) and the
/// coordinator's admission queue (`QState`) share one checker.
pub(crate) trait ProtoState: Send + 'static {
    /// Poison the state toward shutdown on a failing run so every thread
    /// runs home for the join.  `all_parked` is true once every alive
    /// thread is waiting or finished — protocols whose drain would break
    /// an in-flight containment invariant (the pool forcing
    /// `outstanding = 0` while a worker still holds the dispatched job
    /// reference) gate the destructive part on it.
    fn drain(&mut self, all_parked: bool);
}

impl ProtoState for Slot {
    fn drain(&mut self, all_parked: bool) {
        self.shutdown = true;
        if all_parked {
            self.outstanding = 0;
        }
    }
}

/// One logical thread's scheduler-visible state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TStatus {
    /// May be granted the run token (includes currently holding it).
    Runnable,
    /// Sleeping on a model condvar; a matching wake flips it runnable.
    Waiting(Cv),
    Finished,
}

/// One recorded scheduling decision: which of the admissible options was
/// taken.  Options are ordered deterministically (continue-current first
/// at preemptible points, then runnable threads by id), so `(chosen,
/// options)` pairs fully describe the schedule tree.
#[derive(Debug, Clone, Copy)]
struct Decision {
    chosen: usize,
    options: usize,
}

struct State<P> {
    status: Vec<TStatus>,
    /// Logical thread holding the run token (NONE once all finished).
    current: usize,
    /// Model-mutex owner; with atomic critical sections it is only ever
    /// taken and released inside one scheduler step, so this is a pure
    /// sanity check.
    lock_owner: usize,
    /// The protocol state the critical sections mutate.
    proto: P,
    decisions: Vec<Decision>,
    /// Forced choices for the first `prefix.len()` decision points.
    prefix: Vec<usize>,
    preemptions_left: usize,
    max_decisions: usize,
    failure: Option<String>,
    draining: bool,
    finished: usize,
}

/// The scheduler for ONE execution (one schedule).  Fresh per run.
pub(crate) struct ModelSched<P: ProtoState> {
    state: Mutex<State<P>>,
    cv: Condvar,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

fn lock_state<P>(m: &Mutex<State<P>>) -> MutexGuard<'_, State<P>> {
    // A panicking logical thread unwinds past guards by design (panic
    // injection is part of what we check); recover rather than cascade.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl<P: ProtoState> ModelSched<P> {
    pub(crate) fn new(
        prefix: Vec<usize>,
        max_decisions: usize,
        preemptions: usize,
        proto: P,
    ) -> Self {
        ModelSched {
            state: Mutex::new(State {
                status: Vec::new(),
                current: NONE,
                lock_owner: NONE,
                proto,
                decisions: Vec::new(),
                prefix,
                preemptions_left: preemptions,
                max_decisions,
                failure: None,
                draining: false,
                finished: 0,
            }),
            cv: Condvar::new(),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Register and spawn one logical thread.  Ids are assigned in call
    /// order (the scenario's spawn order), which is what makes replay
    /// deterministic.  Thread 0 receives the initial token.
    pub(crate) fn spawn<F>(self: &Arc<Self>, name: &str, f: F)
    where
        F: FnOnce(&ModelSync<P>) + Send + 'static,
    {
        let me = {
            let mut g = lock_state(&self.state);
            g.status.push(TStatus::Runnable);
            g.status.len() - 1
        };
        let sched = Arc::clone(self);
        let name = name.to_string();
        let h = std::thread::Builder::new()
            .name(format!("tvmq-check-{name}"))
            .spawn(move || {
                let sync = ModelSync { sched: Arc::clone(&sched), me };
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&sync)));
                let mut g = lock_state(&sched.state);
                if let Err(payload) = r {
                    // A panic that escapes a logical thread is a verdict:
                    // either the protocol swallowed/shouldn't-have or a
                    // scenario assertion fired.  (During drain it is just
                    // collateral of the already-recorded failure.)
                    if !g.draining {
                        let msg = panic_text(payload.as_ref());
                        sched.fail(&mut g, format!("logical thread {me} ({name}) panicked: {msg}"));
                    }
                }
                if g.lock_owner == me {
                    g.lock_owner = NONE;
                }
                g.status[me] = TStatus::Finished;
                g.finished += 1;
                if g.draining {
                    sched.cv.notify_all();
                    return;
                }
                if g.finished == g.status.len() {
                    g.current = NONE;
                    sched.cv.notify_all();
                } else if g.current == me {
                    sched.grant(&mut g, me, false);
                    sched.cv.notify_all();
                }
            })
            .expect("spawn model thread");
        self.handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(h);
    }

    /// Hand the initial token to thread 0 and release the threads.  Not a
    /// decision: thread 0's first choice point offers every alternative
    /// at no preemption cost (see [`ModelSched::grant`]), so all initial
    /// orders are still explored — without a redundant extra level in the
    /// schedule tree.
    pub(crate) fn start(&self) {
        let mut g = lock_state(&self.state);
        if !g.status.is_empty() {
            g.current = 0;
        }
        self.cv.notify_all();
    }

    /// Join every logical thread, then report `(schedule, failure)`.
    pub(crate) fn finish(&self) -> (Vec<(usize, usize)>, Option<String>) {
        let handles: Vec<_> = self
            .handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
        let g = lock_state(&self.state);
        (
            g.decisions.iter().map(|d| (d.chosen, d.options)).collect(),
            g.failure.clone(),
        )
    }

    /// Record a failure and switch to drain mode: suspend the token,
    /// push the protocol state toward shutdown, wake everyone.
    fn fail(&self, g: &mut State<P>, msg: String) {
        if g.failure.is_none() {
            let trace: Vec<usize> = g.decisions.iter().map(|d| d.chosen).collect();
            g.failure = Some(format!("{msg} [schedule {trace:?}]"));
        }
        g.draining = true;
        g.proto.drain(false);
        self.cv.notify_all();
    }

    /// Decide who runs next at a choice point.  `me_continues`: whether
    /// "let `me` keep running" is an admissible option (true at
    /// preemptible points, false when `me` just blocked or finished).
    /// Sets `current` to the chosen thread; the caller notifies.
    fn grant(&self, g: &mut State<P>, me: usize, me_continues: bool) {
        if g.draining {
            return;
        }
        let mut options: Vec<usize> = Vec::new();
        let first_decision = g.decisions.is_empty();
        if me_continues {
            options.push(me);
            // Alternatives to a runnable current thread are preemptions —
            // admissible only while budget remains.  The execution's very
            // first choice point is exempt: picking which thread starts
            // is an ordering, not a preemption.
            if g.preemptions_left > 0 || first_decision {
                for t in 0..g.status.len() {
                    if t != me && g.status[t] == TStatus::Runnable {
                        options.push(t);
                    }
                }
            }
        } else {
            for t in 0..g.status.len() {
                if t != me && g.status[t] == TStatus::Runnable {
                    options.push(t);
                }
            }
        }
        if options.is_empty() {
            // Nobody can run, somebody is still alive: every alive
            // thread is asleep on a condvar — a lost wakeup.
            self.fail(
                g,
                format!(
                    "deadlock: no runnable thread ({} alive, statuses {:?})",
                    g.status.len() - g.finished,
                    g.status
                ),
            );
            return;
        }
        let k = g.decisions.len();
        if k >= g.max_decisions {
            self.fail(g, format!("decision bound {} exceeded (livelock?)", g.max_decisions));
            return;
        }
        let chosen = if k < g.prefix.len() { g.prefix[k] } else { 0 };
        if chosen >= options.len() {
            // Replay must be deterministic; divergence is a checker bug.
            self.fail(
                g,
                format!(
                    "replay diverged at decision {k}: prefix chose {chosen} of {} options",
                    options.len()
                ),
            );
            return;
        }
        if me_continues && chosen > 0 && !first_decision {
            g.preemptions_left -= 1;
        }
        g.decisions.push(Decision { chosen, options: options.len() });
        g.current = options[chosen];
    }

    /// Preemptible choice point taken by the token holder `me`; returns
    /// once `me` may run again (immediately, or after the threads it was
    /// preempted for have run), or once draining starts.
    fn choice_point<'a>(
        &'a self,
        mut g: MutexGuard<'a, State<P>>,
        me: usize,
    ) -> MutexGuard<'a, State<P>> {
        if g.draining {
            return g;
        }
        debug_assert_eq!(g.current, me, "choice point from a thread without the token");
        self.grant(&mut g, me, true);
        self.cv.notify_all();
        while !(g.draining || (g.current == me && g.status[me] == TStatus::Runnable)) {
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        g
    }

    /// Park until granted the token (a thread arriving at its first sync
    /// op, or re-arriving after being preempted elsewhere).
    fn park_until_current<'a>(
        &'a self,
        mut g: MutexGuard<'a, State<P>>,
        me: usize,
    ) -> MutexGuard<'a, State<P>> {
        while !(g.draining || (g.current == me && g.status[me] == TStatus::Runnable)) {
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        g
    }

    /// Entry into a critical section: a preemptible choice point for the
    /// token holder, a park for anyone else.
    fn enter<'a>(&'a self, me: usize) -> MutexGuard<'a, State<P>> {
        let g = lock_state(&self.state);
        if g.draining {
            return g;
        }
        if g.current == me {
            self.choice_point(g, me)
        } else {
            self.park_until_current(g, me)
        }
    }

    /// Apply a critical section's wake requests: waiters flip runnable.
    /// `notify_one` deterministically wakes the lowest-id waiter — the
    /// checker explores *whether* a wake lands in time, not which of
    /// several equivalent waiters receives it (conservative for lost
    /// wakeups, which is the bug class this layer hunts).
    fn apply_wakes(g: &mut State<P>, w: &Wake) {
        if w.work_all {
            for s in g.status.iter_mut() {
                if *s == TStatus::Waiting(Cv::Work) {
                    *s = TStatus::Runnable;
                }
            }
        } else if w.work_one {
            if let Some(s) = g
                .status
                .iter_mut()
                .find(|s| **s == TStatus::Waiting(Cv::Work))
            {
                *s = TStatus::Runnable;
            }
        }
        if w.done_one {
            if let Some(s) = g
                .status
                .iter_mut()
                .find(|s| **s == TStatus::Waiting(Cv::Done))
            {
                *s = TStatus::Runnable;
            }
        }
    }

    /// Drain-mode sweep: the destructive part of the protocol's drain
    /// (the pool forcing its epoch counter open) applies **only when
    /// every alive thread is parked** — a worker holding the dispatched
    /// job reference is running (not parked), so the dispatcher's barrier
    /// stays intact until the job retires, exactly as in production.
    fn drain_sweep(g: &mut State<P>) {
        let all_parked = g
            .status
            .iter()
            .all(|s| matches!(s, TStatus::Waiting(_) | TStatus::Finished));
        g.proto.drain(all_parked);
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// The model [`SyncOps`]: one handle per logical thread, delegating every
/// primitive to the shared [`ModelSched`].
pub(crate) struct ModelSync<P: ProtoState> {
    sched: Arc<ModelSched<P>>,
    me: usize,
}

impl<P: ProtoState> ModelSync<P> {
    /// Run one atomic critical section under the already-entered state
    /// guard, delivering wakes before the guard drops.
    fn section<R>(
        &self,
        g: &mut State<P>,
        f: impl FnOnce(&mut P, &mut Wake) -> R,
    ) -> R {
        debug_assert_eq!(g.lock_owner, NONE, "atomic sections cannot nest");
        g.lock_owner = self.me;
        let mut w = Wake::default();
        let r = f(&mut g.proto, &mut w);
        ModelSched::apply_wakes(g, &w);
        g.lock_owner = NONE;
        r
    }
}

impl<P: ProtoState> SyncOps for ModelSync<P> {
    type St = P;

    fn locked<R>(&self, f: impl FnOnce(&mut P, &mut Wake) -> R) -> R {
        let mut g = self.sched.enter(self.me);
        let r = self.section(&mut g, f);
        if g.draining {
            ModelSched::drain_sweep(&mut g);
        }
        self.sched.cv.notify_all();
        r
    }

    fn locked_wait<R>(
        &self,
        cv: Cv,
        mut f: impl FnMut(&mut P, &mut Wake) -> Option<R>,
    ) -> R {
        let mut g = self.sched.enter(self.me);
        loop {
            if let Some(r) = self.section(&mut g, &mut f) {
                if g.draining {
                    ModelSched::drain_sweep(&mut g);
                }
                g.status[self.me] = TStatus::Runnable;
                self.sched.cv.notify_all();
                return r;
            }
            g.status[self.me] = TStatus::Waiting(cv);
            if g.draining {
                // Drain: no token discipline; poll with a timeout so a
                // missed drain notification can never wedge the join.
                ModelSched::drain_sweep(&mut g);
                self.sched.cv.notify_all();
                let (ng, _) = self
                    .sched
                    .cv
                    .wait_timeout(g, std::time::Duration::from_millis(2))
                    .unwrap_or_else(PoisonError::into_inner);
                g = ng;
                continue;
            }
            // Forced switch: `me` just went to sleep; any runnable thread
            // may take over, at no preemption cost.
            self.sched.grant(&mut g, self.me, false);
            self.sched.cv.notify_all();
            g = self.sched.park_until_current(g, self.me);
        }
    }

    fn yield_point(&self) {
        let g = lock_state(&self.sched.state);
        if g.draining || g.current != self.me {
            return;
        }
        let g = self.sched.choice_point(g, self.me);
        drop(g);
    }
}

// ---------------------------------------------------------------------------
// The DFS explorer
// ---------------------------------------------------------------------------

/// One failing schedule, with enough context to replay it by hand.
#[derive(Debug, Clone)]
pub struct CheckFailure {
    /// What went wrong (deadlock / panic / bound overrun / property).
    pub description: String,
    /// The decision sequence (chosen option per choice point) of the
    /// failing execution.
    pub schedule: Vec<usize>,
    /// Schedules explored before the failure surfaced.
    pub schedules_explored: usize,
}

impl std::fmt::Display for CheckFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} (after {} schedules; failing schedule: {:?})",
            self.description, self.schedules_explored, self.schedule
        )
    }
}

impl std::error::Error for CheckFailure {}

/// What an exploration covered.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Executions (complete schedules) run.
    pub schedules: usize,
    /// True when the DFS exhausted the schedule tree within the budget —
    /// i.e. the verified properties hold over **every** schedule within
    /// the preemption bound, not just the ones a budget allowed.
    pub complete: bool,
    /// Deepest decision sequence seen (a state-space size proxy).
    pub peak_decisions: usize,
}

/// Bounds for one exploration.
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Max executions before giving up (incomplete, not failed).
    pub max_schedules: usize,
    /// Max scheduling decisions per execution (livelock guard).
    pub max_decisions: usize,
    /// Preemption bound: extra context switches at points where the
    /// running thread could have continued.  Blocking-driven switches are
    /// always free, so even bound 0 explores every wait/notify ordering.
    pub preemptions: usize,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer { max_schedules: 200_000, max_decisions: 10_000, preemptions: 2 }
    }
}

impl Explorer {
    /// Run the DFS: `init` builds the fresh protocol state for each
    /// execution, and `setup` is called once per execution to spawn the
    /// scenario's logical threads onto the fresh scheduler and returns
    /// the post-run property validator.  Returns the first failure
    /// (scheduler-detected or validator-rejected) or a coverage report.
    /// Crate-visible (the scheduler types are not public API); external
    /// callers go through `check::check_pool` / `check::check_queue`.
    pub(crate) fn run<P, I, S, V>(&self, init: I, mut setup: S) -> Result<Report, CheckFailure>
    where
        P: ProtoState,
        I: Fn() -> P,
        S: FnMut(&Arc<ModelSched<P>>) -> V,
        V: FnOnce() -> Result<(), String>,
    {
        let mut prefix: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        let mut peak = 0usize;
        loop {
            if schedules >= self.max_schedules {
                return Ok(Report { schedules, complete: false, peak_decisions: peak });
            }
            let sched = Arc::new(ModelSched::new(
                prefix.clone(),
                self.max_decisions,
                self.preemptions,
                init(),
            ));
            let validate = setup(&sched);
            sched.start();
            let (decisions, failure) = sched.finish();
            schedules += 1;
            peak = peak.max(decisions.len());
            let schedule: Vec<usize> = decisions.iter().map(|d| d.0).collect();
            if let Some(description) = failure {
                return Err(CheckFailure {
                    description,
                    schedule,
                    schedules_explored: schedules,
                });
            }
            if let Err(msg) = validate() {
                return Err(CheckFailure {
                    description: format!("property violated: {msg}"),
                    schedule,
                    schedules_explored: schedules,
                });
            }
            // Backtrack: deepest decision with an untried option.  The
            // admissible-options count already encodes the preemption
            // budget at that point, so plain increment is sound.
            match decisions
                .iter()
                .rposition(|&(chosen, options)| chosen + 1 < options)
            {
                Some(k) => {
                    prefix = decisions[..k].iter().map(|d| d.0).collect();
                    prefix.push(decisions[k].0 + 1);
                }
                None => {
                    return Ok(Report { schedules, complete: true, peak_decisions: peak })
                }
            }
        }
    }
}

/// A [`SyncOps`] wrapper that corrupts wake delivery — the checker's own
/// oracle.  A checker that cannot find a deliberately-planted lost
/// wakeup proves nothing; `tests/pool_check.rs` plants these and asserts
/// a deadlock is reported.
pub(crate) struct Sabotage<S> {
    inner: S,
    /// `None` = faithful passthrough (the harness always wraps, so the
    /// checked protocol code is byte-identical with and without a bug).
    bug: Option<SabotageBug>,
    fired: AtomicBool,
}

/// Which wakeup to lose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SabotageBug {
    /// Swallow the first work-side wake, `notify_all` or `notify_one` —
    /// for the pool, a dispatch whose workers were already asleep never
    /// starts, so the dispatcher's barrier hangs; for the admission
    /// queue, the first accepted item never wakes its consumer.
    DropFirstWorkWake,
    /// Swallow every `notify_one(done)` — the pool's last acknowledgement
    /// never wakes a sleeping dispatcher; the queue's drained counters
    /// never wake the settle-waiter.
    DropDoneWake,
}

impl<S> Sabotage<S> {
    pub(crate) fn new(inner: S, bug: Option<SabotageBug>) -> Self {
        Sabotage { inner, bug, fired: AtomicBool::new(false) }
    }

    fn doctor(&self, w: &mut Wake) {
        match self.bug {
            None => {}
            Some(SabotageBug::DropFirstWorkWake) => {
                if (w.work_all || w.work_one) && !self.fired.swap(true, Ordering::Relaxed) {
                    w.work_all = false;
                    w.work_one = false;
                }
            }
            Some(SabotageBug::DropDoneWake) => {
                w.done_one = false;
            }
        }
    }
}

impl<S: SyncOps> SyncOps for Sabotage<S> {
    type St = S::St;

    fn locked<R>(&self, f: impl FnOnce(&mut Self::St, &mut Wake) -> R) -> R {
        self.inner.locked(|s, w| {
            let r = f(s, w);
            self.doctor(w);
            r
        })
    }

    fn locked_wait<R>(
        &self,
        cv: Cv,
        mut f: impl FnMut(&mut Self::St, &mut Wake) -> Option<R>,
    ) -> R {
        self.inner.locked_wait(cv, |s, w| {
            let r = f(s, w);
            self.doctor(w);
            r
        })
    }

    fn yield_point(&self) {
        self.inner.yield_point();
    }
}
