//! Concurrency checking: exhaustive interleaving exploration for the
//! arena pool's epoch protocol and the coordinator's admission queue,
//! and deterministic fault injection for the serving path.
//!
//! The repo's discipline is that a performance claim is worthless
//! without a correctness gate (the tuner refuses to time a candidate
//! that fails the interpreter oracle).  This module applies the same
//! discipline to the concurrency spine: the hand-rolled mutex/condvar
//! protocol in `executor::pool` is verified by running **the protocol
//! code itself** — not a transcription — under a deterministic model
//! scheduler ([`sched`]) that owns every synchronization decision and
//! enumerates thread interleavings by DFS, CHESS-style; and the
//! coordinator's failure handling is exercised on demand by the
//! [`fault`] layer instead of waiting for production to produce the
//! failure.
//!
//! ## What the shim CAN prove
//!
//! Over a concrete configuration (workers × bands × epochs) and within a
//! stated preemption bound, [`check_pool`] establishes — for **every**
//! schedule in that space, when the report says `complete` — that:
//!
//! - every `(epoch, band)` pair executes exactly once (covering);
//! - every dispatch and the final shutdown terminate — no lost wakeups,
//!   no deadlock (the scheduler convicts any schedule that strands a
//!   sleeping thread);
//! - a panicking band still acknowledges its epoch, the panic re-raises
//!   on the dispatcher exactly once, and later epochs run clean (unwind
//!   soundness).
//!
//! [`check_queue`] applies the same treatment to the sharded
//! coordinator's bounded admission queue (`coordinator::queue`): over a
//! producers × consumers × items × bound configuration it establishes
//! that every offered item settles as consumed-exactly-once or shed-
//! exactly-once, that shutdown drains accepted work before consumers go
//! home, and that a consumer dying mid-stream strands nothing — the
//! survivors finish the drain (worker-death failover at the protocol
//! level).
//!
//! Because the model substrate has **no spurious wakeups**, it delivers
//! strictly fewer wakeups than std's condvars may — conservative in the
//! direction that matters for lost-wakeup bugs.  And because the checker
//! runs the real generic protocol (`dispatch`/`worker_loop`/
//! `signal_shutdown`, `q_push`/`q_pop`/`q_shutdown` over `SyncOps`), a
//! property proved here is a property of the code the production
//! `WorkerPool` and `InferenceServer` monomorphize.
//!
//! ## What it CANNOT prove
//!
//! - **Unbounded generality**: properties hold for the checked
//!   configurations and preemption bound, not for all N.  (Empirically,
//!   lost-wakeup and epoch-protocol bugs in this family surface at 2–3
//!   threads and ≤2 preemptions — the planted-bug self-tests in
//!   `tests/pool_check.rs` are all caught at bound 0–1.)
//! - **Weak memory**: the model is sequentially consistent.  The real
//!   protocol keeps all shared state under one mutex, so this gap is
//!   confined to code *outside* the critical sections; job bodies must
//!   confine shared effects to commutative atomics, as the harness's do.
//! - **Timing**: the scheduler explores orderings, not durations;
//!   timeout-based behavior (the batcher's gather deadline) is out of
//!   scope here and covered by the fault layer's wall-clock tests.
//! - **Non-`SyncOps` blocking**: only synchronization expressed through
//!   the trait is visible; a job that blocked on an external channel
//!   would be invisible to the DFS (none do).
//!
//! ## Schedule-bound semantics
//!
//! A *preemption* is a context switch at a point where the running
//! thread could have continued (critical-section entries and declared
//! yield points).  Switches forced by blocking — condvar waits, thread
//! exit — are always free.  With preemption bound `p`, the DFS covers
//! exactly the schedules containing ≤ `p` preemptions; `p = 0` already
//! covers every ordering driven by sleeps and wakeups, and small `p`
//! adds races between a running thread and its peers.  The explorer also
//! carries a schedule budget ([`Explorer::max_schedules`]) and a
//! per-execution decision bound (livelock guard); a budget-truncated run
//! reports `complete = false` and the CI gate treats its coverage as
//! partial, never as proof.

pub mod fault;
mod pool_model;
mod queue_model;
pub(crate) mod sched;

pub use pool_model::{check_pool, check_pool_with, PoolCheckConfig};
pub use queue_model::{check_queue, check_queue_with, QueueCheckConfig, QueueReport};
pub use sched::{CheckFailure, Explorer, Report, SabotageBug};
