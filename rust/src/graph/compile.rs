//! Graph → arena-planned instruction stream: the lowering step behind the
//! [`crate::executor::ArenaExec`] tier.
//!
//! TVM's graph executor wins over the relay VM for two mechanistic reasons
//! the paper isolates: **fusion** (boundary and elementwise operators
//! disappear into their anchor's epilogue instead of materializing
//! intermediate tensors) and **static memory planning** (every intermediate
//! gets a pre-computed offset into one shared arena, so serving an
//! inference does zero dynamic allocation).  This module reproduces both at
//! the IR level.
//!
//! # Fusion rules
//!
//! A fused step is an *anchor* (`Conv2d` in any layout — NCHW, NHWC, or
//! NCHW{c} — or `Dense`; the dense anchor has no layout) plus an epilogue
//! tail applied per output element.  Two chain shapes fuse:
//!
//! 1. **Quantized** (the `fuse` ablation flag controls all fusion):
//!    `Quantize → Conv2d/Dense(i8 const weight, i32 accum) → Dequantize`
//!    followed by the shared epilogue tail.  The quantized input lives in a
//!    per-step scratch slot; the i32 accumulator and every interior f32
//!    value never exist in memory.
//! 2. **fp32**: a `Conv2d`/`Dense` whose output is f32, followed by at
//!    least one epilogue op (an anchor with nothing to absorb stays a plain
//!    1:1 step).
//!
//! The shared epilogue tail is, in order:
//! `[BiasAdd(f32 const, conv only, same layout as the anchor)] → [Add] →
//! [Relu] → [Add]` — at most one
//! residual `Add`, either before the relu (the ResNet block tail
//! `conv→bias→add→relu`) or after it.  A residual `Add` fuses only when its
//! other operand is already materialized when the fused step runs: a
//! constant, or a node defined *before* the chain's first member (steps are
//! emitted in node order, so earlier ids mean earlier steps).  The residual
//! operand becomes the step's third source and its lifetime is explicitly
//! extended through the fused step
//! ([`crate::memplan::ValueLife::extend_through`]), which forces the
//! planner to keep it space-disjoint from the step's destination — a
//! compile-time check re-verifies that disjointness on every two-input
//! step.  Every interior chain link must be single-consumer and not the
//! graph output.
//!
//! Integer elementwise tails do not fuse (fused chains always end in f32:
//! a dequantized quantized chain or an f32 anchor).  A *quantized*
//! NCHW{c} chain whose channel block fits the executor's stack-resident
//! lane accumulator (the [`ScheduleOverrides::max_stack_lanes`] knob,
//! capped at [`MAX_FUSED_QCONV_CB`]) accumulates on the stack; wider
//! blocks still fuse, spilling the accumulator to per-band windows planned
//! into the step's scratch slot ([`Step::spill`]) — so serving stays
//! allocation-free at every block width.
//!
//! # Schedule overrides
//!
//! Every step carries a [`StepSched`] — banding mode and band cap for the
//! executor's row fan-out — resolved from a [`ScheduleOverrides`] table
//! keyed by the anchor's [`ClassKey`] (op family × layout).  The default
//! overrides reproduce the historical hard-coded schedule; the autotuner
//! (`crate::tune`) searches this space and feeds the winner back in.
//! Overrides never change *what* a step computes, only how its
//! independent output rows are distributed, so every candidate schedule
//! is bit-for-bit equal to the oracle by construction (and the tuner's
//! measurer re-checks anyway).
//!
//! The semantics contract: executing the stream is **bit-for-bit** equal to
//! [`super::interp::evaluate`] — fused epilogues apply exactly the same
//! per-element float operation sequence the unfused ops would (dequantize
//! multiply, then bias add, then the adds/relu in graph order, preserving
//! `Add` operand order, which is observable for NaN), and integer
//! accumulation is order-independent.  The differential tests and the
//! `tests/graph_fuzz.rs` randomized harness enforce this.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use super::ir::{ConstValue, Graph, IrDType, Layout, NodeId, Op, TensorTy};
use super::passes::{DeadCodeElim, Pass};
use crate::executor::Banding;
use crate::memplan::{round_up, StaticPlan, ValueLife};

/// Arena placement alignment: cache-line sized, so typed reinterpretation
/// is always element-aligned and parallel writers don't share lines.
pub const ARENA_ALIGN: usize = 64;

/// Widest channel block the fused quantized NCHW{c} kernel accumulates in
/// its **stack** lane array.  Wider blocks still fuse: the compiler plans
/// per-band i32 spill windows into the step's scratch slot
/// ([`SpillSpec`]), so serving stays allocation-free.  The effective
/// stack bound is `min(self, ScheduleOverrides::max_stack_lanes)` — the
/// tuner can lower it (forcing the spill strategy), never raise it past
/// the executor's fixed stack array.
pub const MAX_FUSED_QCONV_CB: usize = 64;

/// Anchor-step family a schedule override is keyed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AnchorOp {
    Conv2d,
    QConv2d,
    Dense,
    QDense,
}

impl AnchorOp {
    pub fn as_str(self) -> &'static str {
        match self {
            AnchorOp::Conv2d => "conv2d",
            AnchorOp::QConv2d => "qconv2d",
            AnchorOp::Dense => "dense",
            AnchorOp::QDense => "qdense",
        }
    }
}

impl std::str::FromStr for AnchorOp {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "conv2d" => AnchorOp::Conv2d,
            "qconv2d" => AnchorOp::QConv2d,
            "dense" => AnchorOp::Dense,
            "qdense" => AnchorOp::QDense,
            other => return Err(anyhow!("unknown anchor op {other:?}")),
        })
    }
}

/// The tuner's task identity at the compile level: which anchor family in
/// which layout a [`StepSched`] override applies to.  Dense anchors carry
/// no layout.  (The records file additionally keys on shape, precision,
/// and thread count — see `crate::tune::records` — but the compiler only
/// needs the class to resolve a step's schedule.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassKey {
    pub op: AnchorOp,
    pub layout: Option<Layout>,
}

/// Register-blocked microkernel geometry for an int8 anchor step: the
/// cache-tiling factors the tuner searches alongside banding.  Setting it
/// routes the step through the pre-packed panel kernels in
/// [`crate::executor::microkernel`]; `None` keeps the historical scalar
/// loops.  Like every schedule knob it is semantics-free: integer
/// accumulation is order-exact, so no tile geometry can change a bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MicroKernel {
    /// Output-position (m) tile: how many output columns one weight-panel
    /// pass covers before moving on (register/L1 reuse of the panel).
    pub mr: usize,
    /// Output-lane (n) tile: output channels/features grouped per
    /// activation-span pass.  NCHW rows own a single output channel and
    /// NCHW{c} tiles its fixed `kb` lanes, so those kernels ignore it.
    pub nr: usize,
    /// Reduction (k) unroll chunk of the scalar fallback tile (the SIMD
    /// paths step by their register width instead).
    pub ku: usize,
}

impl Default for MicroKernel {
    fn default() -> Self {
        MicroKernel { mr: 4, nr: 8, ku: 8 }
    }
}

/// Per-step schedule knobs the executor reads instead of constants: how
/// the kernel's independent output rows fan out over the worker pool,
/// and whether/how the int8 inner loops run register-blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StepSched {
    /// Row-banding mode; `None` keeps the kernel's built-in default
    /// (contiguous for plane rows, interleaved for NHWC spatial lines).
    pub banding: Option<Banding>,
    /// Cap on the bands one kernel dispatch uses (the tuner's
    /// thread-count knob); `0` means the full pool width.
    pub max_bands: usize,
    /// Register-blocked microkernel geometry; `None` = scalar loops.
    /// Inert for fp32 anchors and anchors whose weight is not an int8
    /// constant (no panel to pre-pack).
    pub micro: Option<MicroKernel>,
}

impl Default for StepSched {
    fn default() -> Self {
        StepSched { banding: None, max_bands: 0, micro: None }
    }
}

/// A shape-specific override key: an anchor class plus the step's exact
/// output shape.  The per-shape table beats the per-class table, which
/// remains the fallback — so a records file tuned on one geometry still
/// transfers its class-level winners to unseen shapes.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeKey {
    pub class: ClassKey,
    /// The anchor step's destination shape.
    pub shape: Vec<usize>,
}

/// Compile-time schedule table: the knobs `graph::compile` resolves into
/// each emitted [`Step`].  Built by hand, from a [`crate::tune`]
/// `SchedulePlan`, or from a persisted records file.
#[derive(Debug, Clone)]
pub struct ScheduleOverrides {
    /// Widest fused packed-q-conv block accumulated on the stack; wider
    /// blocks get arena spill windows.  Clamped to [`MAX_FUSED_QCONV_CB`].
    pub max_stack_lanes: usize,
    /// Worker-pool width the spill windows are sized for.  `ArenaExec`
    /// always overwrites this with its own thread count before compiling.
    pub threads: usize,
    /// Schedule for anchor classes without an explicit entry.
    pub default_sched: StepSched,
    pub per_class: HashMap<ClassKey, StepSched>,
    /// Shape-specific overrides (exact anchor output shape); beats
    /// `per_class`, which stays the fallback for unseen shapes.
    pub per_shape: HashMap<ShapeKey, StepSched>,
}

impl Default for ScheduleOverrides {
    fn default() -> Self {
        ScheduleOverrides {
            max_stack_lanes: MAX_FUSED_QCONV_CB,
            threads: 1,
            default_sched: StepSched::default(),
            per_class: HashMap::new(),
            per_shape: HashMap::new(),
        }
    }
}

impl ScheduleOverrides {
    /// The schedule an anchor step of class `key` runs under (non-anchor
    /// steps pass `None` and get the default, which is inert for them).
    pub fn sched_for(&self, key: Option<ClassKey>) -> StepSched {
        key.and_then(|k| self.per_class.get(&k).copied())
            .unwrap_or(self.default_sched)
    }

    /// [`ScheduleOverrides::sched_for`] with per-shape resolution: an
    /// exact `(class, dst shape)` entry wins, then the class entry, then
    /// the default.  The compiler resolves every anchor step through
    /// this, so two same-class anchors of different geometry can run
    /// different schedules.
    pub fn sched_for_shape(&self, key: Option<ClassKey>, shape: &[usize]) -> StepSched {
        if let Some(k) = key {
            if !self.per_shape.is_empty() {
                let sk = ShapeKey { class: k, shape: shape.to_vec() };
                if let Some(s) = self.per_shape.get(&sk) {
                    return *s;
                }
            }
        }
        self.sched_for(key)
    }

    /// Whether this table changes anything an executor would do relative
    /// to the hard-coded defaults (thread count excluded — it only sizes
    /// spill windows).
    pub fn is_default_schedule(&self) -> bool {
        self.max_stack_lanes >= MAX_FUSED_QCONV_CB
            && self.default_sched == StepSched::default()
            && self.per_class.values().all(|s| *s == StepSched::default())
            && self.per_shape.values().all(|s| *s == StepSched::default())
    }
}

/// Per-band i32 lane-accumulator windows planned into a fused packed
/// q-conv step's scratch slot, for blocks wider than the stack bound.
/// Window `b` (for band `b < bands`) is the `band_bytes`-sized range at
/// `scratch + offset + b·band_bytes`; windows are `ARENA_ALIGN`-aligned
/// and disjoint from the quantized-input bytes at the slot's start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillSpec {
    /// Byte offset of window 0 inside the scratch slot.
    pub offset: usize,
    /// Bytes per band window (`cb · 4` rounded up to a cache line, so
    /// bands never share a line).
    pub band_bytes: usize,
    /// Number of windows — the pool width the plan was sized for.
    pub bands: usize,
}

/// Where a step operand or result lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// A byte range in the shared arena (offset is `ARENA_ALIGN`-aligned;
    /// `bytes` is the exact tensor byte length, not the rounded extent).
    Arena { offset: usize, bytes: usize },
    /// An entry in the constant pool (weights, biases).
    Const(usize),
}

/// A fused residual `Add`: where it sits in the epilogue and which side of
/// the addition the chain value is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Residual {
    /// The add executes before the fused relu (`conv→bias→add→relu`, the
    /// ResNet block tail) rather than after it (`conv→bias→relu→add`).
    pub pre_relu: bool,
    /// The chain value is the `Add`'s left operand (`chain + r`).  Float
    /// addition is only bit-commutative for non-NaN values, so the
    /// executor preserves the graph's operand order exactly.
    pub chain_lhs: bool,
}

/// Fused elementwise tail applied to an anchor's accumulator.  A step
/// whose epilogue has `residual` set carries the residual operand as its
/// third source (`srcs[2]`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Epilogue {
    /// Constant-pool index of a per-channel f32 bias (logical channel
    /// order, the same `[C]` vector every layout's `BiasAdd` reads).
    pub bias: Option<usize>,
    pub relu: bool,
    pub residual: Option<Residual>,
}

impl Epilogue {
    /// An epilogue that does nothing (the unfused anchor).
    pub fn is_identity(&self) -> bool {
        self.bias.is_none() && !self.relu && self.residual.is_none()
    }
}

/// One executable step.  Operand shapes/dtypes ride along in
/// [`Step::srcs`] / [`Step::dst_ty`].
#[derive(Debug, Clone)]
pub enum StepOp {
    /// Copy the executor's input tensor into the arena.
    LoadInput,
    /// fp32 (or standalone int8) conv in any layout; `epi` is non-identity
    /// only for a fused fp32 chain.
    Conv2d { stride: usize, padding: usize, layout: Layout, epi: Epilogue },
    /// Fused `quantize → int8 conv (i32 accum) → dequantize` in the
    /// anchor's layout, with optional bias/residual/relu epilogue.
    /// `srcs = [f32 data, i8 weight, residual?]`; the quantized input
    /// lives in the step's scratch slot for exactly this step — no int8
    /// boundary tensor survives it.
    QConv2d {
        qscale: f32,
        dqscale: f32,
        stride: usize,
        padding: usize,
        layout: Layout,
        epi: Epilogue,
    },
    /// fp32 (or standalone int8) dense; `epi` is non-identity only for the
    /// fused fp32 chain (relu / residual — dense has no bias op).
    Dense { epi: Epilogue },
    /// Fused `quantize → int8 dense (i32 accum) → dequantize [→ epilogue]`.
    QDense { qscale: f32, dqscale: f32, epi: Epilogue },
    BiasAdd { layout: Layout },
    Relu,
    Add,
    MaxPool { window: usize, stride: usize, padding: usize, layout: Layout },
    GlobalAvgPool { layout: Layout },
    Quantize { scale: f32 },
    Dequantize { scale: f32 },
    LayoutTransform { from: Layout, to: Layout },
}

impl StepOp {
    /// The epilogue of an anchor step (`None` for non-anchor steps).
    pub fn epilogue(&self) -> Option<Epilogue> {
        match self {
            StepOp::Conv2d { epi, .. }
            | StepOp::QConv2d { epi, .. }
            | StepOp::Dense { epi }
            | StepOp::QDense { epi, .. } => Some(*epi),
            _ => None,
        }
    }

    /// True when this step reads a residual operand (`srcs[2]`)
    /// elementwise while writing its destination.
    pub fn has_residual(&self) -> bool {
        self.epilogue().map_or(false, |e| e.residual.is_some())
    }

    /// The data layout of a conv anchor step (`None` for everything else);
    /// how tests assert which layouts the fused corpus actually covers.
    pub fn conv_layout(&self) -> Option<Layout> {
        match self {
            StepOp::Conv2d { layout, .. } | StepOp::QConv2d { layout, .. } => Some(*layout),
            _ => None,
        }
    }

    /// The schedule-override class of an anchor step (`None` for steps
    /// with no tunable row fan-out).
    pub fn class_key(&self) -> Option<ClassKey> {
        match self {
            StepOp::Conv2d { layout, .. } => {
                Some(ClassKey { op: AnchorOp::Conv2d, layout: Some(*layout) })
            }
            StepOp::QConv2d { layout, .. } => {
                Some(ClassKey { op: AnchorOp::QConv2d, layout: Some(*layout) })
            }
            StepOp::Dense { .. } => Some(ClassKey { op: AnchorOp::Dense, layout: None }),
            StepOp::QDense { .. } => Some(ClassKey { op: AnchorOp::QDense, layout: None }),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Step {
    pub op: StepOp,
    /// Operand locations + types, in the op's argument order.
    pub srcs: Vec<(Slot, TensorTy)>,
    /// Always an arena slot.
    pub dst: Slot,
    pub dst_ty: TensorTy,
    /// Per-step scratch arena slot (fused steps' quantized input, plus
    /// spill windows when [`Step::spill`] is set).
    pub scratch: Option<Slot>,
    /// Resolved schedule knobs for this step's row fan-out.
    pub sched: StepSched,
    /// Lane-accumulator spill windows for a fused packed q-conv whose
    /// block exceeds the stack bound.
    pub spill: Option<SpillSpec>,
    /// Index into [`CompiledGraph::packed`] when this step's int8 weight
    /// was pre-packed for a microkernel ([`StepSched::micro`]); `None`
    /// runs the scalar kernel.
    pub packed: Option<usize>,
    /// Defining IR node's name (diagnostics).
    pub name: String,
}

/// One ahead-of-time pre-packed int8 weight: the panel form of
/// [`crate::executor::microkernel::pack_weight`], built once at compile
/// time and stored beside the constant pool.  A pure permutation of
/// `consts[src]`, so warm starts re-derive it deterministically.
#[derive(Debug, Clone)]
pub struct PackedWeight {
    /// Constant-pool index of the source weight.
    pub src: usize,
    /// Anchor data layout the panels follow (`None` = dense).
    pub layout: Option<Layout>,
    /// The packed panel bytes.
    pub data: std::sync::Arc<Vec<i8>>,
}

/// The compiled program: steps + constant pool + the arena plan.
#[derive(Debug, Clone)]
pub struct CompiledGraph {
    pub steps: Vec<Step>,
    pub consts: Vec<(ConstValue, TensorTy)>,
    /// Pre-packed microkernel weight panels (possibly empty), indexed by
    /// [`Step::packed`].
    pub packed: Vec<PackedWeight>,
    /// The static plan (aligned first-fit over value lifetimes).  Verified
    /// overlap-free at compile time; `arena_bytes` is its extent.
    pub plan: StaticPlan,
    pub arena_bytes: usize,
    pub input_ty: TensorTy,
    pub output_ty: TensorTy,
    pub output_slot: Slot,
    /// Number of chains (quantized or fp32) fused away into epilogues.
    pub fused_chains: usize,
}

impl CompiledGraph {
    /// Bytes the same values would need with no lifetime reuse (the
    /// dynamic allocator's steady-state cost).
    pub fn unshared_bytes(&self) -> usize {
        self.plan.unshared_bytes
    }
}

/// A step before placement: operands as node ids, scratch as a byte count.
struct ProtoStep {
    op: StepOp,
    src_nodes: Vec<NodeId>,
    def_node: NodeId,
    scratch_bytes: usize,
    spill: Option<SpillSpec>,
    name: String,
}

/// Process-wide count of compiler invocations (every path funnels through
/// [`compile_graph_with`]).  The warm-start tests assert this stays flat
/// across cache hits — the claim "zero compiles on a hit" is counted, not
/// inferred.
static COMPILE_CALLS: AtomicU64 = AtomicU64::new(0);

/// How many times `compile_graph_with` has run in this process.
pub fn compile_calls() -> u64 {
    COMPILE_CALLS.load(Ordering::Relaxed)
}

/// Lower `g` into an arena-planned step stream under the default schedule.
/// `fuse = false` keeps every node a separate step (the "unfused arena"
/// ablation).
pub fn compile_graph(g: &Graph, fuse: bool) -> Result<CompiledGraph> {
    compile_graph_with(g, fuse, &ScheduleOverrides::default())
}

/// [`compile_graph`] with explicit schedule overrides: per-class banding
/// and band-cap knobs resolved into every step, and the packed-q-conv
/// lane-accumulator strategy (stack vs per-band arena spill windows sized
/// for `ovr.threads` bands).
pub fn compile_graph_with(
    g: &Graph,
    fuse: bool,
    ovr: &ScheduleOverrides,
) -> Result<CompiledGraph> {
    COMPILE_CALLS.fetch_add(1, Ordering::Relaxed);
    g.validate()?;
    if !g.live_set()[g.input] {
        return Err(anyhow!("compile: graph output does not depend on the input"));
    }
    // Work on the DCE'd graph so users/lifetimes ignore dead branches.
    let g = DeadCodeElim.run(g)?;
    let users = g.users();

    // Constant pool.
    let mut consts: Vec<(ConstValue, TensorTy)> = Vec::new();
    let mut const_index: HashMap<NodeId, usize> = HashMap::new();
    for node in &g.nodes {
        if let Op::Constant(c) = &node.op {
            const_index.insert(node.id, consts.len());
            consts.push((c.clone(), node.ty.clone()));
        }
    }

    // ---- Step construction (with chain fusion) ----
    let mut protos: Vec<ProtoStep> = Vec::new();
    let mut absorbed = vec![false; g.len()];
    let mut fused_chains = 0usize;

    for node in &g.nodes {
        if absorbed[node.id] || matches!(node.op, Op::Constant(_)) {
            continue;
        }
        if node.id == g.input {
            protos.push(ProtoStep {
                op: StepOp::LoadInput,
                src_nodes: vec![],
                def_node: node.id,
                scratch_bytes: 0,
                spill: None,
                name: node.name.clone(),
            });
            continue;
        }

        // Try a fused chain rooted here (quantized or fp32).
        if fuse {
            if let Some(chain) =
                try_fuse_chain(&g, &users, &absorbed, node.id, &const_index, ovr)?
            {
                for &m in &chain.members {
                    absorbed[m] = true;
                }
                fused_chains += 1;
                protos.push(chain.step);
                continue;
            }
        }

        // 1:1 lowering.
        let op = match &node.op {
            Op::Input => return Err(anyhow!("compile: multiple input nodes")),
            Op::Conv2d { stride, padding, layout } => StepOp::Conv2d {
                stride: *stride,
                padding: *padding,
                layout: *layout,
                epi: Epilogue::default(),
            },
            Op::Dense => StepOp::Dense { epi: Epilogue::default() },
            Op::BiasAdd { layout } => StepOp::BiasAdd { layout: *layout },
            Op::Relu => StepOp::Relu,
            Op::Add => StepOp::Add,
            Op::MaxPool { window, stride, padding, layout } => StepOp::MaxPool {
                window: *window,
                stride: *stride,
                padding: *padding,
                layout: *layout,
            },
            Op::GlobalAvgPool { layout } => StepOp::GlobalAvgPool { layout: *layout },
            Op::Quantize { scale } => StepOp::Quantize { scale: *scale },
            Op::Dequantize { scale } => StepOp::Dequantize { scale: *scale },
            Op::LayoutTransform { from, to } => {
                StepOp::LayoutTransform { from: *from, to: *to }
            }
            Op::Constant(_) => unreachable!("constants skipped above"),
        };
        protos.push(ProtoStep {
            op,
            src_nodes: node.inputs.clone(),
            def_node: node.id,
            scratch_bytes: 0,
            spill: None,
            name: node.name.clone(),
        });
    }

    // ---- Lifetimes over the step stream ----
    // A value is live from its defining step through the last step reading
    // it.  Residual operands of two-input epilogue steps are among the
    // step's sources, so `extend_through` keeps them live across the fused
    // step — the planner then cannot alias them with the destination.
    let mut lives: Vec<ValueLife> = Vec::new();
    let mut life_idx: HashMap<NodeId, usize> = HashMap::new();
    for (i, p) in protos.iter().enumerate() {
        let ty = &g.nodes[p.def_node].ty;
        life_idx.insert(p.def_node, lives.len());
        lives.push(ValueLife {
            name: format!("n{}", p.def_node),
            bytes: ty.byte_len(),
            def_step: i,
            last_use_step: i,
        });
        if p.scratch_bytes > 0 {
            lives.push(ValueLife {
                name: format!("s{i}"),
                bytes: p.scratch_bytes,
                def_step: i,
                last_use_step: i,
            });
        }
    }
    for (i, p) in protos.iter().enumerate() {
        for &s in &p.src_nodes {
            if let Some(&li) = life_idx.get(&s) {
                lives[li].extend_through(i);
            }
        }
    }
    // The output value survives past the last step.
    let out_life = *life_idx
        .get(&g.output)
        .ok_or_else(|| anyhow!("compile: output is not materialized by any step"))?;
    lives[out_life].extend_through(protos.len());

    let plan = StaticPlan::first_fit_aligned(&lives, ARENA_ALIGN);
    plan.verify().map_err(|e| anyhow!("arena plan invalid: {e}"))?;
    let offsets = plan.offset_index();
    let arena_bytes = plan.arena_bytes;

    let arena_slot = |id: NodeId| -> Result<Slot> {
        let (off, _) = offsets
            .get(&format!("n{id}"))
            .ok_or_else(|| anyhow!("node {id} missing from arena plan"))?;
        Ok(Slot::Arena { offset: *off, bytes: g.nodes[id].ty.byte_len() })
    };
    let resolve = |id: NodeId| -> Result<(Slot, TensorTy)> {
        let slot = match const_index.get(&id) {
            Some(&ci) => Slot::Const(ci),
            None => arena_slot(id)?,
        };
        Ok((slot, g.nodes[id].ty.clone()))
    };

    // ---- Materialize placed steps ----
    let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
    for (i, p) in protos.into_iter().enumerate() {
        let srcs = p
            .src_nodes
            .iter()
            .map(|&s| resolve(s))
            .collect::<Result<Vec<_>>>()?;
        let scratch = if p.scratch_bytes > 0 {
            let (off, _) = offsets
                .get(&format!("s{i}"))
                .ok_or_else(|| anyhow!("step {i} scratch missing from plan"))?;
            Some(Slot::Arena { offset: *off, bytes: p.scratch_bytes })
        } else {
            None
        };
        let sched =
            ovr.sched_for_shape(p.op.class_key(), &g.nodes[p.def_node].ty.shape);
        steps.push(Step {
            op: p.op,
            srcs,
            dst: arena_slot(p.def_node)?,
            dst_ty: g.nodes[p.def_node].ty.clone(),
            scratch,
            sched,
            spill: p.spill,
            packed: None,
            name: p.name,
        });
    }

    // ---- AOT weight pre-packing (microkernel panels) ----
    // An anchor step whose schedule asks for a microkernel and whose
    // weight is an int8 constant gets its weight packed once, here, into
    // the per-output-lane panel form the register-blocked kernels read.
    // Steps sharing a weight share one panel.  fp32 anchors and
    // non-constant weights fall through with `packed = None` (the micro
    // knob is inert for them — the executor runs the scalar kernel).
    let mut packed: Vec<PackedWeight> = Vec::new();
    let mut packed_by: HashMap<(usize, Option<Layout>), usize> = HashMap::new();
    for step in &mut steps {
        if step.sched.micro.is_none() || step.op.class_key().is_none() {
            continue;
        }
        let Some(&(Slot::Const(ci), ref wt)) = step.srcs.get(1) else {
            continue;
        };
        if wt.dtype != IrDType::S8 {
            continue;
        }
        let layout = step.op.conv_layout();
        let pi = match packed_by.get(&(ci, layout)) {
            Some(&pi) => pi,
            None => {
                let ConstValue::I8(w) = &consts[ci].0 else {
                    return Err(anyhow!(
                        "step '{}': int8 weight const {ci} holds a non-i8 payload",
                        step.name
                    ));
                };
                let data =
                    crate::executor::microkernel::pack_weight(layout, w, &wt.shape);
                packed.push(PackedWeight {
                    src: ci,
                    layout,
                    data: std::sync::Arc::new(data),
                });
                packed_by.insert((ci, layout), packed.len() - 1);
                packed.len() - 1
            }
        };
        step.packed = Some(pi);
    }

    // Defense in depth: a two-input epilogue step reads its residual
    // operand elementwise while writing its destination; re-verify the
    // plan kept the two byte ranges disjoint.
    for step in &steps {
        if !step.op.has_residual() {
            continue;
        }
        if let (Slot::Arena { offset: ro, bytes: rb }, Slot::Arena { offset: d, bytes: db }) =
            (step.srcs[2].0, step.dst)
        {
            if ro < d + db && d < ro + rb {
                return Err(anyhow!(
                    "step '{}': residual operand [{ro}+{rb}] aliases destination [{d}+{db}]",
                    step.name
                ));
            }
        }
    }

    let output_slot = arena_slot(g.output)?;
    Ok(CompiledGraph {
        steps,
        consts,
        packed,
        plan,
        arena_bytes,
        input_ty: g.nodes[g.input].ty.clone(),
        output_ty: g.nodes[g.output].ty.clone(),
        output_slot,
        fused_chains,
    })
}

/// A matched chain: the fused step plus every absorbed node id.
struct FusedChain {
    step: ProtoStep,
    members: Vec<NodeId>,
}

/// Match a fusable chain rooted at `start`: either `quantize → anchor(i8
/// const weight) → dequantize [→ tail]` (rooted at the `Quantize`) or an
/// f32 `anchor [→ tail]` (rooted at the anchor itself; only fused when the
/// tail absorbs at least one op).  The shared tail grammar is
/// `[bias] [add] [relu] [add]` with at most one residual add — see the
/// module docs for the full rules.
fn try_fuse_chain(
    g: &Graph,
    users: &[Vec<NodeId>],
    absorbed: &[bool],
    start: NodeId,
    const_index: &HashMap<NodeId, usize>,
    ovr: &ScheduleOverrides,
) -> Result<Option<FusedChain>> {
    // A node may be absorbed into a chain only if its value has exactly
    // one consumer (the next link), is not the graph output, and was not
    // claimed by an earlier chain.
    let absorbable = |id: NodeId| users[id].len() == 1 && id != g.output && !absorbed[id];

    // Resolve the anchor: `start` itself (fp32 chain) or the single user
    // of a starting Quantize (quantized chain).
    let node = &g.nodes[start];
    let (qscale, anchor_id) = match node.op {
        Op::Quantize { scale } => {
            if !absorbable(start) {
                return Ok(None);
            }
            (Some(scale), users[start][0])
        }
        Op::Conv2d { .. } | Op::Dense if node.ty.dtype == IrDType::F32 => (None, start),
        _ => return Ok(None),
    };
    if absorbed[anchor_id] {
        return Ok(None);
    }
    let anchor = &g.nodes[anchor_id];
    let (is_conv, stride, padding, conv_layout) = match anchor.op {
        Op::Conv2d { stride, padding, layout } => (true, stride, padding, Some(layout)),
        Op::Dense => (false, 0, 0, None),
        _ => return Ok(None),
    };
    if anchor.inputs.len() != 2 {
        return Ok(None);
    }
    let wid = anchor.inputs[1];

    let mut members: Vec<NodeId> = Vec::new();
    let mut tail;
    let mut dqscale = 0f32;
    if qscale.is_some() {
        // The quantized value must be the anchor's *data* operand and the
        // weight must be a pre-quantized i8 constant.
        if anchor.inputs[0] != start {
            return Ok(None);
        }
        if g.nodes[wid].ty.dtype != IrDType::S8 || !const_index.contains_key(&wid) {
            return Ok(None);
        }
        if !absorbable(anchor_id) {
            return Ok(None);
        }
        let dq_id = users[anchor_id][0];
        match g.nodes[dq_id].op {
            Op::Dequantize { scale } if !absorbed[dq_id] => dqscale = scale,
            _ => return Ok(None),
        }
        members.extend([start, anchor_id, dq_id]);
        tail = dq_id;
    } else {
        members.push(anchor_id);
        tail = anchor_id;
    }

    // ---- Shared epilogue tail: [bias] [add] [relu] [add] ----
    let mut epi = Epilogue::default();
    let mut residual_src: Option<NodeId> = None;

    // Per-channel f32 constant bias (conv only: BiasAdd needs an image
    // rank), and only in the anchor's own layout — a mismatched BiasAdd
    // layout would misindex the channel and is left as a 1:1 step.
    if is_conv && absorbable(tail) {
        let cand = users[tail][0];
        if matches!(g.nodes[cand].op, Op::BiasAdd { layout } if Some(layout) == conv_layout) {
            let b = g.nodes[cand].inputs[1];
            if !absorbed[cand]
                && g.nodes[cand].inputs[0] == tail
                && g.nodes[b].ty.dtype == IrDType::F32
            {
                if let Some(&bci) = const_index.get(&b) {
                    epi.bias = Some(bci);
                    members.push(cand);
                    tail = cand;
                }
            }
        }
    }
    // Residual add before the relu (ResNet block tail).
    if let Some((cand, r, chain_lhs)) = match_residual(g, users, &absorbable, absorbed, tail, start)
    {
        epi.residual = Some(Residual { pre_relu: true, chain_lhs });
        residual_src = Some(r);
        members.push(cand);
        tail = cand;
    }
    // Relu.
    if absorbable(tail) {
        let cand = users[tail][0];
        if matches!(g.nodes[cand].op, Op::Relu) && !absorbed[cand] {
            epi.relu = true;
            members.push(cand);
            tail = cand;
        }
    }
    // Residual add after the relu (only if the pre-relu slot is empty).
    if epi.residual.is_none() {
        if let Some((cand, r, chain_lhs)) =
            match_residual(g, users, &absorbable, absorbed, tail, start)
        {
            epi.residual = Some(Residual { pre_relu: false, chain_lhs });
            residual_src = Some(r);
            members.push(cand);
            tail = cand;
        }
    }

    let (op, data_id, scratch_bytes, spill) = match qscale {
        Some(qs) => {
            let op = if is_conv {
                let layout = conv_layout.expect("conv anchor carries a layout");
                StepOp::QConv2d { qscale: qs, dqscale, stride, padding, layout, epi }
            } else {
                StepOp::QDense { qscale: qs, dqscale, epi }
            };
            // Scratch holds the quantized (i8) input for exactly this
            // step — plus, for a packed conv whose block exceeds the
            // stack bound, one aligned i32 lane-accumulator window per
            // worker band (the heap-backed fallback lives in the arena,
            // so serving still allocates nothing).
            let qbytes = g.nodes[start].ty.byte_len();
            let stack_bound = ovr.max_stack_lanes.min(MAX_FUSED_QCONV_CB).max(1);
            let spill = match op {
                StepOp::QConv2d { layout: Layout::Nchwc(cb), .. } if cb > stack_bound => {
                    let offset = round_up(qbytes, ARENA_ALIGN);
                    let band_bytes = round_up(cb * 4, ARENA_ALIGN);
                    Some(SpillSpec { offset, band_bytes, bands: ovr.threads.max(1) })
                }
                _ => None,
            };
            let scratch_bytes = match spill {
                Some(sp) => sp.offset + sp.bands * sp.band_bytes,
                None => qbytes,
            };
            (op, g.nodes[start].inputs[0], scratch_bytes, spill)
        }
        None => {
            // An fp32 anchor with an empty tail is already its own fused
            // form — leave it to 1:1 lowering.
            if members.len() == 1 {
                return Ok(None);
            }
            let op = if is_conv {
                let layout = conv_layout.expect("conv anchor carries a layout");
                StepOp::Conv2d { stride, padding, layout, epi }
            } else {
                StepOp::Dense { epi }
            };
            (op, anchor.inputs[0], 0, None)
        }
    };

    let mut src_nodes = vec![data_id, wid];
    if let Some(r) = residual_src {
        src_nodes.push(r);
    }
    Ok(Some(FusedChain {
        step: ProtoStep {
            op,
            src_nodes,
            def_node: tail,
            scratch_bytes,
            spill,
            name: format!("{}+fused", anchor.name),
        },
        members,
    }))
}

/// Match a residual `Add` hanging off `tail`.  Returns `(add node, other
/// operand, chain_lhs)`.  The other operand must already be materialized
/// when the fused step executes: a constant, or a node with an id below
/// the chain's `start` (steps are emitted in node-id order of their first
/// member, so a smaller id guarantees an earlier step — including when the
/// operand is itself the tail of an earlier fused chain).
fn match_residual(
    g: &Graph,
    users: &[Vec<NodeId>],
    absorbable: &impl Fn(NodeId) -> bool,
    absorbed: &[bool],
    tail: NodeId,
    start: NodeId,
) -> Option<(NodeId, NodeId, bool)> {
    if !absorbable(tail) {
        return None;
    }
    let cand = users[tail][0];
    let n = &g.nodes[cand];
    if absorbed[cand] || !matches!(n.op, Op::Add) || n.ty.dtype != IrDType::F32 {
        return None;
    }
    let r = n.other_input(tail)?;
    if r < start || matches!(g.nodes[r].op, Op::Constant(_)) {
        Some((cand, r, n.inputs[0] == tail))
    } else {
        None
    }
}
