//! Graph → arena-planned instruction stream: the lowering step behind the
//! [`crate::executor::ArenaExec`] tier.
//!
//! TVM's graph executor wins over the relay VM for two mechanistic reasons
//! the paper isolates: **fusion** (q/dq boundary operators disappear into
//! their anchor's epilogue instead of materializing int8/fp32 boundary
//! tensors) and **static memory planning** (every intermediate gets a
//! pre-computed offset into one shared arena, so serving an inference does
//! zero dynamic allocation).  This module reproduces both at the IR level:
//!
//! 1. `Quantize → Conv2d/Dense(i8, i32 accum) → Dequantize [→ BiasAdd]
//!    [→ Relu]` chains collapse into one fused step whose interior values
//!    (the i32 accumulator, the dequantized f32, the biased f32) never
//!    exist in memory;
//! 2. remaining nodes lower 1:1 to steps, and every step output gets a
//!    [`crate::memplan::StaticPlan`] first-fit placement computed from
//!    graph-IR value lifetimes (def step → last consuming step).
//!
//! The semantics contract: executing the stream is **bit-for-bit** equal to
//! [`super::interp::evaluate`] — fused epilogues apply exactly the same
//! per-element float operation sequence the unfused ops would (dequantize
//! multiply, then bias add, then relu max), and integer accumulation is
//! order-independent.  The differential tests enforce this.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::ir::{ConstValue, Graph, IrDType, Layout, NodeId, Op, TensorTy};
use super::passes::{DeadCodeElim, Pass};
use crate::memplan::{StaticPlan, ValueLife};

/// Arena placement alignment: cache-line sized, so typed reinterpretation
/// is always element-aligned and parallel writers don't share lines.
pub const ARENA_ALIGN: usize = 64;

/// Where a step operand or result lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// A byte range in the shared arena (offset is `ARENA_ALIGN`-aligned;
    /// `bytes` is the exact tensor byte length, not the rounded extent).
    Arena { offset: usize, bytes: usize },
    /// An entry in the constant pool (weights, biases).
    Const(usize),
}

/// Fused elementwise tail applied to an anchor's accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Epilogue {
    /// Constant-pool index of a per-channel f32 bias (NCHW channel order).
    pub bias: Option<usize>,
    pub relu: bool,
}

/// One executable step.  Operand shapes/dtypes ride along in
/// [`Step::srcs`] / [`Step::dst_ty`].
#[derive(Debug, Clone)]
pub enum StepOp {
    /// Copy the executor's input tensor into the arena.
    LoadInput,
    Conv2d { stride: usize, padding: usize, layout: Layout },
    /// Fused `quantize → int8 NCHW conv (i32 accum) → dequantize` with
    /// optional bias/relu epilogue.  `srcs = [f32 data, i8 weight]`; the
    /// quantized input lives in the step's scratch slot for exactly this
    /// step — no int8 boundary tensor survives it.
    QConv2d { qscale: f32, dqscale: f32, stride: usize, padding: usize, epi: Epilogue },
    Dense,
    /// Fused `quantize → int8 dense (i32 accum) → dequantize [→ relu]`.
    QDense { qscale: f32, dqscale: f32, epi: Epilogue },
    BiasAdd { layout: Layout },
    Relu,
    Add,
    MaxPool { window: usize, stride: usize, padding: usize, layout: Layout },
    GlobalAvgPool { layout: Layout },
    Quantize { scale: f32 },
    Dequantize { scale: f32 },
    LayoutTransform { from: Layout, to: Layout },
}

#[derive(Debug, Clone)]
pub struct Step {
    pub op: StepOp,
    /// Operand locations + types, in the op's argument order.
    pub srcs: Vec<(Slot, TensorTy)>,
    /// Always an arena slot.
    pub dst: Slot,
    pub dst_ty: TensorTy,
    /// Per-step scratch arena slot (fused steps' quantized input).
    pub scratch: Option<Slot>,
    /// Defining IR node's name (diagnostics).
    pub name: String,
}

/// The compiled program: steps + constant pool + the arena plan.
#[derive(Debug, Clone)]
pub struct CompiledGraph {
    pub steps: Vec<Step>,
    pub consts: Vec<(ConstValue, TensorTy)>,
    /// The static plan (aligned first-fit over value lifetimes).  Verified
    /// overlap-free at compile time; `arena_bytes` is its extent.
    pub plan: StaticPlan,
    pub arena_bytes: usize,
    pub input_ty: TensorTy,
    pub output_ty: TensorTy,
    pub output_slot: Slot,
    /// Number of q→anchor→dq chains fused away.
    pub fused_chains: usize,
}

impl CompiledGraph {
    /// Bytes the same values would need with no lifetime reuse (the
    /// dynamic allocator's steady-state cost).
    pub fn unshared_bytes(&self) -> usize {
        self.plan.unshared_bytes
    }
}

/// A step before placement: operands as node ids, scratch as a byte count.
struct ProtoStep {
    op: StepOp,
    src_nodes: Vec<NodeId>,
    def_node: NodeId,
    scratch_bytes: usize,
    name: String,
}

/// Lower `g` into an arena-planned step stream.  `fuse_qdq = false` keeps
/// every node a separate step (the "unfused arena" ablation).
pub fn compile_graph(g: &Graph, fuse_qdq: bool) -> Result<CompiledGraph> {
    g.validate()?;
    if !g.live_set()[g.input] {
        return Err(anyhow!("compile: graph output does not depend on the input"));
    }
    // Work on the DCE'd graph so users/lifetimes ignore dead branches.
    let g = DeadCodeElim.run(g)?;
    let users = g.users();

    // Constant pool.
    let mut consts: Vec<(ConstValue, TensorTy)> = Vec::new();
    let mut const_index: HashMap<NodeId, usize> = HashMap::new();
    for node in &g.nodes {
        if let Op::Constant(c) = &node.op {
            const_index.insert(node.id, consts.len());
            consts.push((c.clone(), node.ty.clone()));
        }
    }

    // ---- Step construction (with q→anchor→dq chain fusion) ----
    let mut protos: Vec<ProtoStep> = Vec::new();
    let mut absorbed = vec![false; g.len()];
    let mut fused_chains = 0usize;

    // A node may be absorbed into a chain only if its value has exactly one
    // consumer (the next chain link) and is not the graph output.
    let absorbable = |id: NodeId| users[id].len() == 1 && id != g.output;

    for node in &g.nodes {
        if absorbed[node.id] || matches!(node.op, Op::Constant(_)) {
            continue;
        }
        if node.id == g.input {
            protos.push(ProtoStep {
                op: StepOp::LoadInput,
                src_nodes: vec![],
                def_node: node.id,
                scratch_bytes: 0,
                name: node.name.clone(),
            });
            continue;
        }

        // Try the fused chain starting at a Quantize node.
        if fuse_qdq {
            if let Op::Quantize { scale: qscale } = node.op {
                if let Some(proto) = try_fuse_chain(&g, &users, node.id, qscale, &const_index, absorbable)? {
                    for &m in &proto.members {
                        absorbed[m] = true;
                    }
                    fused_chains += 1;
                    protos.push(proto.step);
                    continue;
                }
            }
        }

        // 1:1 lowering.
        let op = match &node.op {
            Op::Input => return Err(anyhow!("compile: multiple input nodes")),
            Op::Conv2d { stride, padding, layout } => {
                StepOp::Conv2d { stride: *stride, padding: *padding, layout: *layout }
            }
            Op::Dense => StepOp::Dense,
            Op::BiasAdd { layout } => StepOp::BiasAdd { layout: *layout },
            Op::Relu => StepOp::Relu,
            Op::Add => StepOp::Add,
            Op::MaxPool { window, stride, padding, layout } => StepOp::MaxPool {
                window: *window,
                stride: *stride,
                padding: *padding,
                layout: *layout,
            },
            Op::GlobalAvgPool { layout } => StepOp::GlobalAvgPool { layout: *layout },
            Op::Quantize { scale } => StepOp::Quantize { scale: *scale },
            Op::Dequantize { scale } => StepOp::Dequantize { scale: *scale },
            Op::LayoutTransform { from, to } => {
                StepOp::LayoutTransform { from: *from, to: *to }
            }
            Op::Constant(_) => unreachable!("constants skipped above"),
        };
        protos.push(ProtoStep {
            op,
            src_nodes: node.inputs.clone(),
            def_node: node.id,
            scratch_bytes: 0,
            name: node.name.clone(),
        });
    }

    // ---- Lifetimes over the step stream ----
    // A value's def step is its proto's position; its last use is the last
    // step consuming it (the output survives past the end).
    let mut last_use: HashMap<NodeId, usize> = HashMap::new();
    for (i, p) in protos.iter().enumerate() {
        for &s in &p.src_nodes {
            if !const_index.contains_key(&s) {
                let e = last_use.entry(s).or_insert(i);
                *e = (*e).max(i);
            }
        }
    }
    // The output value survives past the last step.
    last_use.insert(g.output, protos.len());

    let mut lives: Vec<ValueLife> = Vec::new();
    for (i, p) in protos.iter().enumerate() {
        let ty = &g.nodes[p.def_node].ty;
        lives.push(ValueLife {
            name: format!("n{}", p.def_node),
            bytes: ty.byte_len(),
            def_step: i,
            last_use_step: *last_use.get(&p.def_node).unwrap_or(&i),
        });
        if p.scratch_bytes > 0 {
            lives.push(ValueLife {
                name: format!("s{i}"),
                bytes: p.scratch_bytes,
                def_step: i,
                last_use_step: i,
            });
        }
    }

    let plan = StaticPlan::first_fit_aligned(&lives, ARENA_ALIGN);
    plan.verify().map_err(|e| anyhow!("arena plan invalid: {e}"))?;
    let offsets = plan.offset_index();
    let arena_bytes = plan.arena_bytes;

    let arena_slot = |id: NodeId| -> Result<Slot> {
        let (off, _) = offsets
            .get(&format!("n{id}"))
            .ok_or_else(|| anyhow!("node {id} missing from arena plan"))?;
        Ok(Slot::Arena { offset: *off, bytes: g.nodes[id].ty.byte_len() })
    };
    let resolve = |id: NodeId| -> Result<(Slot, TensorTy)> {
        let slot = match const_index.get(&id) {
            Some(&ci) => Slot::Const(ci),
            None => arena_slot(id)?,
        };
        Ok((slot, g.nodes[id].ty.clone()))
    };

    // ---- Materialize placed steps ----
    let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
    for (i, p) in protos.into_iter().enumerate() {
        let srcs = p
            .src_nodes
            .iter()
            .map(|&s| resolve(s))
            .collect::<Result<Vec<_>>>()?;
        let scratch = if p.scratch_bytes > 0 {
            let (off, _) = offsets
                .get(&format!("s{i}"))
                .ok_or_else(|| anyhow!("step {i} scratch missing from plan"))?;
            Some(Slot::Arena { offset: *off, bytes: p.scratch_bytes })
        } else {
            None
        };
        steps.push(Step {
            op: p.op,
            srcs,
            dst: arena_slot(p.def_node)?,
            dst_ty: g.nodes[p.def_node].ty.clone(),
            scratch,
            name: p.name,
        });
    }

    let output_slot = arena_slot(g.output)?;
    Ok(CompiledGraph {
        steps,
        consts,
        plan,
        arena_bytes,
        input_ty: g.nodes[g.input].ty.clone(),
        output_ty: g.nodes[g.output].ty.clone(),
        output_slot,
        fused_chains,
    })
}

/// A matched chain: the fused step plus every absorbed node id.
struct FusedChain {
    step: ProtoStep,
    members: Vec<NodeId>,
}

/// Match `q → conv/dense(i8 const weight) → dq [→ bias] [→ relu]` rooted at
/// the quantize node `qid`.  Every interior link must be single-consumer
/// and not the graph output (the closure `absorbable` checks both).
fn try_fuse_chain(
    g: &Graph,
    users: &[Vec<NodeId>],
    qid: NodeId,
    qscale: f32,
    const_index: &HashMap<NodeId, usize>,
    absorbable: impl Fn(NodeId) -> bool,
) -> Result<Option<FusedChain>> {
    if !absorbable(qid) {
        return Ok(None);
    }
    let anchor_id = users[qid][0];
    let anchor = &g.nodes[anchor_id];
    // The quantized value must be the anchor's *data* operand and the
    // weight must be a pre-quantized i8 constant.
    let (is_conv, stride, padding) = match anchor.op {
        Op::Conv2d { stride, padding, layout: Layout::Nchw } => (true, stride, padding),
        Op::Dense => (false, 0, 0),
        _ => return Ok(None),
    };
    if anchor.inputs.len() != 2 || anchor.inputs[0] != qid {
        return Ok(None);
    }
    let wid = anchor.inputs[1];
    if g.nodes[wid].ty.dtype != IrDType::S8 || !const_index.contains_key(&wid) {
        return Ok(None);
    }
    if !absorbable(anchor_id) {
        return Ok(None);
    }
    let dq_id = users[anchor_id][0];
    let dqscale = match g.nodes[dq_id].op {
        Op::Dequantize { scale } => scale,
        _ => return Ok(None),
    };

    // Greedily absorb the elementwise tail.
    let mut members = vec![qid, anchor_id, dq_id];
    let mut tail = dq_id;
    let mut epi = Epilogue::default();
    if is_conv && absorbable(tail) {
        let cand = users[tail][0];
        if let Op::BiasAdd { layout: Layout::Nchw } = g.nodes[cand].op {
            if g.nodes[cand].inputs[0] == tail {
                if let Some(&bci) = const_index.get(&g.nodes[cand].inputs[1]) {
                    if g.nodes[g.nodes[cand].inputs[1]].ty.dtype == IrDType::F32 {
                        epi.bias = Some(bci);
                        members.push(cand);
                        tail = cand;
                    }
                }
            }
        }
    }
    if absorbable(tail) {
        let cand = users[tail][0];
        if matches!(g.nodes[cand].op, Op::Relu) {
            epi.relu = true;
            members.push(cand);
            tail = cand;
        }
    }

    let op = if is_conv {
        StepOp::QConv2d { qscale, dqscale, stride, padding, epi }
    } else {
        StepOp::QDense { qscale, dqscale, epi }
    };
    let data_id = g.nodes[qid].inputs[0];
    Ok(Some(FusedChain {
        step: ProtoStep {
            op,
            src_nodes: vec![data_id, wid],
            def_node: tail,
            scratch_bytes: g.nodes[qid].ty.byte_len(),
            name: format!("{}+fused", anchor.name),
        },
        members,
    }))
}
