//! Graph → arena-planned instruction stream: the lowering step behind the
//! [`crate::executor::ArenaExec`] tier.
//!
//! TVM's graph executor wins over the relay VM for two mechanistic reasons
//! the paper isolates: **fusion** (boundary and elementwise operators
//! disappear into their anchor's epilogue instead of materializing
//! intermediate tensors) and **static memory planning** (every intermediate
//! gets a pre-computed offset into one shared arena, so serving an
//! inference does zero dynamic allocation).  This module reproduces both at
//! the IR level.
//!
//! # Fusion rules
//!
//! A fused step is an *anchor* (`Conv2d` in any layout — NCHW, NHWC, or
//! NCHW{c} — or `Dense`; the dense anchor has no layout) plus an epilogue
//! tail applied per output element.  Two chain shapes fuse:
//!
//! 1. **Quantized** (the `fuse` ablation flag controls all fusion):
//!    `Quantize → Conv2d/Dense(i8 const weight, i32 accum) → Dequantize`
//!    followed by the shared epilogue tail.  The quantized input lives in a
//!    per-step scratch slot; the i32 accumulator and every interior f32
//!    value never exist in memory.
//! 2. **fp32**: a `Conv2d`/`Dense` whose output is f32, followed by at
//!    least one epilogue op (an anchor with nothing to absorb stays a plain
//!    1:1 step).
//!
//! The shared epilogue tail is, in order:
//! `[BiasAdd(f32 const, conv only, same layout as the anchor)] → [Add] →
//! [Relu] → [Add]` — at most one
//! residual `Add`, either before the relu (the ResNet block tail
//! `conv→bias→add→relu`) or after it.  A residual `Add` fuses only when its
//! other operand is already materialized when the fused step runs: a
//! constant, or a node defined *before* the chain's first member (steps are
//! emitted in node order, so earlier ids mean earlier steps).  The residual
//! operand becomes the step's third source and its lifetime is explicitly
//! extended through the fused step
//! ([`crate::memplan::ValueLife::extend_through`]), which forces the
//! planner to keep it space-disjoint from the step's destination — a
//! compile-time check re-verifies that disjointness on every two-input
//! step.  Every interior chain link must be single-consumer and not the
//! graph output.
//!
//! Integer elementwise tails do not fuse (fused chains always end in f32:
//! a dequantized quantized chain or an f32 anchor).  One width limit: a
//! *quantized* NCHW{c} chain fuses only while its channel block fits the
//! executor's stack-resident lane accumulator
//! ([`MAX_FUSED_QCONV_CB`]); wider blocks keep their q/dq chain as 1:1
//! steps, which stay bit-identical, just slower.
//!
//! The semantics contract: executing the stream is **bit-for-bit** equal to
//! [`super::interp::evaluate`] — fused epilogues apply exactly the same
//! per-element float operation sequence the unfused ops would (dequantize
//! multiply, then bias add, then the adds/relu in graph order, preserving
//! `Add` operand order, which is observable for NaN), and integer
//! accumulation is order-independent.  The differential tests and the
//! `tests/graph_fuzz.rs` randomized harness enforce this.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::ir::{ConstValue, Graph, IrDType, Layout, NodeId, Op, TensorTy};
use super::passes::{DeadCodeElim, Pass};
use crate::memplan::{StaticPlan, ValueLife};

/// Arena placement alignment: cache-line sized, so typed reinterpretation
/// is always element-aligned and parallel writers don't share lines.
pub const ARENA_ALIGN: usize = 64;

/// Widest channel block a *fused* quantized NCHW{c} conv supports: the
/// executor keeps the per-pixel i32 lane accumulator on the stack (serving
/// allocates nothing), so the block width is bounded here at compile time.
/// Chains with a wider block simply stay unfused 1:1 steps.
pub const MAX_FUSED_QCONV_CB: usize = 64;

/// Where a step operand or result lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// A byte range in the shared arena (offset is `ARENA_ALIGN`-aligned;
    /// `bytes` is the exact tensor byte length, not the rounded extent).
    Arena { offset: usize, bytes: usize },
    /// An entry in the constant pool (weights, biases).
    Const(usize),
}

/// A fused residual `Add`: where it sits in the epilogue and which side of
/// the addition the chain value is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Residual {
    /// The add executes before the fused relu (`conv→bias→add→relu`, the
    /// ResNet block tail) rather than after it (`conv→bias→relu→add`).
    pub pre_relu: bool,
    /// The chain value is the `Add`'s left operand (`chain + r`).  Float
    /// addition is only bit-commutative for non-NaN values, so the
    /// executor preserves the graph's operand order exactly.
    pub chain_lhs: bool,
}

/// Fused elementwise tail applied to an anchor's accumulator.  A step
/// whose epilogue has `residual` set carries the residual operand as its
/// third source (`srcs[2]`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Epilogue {
    /// Constant-pool index of a per-channel f32 bias (logical channel
    /// order, the same `[C]` vector every layout's `BiasAdd` reads).
    pub bias: Option<usize>,
    pub relu: bool,
    pub residual: Option<Residual>,
}

impl Epilogue {
    /// An epilogue that does nothing (the unfused anchor).
    pub fn is_identity(&self) -> bool {
        self.bias.is_none() && !self.relu && self.residual.is_none()
    }
}

/// One executable step.  Operand shapes/dtypes ride along in
/// [`Step::srcs`] / [`Step::dst_ty`].
#[derive(Debug, Clone)]
pub enum StepOp {
    /// Copy the executor's input tensor into the arena.
    LoadInput,
    /// fp32 (or standalone int8) conv in any layout; `epi` is non-identity
    /// only for a fused fp32 chain.
    Conv2d { stride: usize, padding: usize, layout: Layout, epi: Epilogue },
    /// Fused `quantize → int8 conv (i32 accum) → dequantize` in the
    /// anchor's layout, with optional bias/residual/relu epilogue.
    /// `srcs = [f32 data, i8 weight, residual?]`; the quantized input
    /// lives in the step's scratch slot for exactly this step — no int8
    /// boundary tensor survives it.
    QConv2d {
        qscale: f32,
        dqscale: f32,
        stride: usize,
        padding: usize,
        layout: Layout,
        epi: Epilogue,
    },
    /// fp32 (or standalone int8) dense; `epi` is non-identity only for the
    /// fused fp32 chain (relu / residual — dense has no bias op).
    Dense { epi: Epilogue },
    /// Fused `quantize → int8 dense (i32 accum) → dequantize [→ epilogue]`.
    QDense { qscale: f32, dqscale: f32, epi: Epilogue },
    BiasAdd { layout: Layout },
    Relu,
    Add,
    MaxPool { window: usize, stride: usize, padding: usize, layout: Layout },
    GlobalAvgPool { layout: Layout },
    Quantize { scale: f32 },
    Dequantize { scale: f32 },
    LayoutTransform { from: Layout, to: Layout },
}

impl StepOp {
    /// The epilogue of an anchor step (`None` for non-anchor steps).
    pub fn epilogue(&self) -> Option<Epilogue> {
        match self {
            StepOp::Conv2d { epi, .. }
            | StepOp::QConv2d { epi, .. }
            | StepOp::Dense { epi }
            | StepOp::QDense { epi, .. } => Some(*epi),
            _ => None,
        }
    }

    /// True when this step reads a residual operand (`srcs[2]`)
    /// elementwise while writing its destination.
    pub fn has_residual(&self) -> bool {
        self.epilogue().map_or(false, |e| e.residual.is_some())
    }

    /// The data layout of a conv anchor step (`None` for everything else);
    /// how tests assert which layouts the fused corpus actually covers.
    pub fn conv_layout(&self) -> Option<Layout> {
        match self {
            StepOp::Conv2d { layout, .. } | StepOp::QConv2d { layout, .. } => Some(*layout),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Step {
    pub op: StepOp,
    /// Operand locations + types, in the op's argument order.
    pub srcs: Vec<(Slot, TensorTy)>,
    /// Always an arena slot.
    pub dst: Slot,
    pub dst_ty: TensorTy,
    /// Per-step scratch arena slot (fused steps' quantized input).
    pub scratch: Option<Slot>,
    /// Defining IR node's name (diagnostics).
    pub name: String,
}

/// The compiled program: steps + constant pool + the arena plan.
#[derive(Debug, Clone)]
pub struct CompiledGraph {
    pub steps: Vec<Step>,
    pub consts: Vec<(ConstValue, TensorTy)>,
    /// The static plan (aligned first-fit over value lifetimes).  Verified
    /// overlap-free at compile time; `arena_bytes` is its extent.
    pub plan: StaticPlan,
    pub arena_bytes: usize,
    pub input_ty: TensorTy,
    pub output_ty: TensorTy,
    pub output_slot: Slot,
    /// Number of chains (quantized or fp32) fused away into epilogues.
    pub fused_chains: usize,
}

impl CompiledGraph {
    /// Bytes the same values would need with no lifetime reuse (the
    /// dynamic allocator's steady-state cost).
    pub fn unshared_bytes(&self) -> usize {
        self.plan.unshared_bytes
    }
}

/// A step before placement: operands as node ids, scratch as a byte count.
struct ProtoStep {
    op: StepOp,
    src_nodes: Vec<NodeId>,
    def_node: NodeId,
    scratch_bytes: usize,
    name: String,
}

/// Lower `g` into an arena-planned step stream.  `fuse = false` keeps
/// every node a separate step (the "unfused arena" ablation).
pub fn compile_graph(g: &Graph, fuse: bool) -> Result<CompiledGraph> {
    g.validate()?;
    if !g.live_set()[g.input] {
        return Err(anyhow!("compile: graph output does not depend on the input"));
    }
    // Work on the DCE'd graph so users/lifetimes ignore dead branches.
    let g = DeadCodeElim.run(g)?;
    let users = g.users();

    // Constant pool.
    let mut consts: Vec<(ConstValue, TensorTy)> = Vec::new();
    let mut const_index: HashMap<NodeId, usize> = HashMap::new();
    for node in &g.nodes {
        if let Op::Constant(c) = &node.op {
            const_index.insert(node.id, consts.len());
            consts.push((c.clone(), node.ty.clone()));
        }
    }

    // ---- Step construction (with chain fusion) ----
    let mut protos: Vec<ProtoStep> = Vec::new();
    let mut absorbed = vec![false; g.len()];
    let mut fused_chains = 0usize;

    for node in &g.nodes {
        if absorbed[node.id] || matches!(node.op, Op::Constant(_)) {
            continue;
        }
        if node.id == g.input {
            protos.push(ProtoStep {
                op: StepOp::LoadInput,
                src_nodes: vec![],
                def_node: node.id,
                scratch_bytes: 0,
                name: node.name.clone(),
            });
            continue;
        }

        // Try a fused chain rooted here (quantized or fp32).
        if fuse {
            if let Some(chain) = try_fuse_chain(&g, &users, &absorbed, node.id, &const_index)? {
                for &m in &chain.members {
                    absorbed[m] = true;
                }
                fused_chains += 1;
                protos.push(chain.step);
                continue;
            }
        }

        // 1:1 lowering.
        let op = match &node.op {
            Op::Input => return Err(anyhow!("compile: multiple input nodes")),
            Op::Conv2d { stride, padding, layout } => StepOp::Conv2d {
                stride: *stride,
                padding: *padding,
                layout: *layout,
                epi: Epilogue::default(),
            },
            Op::Dense => StepOp::Dense { epi: Epilogue::default() },
            Op::BiasAdd { layout } => StepOp::BiasAdd { layout: *layout },
            Op::Relu => StepOp::Relu,
            Op::Add => StepOp::Add,
            Op::MaxPool { window, stride, padding, layout } => StepOp::MaxPool {
                window: *window,
                stride: *stride,
                padding: *padding,
                layout: *layout,
            },
            Op::GlobalAvgPool { layout } => StepOp::GlobalAvgPool { layout: *layout },
            Op::Quantize { scale } => StepOp::Quantize { scale: *scale },
            Op::Dequantize { scale } => StepOp::Dequantize { scale: *scale },
            Op::LayoutTransform { from, to } => {
                StepOp::LayoutTransform { from: *from, to: *to }
            }
            Op::Constant(_) => unreachable!("constants skipped above"),
        };
        protos.push(ProtoStep {
            op,
            src_nodes: node.inputs.clone(),
            def_node: node.id,
            scratch_bytes: 0,
            name: node.name.clone(),
        });
    }

    // ---- Lifetimes over the step stream ----
    // A value is live from its defining step through the last step reading
    // it.  Residual operands of two-input epilogue steps are among the
    // step's sources, so `extend_through` keeps them live across the fused
    // step — the planner then cannot alias them with the destination.
    let mut lives: Vec<ValueLife> = Vec::new();
    let mut life_idx: HashMap<NodeId, usize> = HashMap::new();
    for (i, p) in protos.iter().enumerate() {
        let ty = &g.nodes[p.def_node].ty;
        life_idx.insert(p.def_node, lives.len());
        lives.push(ValueLife {
            name: format!("n{}", p.def_node),
            bytes: ty.byte_len(),
            def_step: i,
            last_use_step: i,
        });
        if p.scratch_bytes > 0 {
            lives.push(ValueLife {
                name: format!("s{i}"),
                bytes: p.scratch_bytes,
                def_step: i,
                last_use_step: i,
            });
        }
    }
    for (i, p) in protos.iter().enumerate() {
        for &s in &p.src_nodes {
            if let Some(&li) = life_idx.get(&s) {
                lives[li].extend_through(i);
            }
        }
    }
    // The output value survives past the last step.
    let out_life = *life_idx
        .get(&g.output)
        .ok_or_else(|| anyhow!("compile: output is not materialized by any step"))?;
    lives[out_life].extend_through(protos.len());

    let plan = StaticPlan::first_fit_aligned(&lives, ARENA_ALIGN);
    plan.verify().map_err(|e| anyhow!("arena plan invalid: {e}"))?;
    let offsets = plan.offset_index();
    let arena_bytes = plan.arena_bytes;

    let arena_slot = |id: NodeId| -> Result<Slot> {
        let (off, _) = offsets
            .get(&format!("n{id}"))
            .ok_or_else(|| anyhow!("node {id} missing from arena plan"))?;
        Ok(Slot::Arena { offset: *off, bytes: g.nodes[id].ty.byte_len() })
    };
    let resolve = |id: NodeId| -> Result<(Slot, TensorTy)> {
        let slot = match const_index.get(&id) {
            Some(&ci) => Slot::Const(ci),
            None => arena_slot(id)?,
        };
        Ok((slot, g.nodes[id].ty.clone()))
    };

    // ---- Materialize placed steps ----
    let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
    for (i, p) in protos.into_iter().enumerate() {
        let srcs = p
            .src_nodes
            .iter()
            .map(|&s| resolve(s))
            .collect::<Result<Vec<_>>>()?;
        let scratch = if p.scratch_bytes > 0 {
            let (off, _) = offsets
                .get(&format!("s{i}"))
                .ok_or_else(|| anyhow!("step {i} scratch missing from plan"))?;
            Some(Slot::Arena { offset: *off, bytes: p.scratch_bytes })
        } else {
            None
        };
        steps.push(Step {
            op: p.op,
            srcs,
            dst: arena_slot(p.def_node)?,
            dst_ty: g.nodes[p.def_node].ty.clone(),
            scratch,
            name: p.name,
        });
    }

    // Defense in depth: a two-input epilogue step reads its residual
    // operand elementwise while writing its destination; re-verify the
    // plan kept the two byte ranges disjoint.
    for step in &steps {
        if !step.op.has_residual() {
            continue;
        }
        if let (Slot::Arena { offset: ro, bytes: rb }, Slot::Arena { offset: d, bytes: db }) =
            (step.srcs[2].0, step.dst)
        {
            if ro < d + db && d < ro + rb {
                return Err(anyhow!(
                    "step '{}': residual operand [{ro}+{rb}] aliases destination [{d}+{db}]",
                    step.name
                ));
            }
        }
    }

    let output_slot = arena_slot(g.output)?;
    Ok(CompiledGraph {
        steps,
        consts,
        plan,
        arena_bytes,
        input_ty: g.nodes[g.input].ty.clone(),
        output_ty: g.nodes[g.output].ty.clone(),
        output_slot,
        fused_chains,
    })
}

/// A matched chain: the fused step plus every absorbed node id.
struct FusedChain {
    step: ProtoStep,
    members: Vec<NodeId>,
}

/// Match a fusable chain rooted at `start`: either `quantize → anchor(i8
/// const weight) → dequantize [→ tail]` (rooted at the `Quantize`) or an
/// f32 `anchor [→ tail]` (rooted at the anchor itself; only fused when the
/// tail absorbs at least one op).  The shared tail grammar is
/// `[bias] [add] [relu] [add]` with at most one residual add — see the
/// module docs for the full rules.
fn try_fuse_chain(
    g: &Graph,
    users: &[Vec<NodeId>],
    absorbed: &[bool],
    start: NodeId,
    const_index: &HashMap<NodeId, usize>,
) -> Result<Option<FusedChain>> {
    // A node may be absorbed into a chain only if its value has exactly
    // one consumer (the next link), is not the graph output, and was not
    // claimed by an earlier chain.
    let absorbable = |id: NodeId| users[id].len() == 1 && id != g.output && !absorbed[id];

    // Resolve the anchor: `start` itself (fp32 chain) or the single user
    // of a starting Quantize (quantized chain).
    let node = &g.nodes[start];
    let (qscale, anchor_id) = match node.op {
        Op::Quantize { scale } => {
            if !absorbable(start) {
                return Ok(None);
            }
            (Some(scale), users[start][0])
        }
        Op::Conv2d { .. } | Op::Dense if node.ty.dtype == IrDType::F32 => (None, start),
        _ => return Ok(None),
    };
    if absorbed[anchor_id] {
        return Ok(None);
    }
    let anchor = &g.nodes[anchor_id];
    let (is_conv, stride, padding, conv_layout) = match anchor.op {
        Op::Conv2d { stride, padding, layout } => (true, stride, padding, Some(layout)),
        Op::Dense => (false, 0, 0, None),
        _ => return Ok(None),
    };
    if anchor.inputs.len() != 2 {
        return Ok(None);
    }
    let wid = anchor.inputs[1];

    let mut members: Vec<NodeId> = Vec::new();
    let mut tail;
    let mut dqscale = 0f32;
    if qscale.is_some() {
        // The quantized value must be the anchor's *data* operand and the
        // weight must be a pre-quantized i8 constant.
        if anchor.inputs[0] != start {
            return Ok(None);
        }
        if g.nodes[wid].ty.dtype != IrDType::S8 || !const_index.contains_key(&wid) {
            return Ok(None);
        }
        if !absorbable(anchor_id) {
            return Ok(None);
        }
        let dq_id = users[anchor_id][0];
        match g.nodes[dq_id].op {
            Op::Dequantize { scale } if !absorbed[dq_id] => dqscale = scale,
            _ => return Ok(None),
        }
        members.extend([start, anchor_id, dq_id]);
        tail = dq_id;
    } else {
        members.push(anchor_id);
        tail = anchor_id;
    }

    // ---- Shared epilogue tail: [bias] [add] [relu] [add] ----
    let mut epi = Epilogue::default();
    let mut residual_src: Option<NodeId> = None;

    // Per-channel f32 constant bias (conv only: BiasAdd needs an image
    // rank), and only in the anchor's own layout — a mismatched BiasAdd
    // layout would misindex the channel and is left as a 1:1 step.
    if is_conv && absorbable(tail) {
        let cand = users[tail][0];
        if matches!(g.nodes[cand].op, Op::BiasAdd { layout } if Some(layout) == conv_layout) {
            let b = g.nodes[cand].inputs[1];
            if !absorbed[cand]
                && g.nodes[cand].inputs[0] == tail
                && g.nodes[b].ty.dtype == IrDType::F32
            {
                if let Some(&bci) = const_index.get(&b) {
                    epi.bias = Some(bci);
                    members.push(cand);
                    tail = cand;
                }
            }
        }
    }
    // Residual add before the relu (ResNet block tail).
    if let Some((cand, r, chain_lhs)) = match_residual(g, users, &absorbable, absorbed, tail, start)
    {
        epi.residual = Some(Residual { pre_relu: true, chain_lhs });
        residual_src = Some(r);
        members.push(cand);
        tail = cand;
    }
    // Relu.
    if absorbable(tail) {
        let cand = users[tail][0];
        if matches!(g.nodes[cand].op, Op::Relu) && !absorbed[cand] {
            epi.relu = true;
            members.push(cand);
            tail = cand;
        }
    }
    // Residual add after the relu (only if the pre-relu slot is empty).
    if epi.residual.is_none() {
        if let Some((cand, r, chain_lhs)) =
            match_residual(g, users, &absorbable, absorbed, tail, start)
        {
            epi.residual = Some(Residual { pre_relu: false, chain_lhs });
            residual_src = Some(r);
            members.push(cand);
            tail = cand;
        }
    }

    let (op, data_id, scratch_bytes) = match qscale {
        Some(qs) => {
            let op = if is_conv {
                let layout = conv_layout.expect("conv anchor carries a layout");
                if matches!(layout, Layout::Nchwc(cb) if cb > MAX_FUSED_QCONV_CB) {
                    // The fused packed kernel's lane accumulator is
                    // stack-bounded; leave wider blocks as 1:1 steps.
                    return Ok(None);
                }
                StepOp::QConv2d { qscale: qs, dqscale, stride, padding, layout, epi }
            } else {
                StepOp::QDense { qscale: qs, dqscale, epi }
            };
            // Scratch holds the quantized (i8) input for exactly this step.
            (op, g.nodes[start].inputs[0], g.nodes[start].ty.byte_len())
        }
        None => {
            // An fp32 anchor with an empty tail is already its own fused
            // form — leave it to 1:1 lowering.
            if members.len() == 1 {
                return Ok(None);
            }
            let op = if is_conv {
                let layout = conv_layout.expect("conv anchor carries a layout");
                StepOp::Conv2d { stride, padding, layout, epi }
            } else {
                StepOp::Dense { epi }
            };
            (op, anchor.inputs[0], 0)
        }
    };

    let mut src_nodes = vec![data_id, wid];
    if let Some(r) = residual_src {
        src_nodes.push(r);
    }
    Ok(Some(FusedChain {
        step: ProtoStep {
            op,
            src_nodes,
            def_node: tail,
            scratch_bytes,
            name: format!("{}+fused", anchor.name),
        },
        members,
    }))
}

/// Match a residual `Add` hanging off `tail`.  Returns `(add node, other
/// operand, chain_lhs)`.  The other operand must already be materialized
/// when the fused step executes: a constant, or a node with an id below
/// the chain's `start` (steps are emitted in node-id order of their first
/// member, so a smaller id guarantees an earlier step — including when the
/// operand is itself the tail of an earlier fused chain).
fn match_residual(
    g: &Graph,
    users: &[Vec<NodeId>],
    absorbable: &impl Fn(NodeId) -> bool,
    absorbed: &[bool],
    tail: NodeId,
    start: NodeId,
) -> Option<(NodeId, NodeId, bool)> {
    if !absorbable(tail) {
        return None;
    }
    let cand = users[tail][0];
    let n = &g.nodes[cand];
    if absorbed[cand] || !matches!(n.op, Op::Add) || n.ty.dtype != IrDType::F32 {
        return None;
    }
    let r = n.other_input(tail)?;
    if r < start || matches!(g.nodes[r].op, Op::Constant(_)) {
        Some((cand, r, n.inputs[0] == tail))
    } else {
        None
    }
}
