//! Graph-level optimization passes (TVM's first optimization layer).
//!
//! Every pass preserves `interp::evaluate` semantics (modulo fp tolerance
//! for layout/quantize rewrites); the pass tests and proptests enforce it.

mod dce;
mod fold;
mod fusion;
mod layout_pass;
mod quantize_pass;

use anyhow::Result;

pub use dce::DeadCodeElim;
pub use fold::ConstantFold;
pub use fusion::{FusionPass, FusionPlan};
pub use layout_pass::{AlterConvLayout, CancelLayoutTransforms};
pub use quantize_pass::{calibrate_graph, quantize_graph_with_report, QuantizeRealize};

use super::ir::Graph;

/// A graph-to-graph rewrite.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, g: &Graph) -> Result<Graph>;
}

/// Sequential pass pipeline with per-pass logging hooks.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    pub verbose: bool,
}

impl PassManager {
    pub fn new() -> Self {
        Self { passes: Vec::new(), verbose: false }
    }

    pub fn add(mut self, pass: impl Pass + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    pub fn run(&self, g: &Graph) -> Result<Graph> {
        let mut cur = g.clone();
        for p in &self.passes {
            let before = cur.len();
            cur = p.run(&cur)?;
            cur.validate()?;
            if self.verbose {
                eprintln!("pass {:20} {} -> {} nodes", p.name(), before, cur.len());
            }
        }
        Ok(cur)
    }
}

impl Default for PassManager {
    fn default() -> Self {
        Self::new()
    }
}
