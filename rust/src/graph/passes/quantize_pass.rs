//! Quantize realize: rewrite fp32 convs/dense into
//! `quantize → int8 op (int32 accum) → dequantize` chains.
//!
//! The rust-side mirror of the python `quantize_pass` (calibrate → annotate
//! → realize), operating on the IR: given per-node input scales from
//! [`calibrate_graph`], each anchor op is bracketed with the qnn boundary
//! operators and its weight constant is replaced by a pre-quantized int8
//! constant — exactly TVM's `relay.quantize.realize` output shape, and the
//! paper's §3.2.2 "reads fp32 writes int8 / reads int8 writes fp32" pattern.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::Pass;
use crate::graph::interp::evaluate;
use crate::graph::ir::{ConstValue, Graph, NodeId, Op};
use crate::quant::{abs_max_scale, quantize};
use crate::runtime::TensorData;

/// Run the fp32 graph on a calibration batch and record the abs-max scale
/// of every anchor-op *data input* (weights get their scales at realize).
pub fn calibrate_graph(g: &Graph, calib: &TensorData) -> Result<HashMap<NodeId, f32>> {
    // Evaluate and keep every intermediate.
    let live = g.live_set();
    let mut env: Vec<Option<TensorData>> = vec![None; g.len()];
    for node in &g.nodes {
        if !live[node.id] {
            continue;
        }
        let v = crate::graph::interp::eval_node(g, node, &env, calib)?;
        env[node.id] = Some(v);
    }
    let mut scales = HashMap::new();
    for node in &g.nodes {
        if node.op.is_anchor() {
            let data = node.inputs[0];
            let t = env[data]
                .as_ref()
                .ok_or_else(|| anyhow!("calibration missed node {}", data))?;
            scales.insert(node.id, abs_max_scale(&t.as_f32()?));
        }
    }
    Ok(scales)
}

/// The realize rewrite.  Conv anchors in **every** layout (NCHW, NHWC,
/// NCHW{c}) and dense are quantized; weight quantization is elementwise,
/// so packed/permuted weight constants keep their layout's shape.
/// Everything else stays fp32.
pub struct QuantizeRealize {
    pub scales: HashMap<NodeId, f32>,
}

impl Pass for QuantizeRealize {
    fn name(&self) -> &'static str {
        "quantize_realize"
    }

    fn run(&self, g: &Graph) -> Result<Graph> {
        let mut out = Graph::new();
        let mut remap: Vec<NodeId> = vec![usize::MAX; g.len()];
        for node in &g.nodes {
            let inputs: Vec<NodeId> = node.inputs.iter().map(|&i| remap[i]).collect();
            let quantizable = match &node.op {
                Op::Conv2d { .. } | Op::Dense => {
                    self.scales.contains_key(&node.id)
                        && matches!(
                            g.nodes[node.inputs[1]].op,
                            Op::Constant(ConstValue::F32(_))
                        )
                }
                _ => false,
            };
            let new_id = if quantizable {
                let s_in = self.scales[&node.id];
                let w_node = &g.nodes[node.inputs[1]];
                let w_vals = match &w_node.op {
                    Op::Constant(ConstValue::F32(v)) => v.clone(),
                    _ => unreachable!(),
                };
                let s_w = abs_max_scale(&w_vals);
                let w_q = quantize(&w_vals, s_w);
                let w_q_id = out.add_const_i8(
                    format!("{}.w_q", node.name),
                    w_node.ty.shape.clone(),
                    w_q,
                )?;
                let q_in = out.add(
                    format!("{}.quantize", node.name),
                    Op::Quantize { scale: s_in },
                    vec![inputs[0]],
                )?;
                let op_q = match &node.op {
                    Op::Conv2d { stride, padding, layout } => Op::Conv2d {
                        stride: *stride,
                        padding: *padding,
                        layout: *layout,
                    },
                    Op::Dense => Op::Dense,
                    _ => unreachable!(),
                };
                let acc = out.add(node.name.clone(), op_q, vec![q_in, w_q_id])?;
                out.add(
                    format!("{}.dequantize", node.name),
                    Op::Dequantize { scale: s_in * s_w },
                    vec![acc],
                )?
            } else {
                out.add_clone(node, inputs)?
            };
            remap[node.id] = new_id;
        }
        out.input = remap[g.input];
        out.output = remap[g.output];
        super::DeadCodeElim.run(&out)
    }
}

/// End-to-end helper: calibrate on `calib`, realize, and report the output
/// SQNR of the quantized graph vs the fp32 graph on `eval` input.
pub fn quantize_graph_with_report(
    g: &Graph,
    calib: &TensorData,
    eval: &TensorData,
) -> Result<(Graph, f64)> {
    let scales = calibrate_graph(g, calib)?;
    let qg = QuantizeRealize { scales }.run(g)?;
    qg.validate()?;
    let ref_out = evaluate(g, eval)?.as_f32()?;
    let q_out = evaluate(&qg, eval)?.as_f32()?;
    let sig: f64 = ref_out.iter().map(|v| (*v as f64).powi(2)).sum();
    let noise: f64 = ref_out
        .iter()
        .zip(&q_out)
        .map(|(a, b)| ((*a - *b) as f64).powi(2))
        .sum();
    let sqnr = 10.0 * (sig / noise.max(1e-30)).log10();
    Ok((qg, sqnr))
}
