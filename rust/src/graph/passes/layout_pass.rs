//! Layout alteration: rewrite NCHW convs to the packed NCHW{c} layout
//! (Figure 1) by bracketing them with layout transforms, then cancel
//! adjacent inverse transforms so interior activations stay packed.
//!
//! TVM's `AlterOpLayout` + `CancelLayoutTransform` pair, distilled.  After
//! `ConstantFold`, the weight-side transforms disappear into pre-packed
//! constants, which is exactly the artifact TVM ships.

use anyhow::{anyhow, Result};

use super::Pass;
use crate::graph::ir::{dims_of, Graph, Layout, Node, NodeId, Op};

/// Rewrite every `Conv2d(Nchw)` whose channel counts divide `c_block` into
/// transform → packed conv → inverse-transform.
pub struct AlterConvLayout {
    pub c_block: usize,
    pub k_block: usize,
}

impl Pass for AlterConvLayout {
    fn name(&self) -> &'static str {
        "alter_conv_layout"
    }

    fn run(&self, g: &Graph) -> Result<Graph> {
        let mut out = Graph::new();
        let mut remap: Vec<NodeId> = vec![usize::MAX; g.len()];
        for node in &g.nodes {
            let inputs: Vec<NodeId> = node.inputs.iter().map(|&i| remap[i]).collect();
            let new_id = match &node.op {
                Op::Conv2d { stride, padding, layout: Layout::Nchw } => {
                    let data_ty = &g.nodes[node.inputs[0]].ty;
                    let w_ty = &g.nodes[node.inputs[1]].ty;
                    let (_, c, _, _) = dims_of(&data_ty.shape, Layout::Nchw)?;
                    let k = w_ty.shape[0];
                    if c % self.c_block != 0 || k % self.k_block != 0 {
                        // Not packable: keep as-is (e.g. the 3-channel stem).
                        out.add_clone(node, inputs)?
                    } else {
                        let (r, s) = (w_ty.shape[2], w_ty.shape[3]);
                        let packed = Layout::Nchwc(self.c_block);
                        let data_p = out.add(
                            format!("{}.pack_in", node.name),
                            Op::LayoutTransform { from: Layout::Nchw, to: packed },
                            vec![inputs[0]],
                        )?;
                        // Weight pack: OIHW -> OIHW{i}{o} via an explicit
                        // reshaping node sequence is overkill; emit a
                        // PackWeight pseudo-transform as a constant rewrite.
                        let w_p = pack_weight_node(
                            &mut out, g, node.inputs[1], inputs[1],
                            k, c, r, s, self.c_block, self.k_block,
                            &node.name,
                        )?;
                        let conv = out.add(
                            node.name.clone(),
                            Op::Conv2d { stride: *stride, padding: *padding, layout: packed },
                            vec![data_p, w_p],
                        )?;
                        out.add(
                            format!("{}.unpack_out", node.name),
                            Op::LayoutTransform { from: packed, to: Layout::Nchw },
                            vec![conv],
                        )?
                    }
                }
                _ => out.add_clone(node, inputs)?,
            };
            remap[node.id] = new_id;
        }
        out.input = remap[g.input];
        out.output = remap[g.output];
        Ok(out)
    }
}

/// Pack an f32 OIHW weight constant immediately (constants are known at
/// pass time — this *is* TVM's fold-after-alter behaviour).
#[allow(clippy::too_many_arguments)]
fn pack_weight_node(
    out: &mut Graph,
    g: &Graph,
    old_w: NodeId,
    _new_w: NodeId,
    k: usize,
    c: usize,
    r: usize,
    s: usize,
    cb: usize,
    kb: usize,
    conv_name: &str,
) -> Result<NodeId> {
    let w_node: &Node = &g.nodes[old_w];
    match &w_node.op {
        Op::Constant(crate::graph::ir::ConstValue::F32(vals)) => {
            let packed = crate::layout::pack_oihw(vals, k, c, r, s, cb, kb)?;
            let id = out.add_const_f32(
                format!("{}.w_packed", conv_name),
                vec![k / kb, c / cb, r, s, cb, kb],
                packed,
            )?;
            Ok(id)
        }
        _ => Err(anyhow!(
            "alter_conv_layout: weight of {} is not an f32 constant", conv_name
        )),
    }
}

/// Cancel `LayoutTransform(A→B)` followed by `LayoutTransform(B→A)`, so
/// packed regions connect without bouncing through NCHW.
pub struct CancelLayoutTransforms;

impl Pass for CancelLayoutTransforms {
    fn name(&self) -> &'static str {
        "cancel_layout_transforms"
    }

    fn run(&self, g: &Graph) -> Result<Graph> {
        // forward[i]: what node i should be replaced with when used.
        let mut forward: Vec<NodeId> = (0..g.len()).collect();
        for node in &g.nodes {
            if let Op::LayoutTransform { from, to } = &node.op {
                let src = forward[node.inputs[0]];
                if let Op::LayoutTransform { from: f2, to: t2 } = &g.nodes[src].op {
                    if t2 == from && f2 == to {
                        // src undoes us: this node == src's input.
                        forward[node.id] = forward[g.nodes[src].inputs[0]];
                        continue;
                    }
                }
                // Identity transform.
                if from == to {
                    forward[node.id] = src;
                }
            }
        }
        let mut out = Graph::new();
        let mut remap: Vec<NodeId> = vec![usize::MAX; g.len()];
        for node in &g.nodes {
            if forward[node.id] != node.id {
                remap[node.id] = remap[forward[node.id]];
                continue;
            }
            let inputs: Vec<NodeId> =
                node.inputs.iter().map(|&i| remap[forward[i]]).collect();
            let new_id = out.add_clone(node, inputs)?;
            remap[node.id] = new_id;
        }
        out.input = remap[forward[g.input]];
        out.output = remap[forward[g.output]];
        super::DeadCodeElim.run(&out)
    }
}
