//! Constant folding: pre-evaluate nodes whose inputs are all constants.
//!
//! This is where packed weights come from: `AlterConvLayout` inserts
//! layout transforms over weight constants, and this pass collapses them
//! into pre-packed constants — TVM does exactly this at build time.

use anyhow::{anyhow, Result};

use super::Pass;
use crate::graph::interp::eval_node;
use crate::graph::ir::{ConstValue, Graph, Op};
use crate::runtime::{DType, TensorData};

pub struct ConstantFold;

impl Pass for ConstantFold {
    fn name(&self) -> &'static str {
        "constant_fold"
    }

    fn run(&self, g: &Graph) -> Result<Graph> {
        let mut out = g.clone();
        // Track which nodes are constant-valued; evaluate as we walk (ids
        // are topologically ordered).
        let mut env: Vec<Option<TensorData>> = vec![None; out.nodes.len()];
        let dummy = TensorData::zeros(DType::F32, vec![0]);
        for id in 0..out.nodes.len() {
            let node = out.nodes[id].clone();
            let foldable = match node.op {
                Op::Constant(_) => {
                    env[id] = Some(eval_node(&out, &node, &env, &dummy)?);
                    false
                }
                Op::Input => false,
                _ => node.inputs.iter().all(|&i| env[i].is_some()),
            };
            if !foldable {
                continue;
            }
            let value = eval_node(&out, &node, &env, &dummy)?;
            let op = match value.dtype {
                DType::F32 => Op::Constant(ConstValue::F32(std::sync::Arc::new(
                    value.as_f32()?,
                ))),
                DType::S8 => Op::Constant(ConstValue::I8(std::sync::Arc::new(
                    value.as_i8()?,
                ))),
                DType::S32 => {
                    // No i32 constants in the IR: leave unfolded.
                    continue;
                }
            };
            env[id] = Some(value);
            out.nodes[id].op = op;
            out.nodes[id].inputs = vec![];
            // NOTE: node.ty keeps its (possibly multi-dim) shape; Constant
            // type inference flattens, so keep the declared ty as-is.
        }
        // Folding can orphan inputs of folded nodes.
        super::DeadCodeElim.run(&out).map_err(|e| anyhow!("dce after fold: {e}"))
    }
}
