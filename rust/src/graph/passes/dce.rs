//! Dead-node elimination: drop everything unreachable from the output.

use anyhow::Result;

use super::Pass;
use crate::graph::ir::Graph;

pub struct DeadCodeElim;

impl Pass for DeadCodeElim {
    fn name(&self) -> &'static str {
        "dead_code_elim"
    }

    fn run(&self, g: &Graph) -> Result<Graph> {
        let live = g.live_set();
        let mut remap = vec![usize::MAX; g.nodes.len()];
        let mut out = Graph::new();
        for node in &g.nodes {
            if !live[node.id] {
                continue;
            }
            let mut n = node.clone();
            n.id = out.nodes.len();
            n.inputs = n.inputs.iter().map(|&i| remap[i]).collect();
            remap[node.id] = n.id;
            out.nodes.push(n);
        }
        out.input = remap[g.input];
        out.output = remap[g.output];
        Ok(out)
    }
}
