//! Operator fusion: group anchor ops (conv/dense) with their elementwise
//! consumers into primitive functions.
//!
//! TVM's `FuseOps` classifies ops (out-elemwise-fusable / injective /
//! opaque) and greedily merges along single-consumer edges; each group
//! becomes one compiled primitive.  The *number of groups* is the number of
//! executor dispatches — the quantity whose difference drives Table 1
//! (graph executor: fused groups over one module; VM: one packed call per
//! group plus interpretation).
//!
//! The pass produces a [`FusionPlan`] (a partition of node ids) rather than
//! rewriting the graph: groups keep IR semantics intact and the plan is
//! checked executable-in-order by the tests.

use anyhow::{anyhow, Result};

use super::Pass;
use crate::graph::ir::{Graph, NodeId, Op};

/// A partition of the graph into dispatch groups, each headed by an anchor
/// or a chain of injective ops.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    /// node ids per group, in topological order within and across groups.
    pub groups: Vec<Vec<NodeId>>,
}

impl FusionPlan {
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// group index of every node.
    pub fn group_of(&self, n_nodes: usize) -> Vec<usize> {
        let mut of = vec![usize::MAX; n_nodes];
        for (gi, grp) in self.groups.iter().enumerate() {
            for &id in grp {
                of[id] = gi;
            }
        }
        of
    }

    /// Validate: every non-trivial node in exactly one group; groups
    /// respect topological order (a group's external inputs come from
    /// strictly earlier groups); each group is contiguous-executable.
    pub fn validate(&self, g: &Graph) -> Result<()> {
        let of = self.group_of(g.len());
        for node in &g.nodes {
            let skip = matches!(node.op, Op::Input | Op::Constant(_));
            if skip != (of[node.id] == usize::MAX) {
                return Err(anyhow!(
                    "node {} ({}) grouping inconsistent",
                    node.name, node.op.kind_name()
                ));
            }
        }
        for (gi, grp) in self.groups.iter().enumerate() {
            if grp.is_empty() {
                return Err(anyhow!("empty group {gi}"));
            }
            for w in grp.windows(2) {
                if w[0] >= w[1] {
                    return Err(anyhow!("group {gi} not topologically sorted"));
                }
            }
            for &id in grp {
                for &inp in &g.nodes[id].inputs {
                    if of[inp] != usize::MAX && of[inp] > gi {
                        return Err(anyhow!(
                            "group {gi} consumes node {} from later group {}",
                            inp, of[inp]
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

pub struct FusionPass {
    /// When false, every compute node is its own group — the "no fusion"
    /// ablation (what the VM effectively pays).
    pub enabled: bool,
}

impl FusionPass {
    pub fn plan(&self, g: &Graph) -> Result<FusionPlan> {
        let users = g.users();
        let mut group_of: Vec<Option<usize>> = vec![None; g.len()];
        let mut groups: Vec<Vec<NodeId>> = Vec::new();

        for node in &g.nodes {
            if matches!(node.op, Op::Input | Op::Constant(_)) {
                continue;
            }
            if !self.enabled {
                group_of[node.id] = Some(groups.len());
                groups.push(vec![node.id]);
                continue;
            }
            // Try to join the group of a data producer when this node is
            // elementwise/injective and the producer edge is single-consumer.
            // Ordering constraint: a node may only join the *latest* of its
            // producers' groups — joining an earlier one would make that
            // group consume a value produced by a later group, breaking the
            // sequential dispatch order (caught by FusionPlan::validate).
            let mut joined = None;
            if node.op.is_elementwise() || matches!(node.op, Op::LayoutTransform { .. }) {
                let max_in_group = node
                    .inputs
                    .iter()
                    .filter_map(|&inp| group_of[inp])
                    .max();
                if let Some(gmax) = max_in_group {
                    let join_ok = node.inputs.iter().any(|&inp| {
                        group_of[inp] == Some(gmax) && users[inp].len() == 1
                    });
                    if join_ok {
                        joined = Some(gmax);
                    }
                }
            }
            match joined {
                Some(gi) => {
                    groups[gi].push(node.id);
                    group_of[node.id] = Some(gi);
                }
                None => {
                    group_of[node.id] = Some(groups.len());
                    groups.push(vec![node.id]);
                }
            }
        }
        let plan = FusionPlan { groups };
        plan.validate(g)?;
        Ok(plan)
    }
}

impl Pass for FusionPass {
    fn name(&self) -> &'static str {
        "fuse_ops"
    }

    /// As a `Pass`, fusion is analysis-only (the plan is consumed by the
    /// executor lowering); the graph passes through unchanged.
    fn run(&self, g: &Graph) -> Result<Graph> {
        self.plan(g)?;
        Ok(g.clone())
    }
}
