//! Reference interpreter over the graph IR — the semantic oracle.
//!
//! Every pass must preserve `evaluate(g, x)`; the pass tests and proptests
//! check exactly that.  Implementations are deliberately naive (clarity
//! over speed) except the NCHW{c} conv, whose *relative* speed vs the
//! unpacked conv is itself a measurement (Figure 1 bench): packing makes
//! the inner loop unit-stride, and that locality is visible even in
//! straightforward rust.  Conv runs in every layout at both precisions —
//! fp32 and int8 (i32 accumulation) in NCHW, NHWC, and NCHW{c} — so the
//! oracle covers the executor's whole layout × precision matrix.

use anyhow::{anyhow, Result};

use super::ir::{dims_of, ConstValue, Graph, IrDType, Layout, Node, Op};
use crate::runtime::{DType, TensorData};

fn to_dtype(ir: IrDType) -> DType {
    match ir {
        IrDType::F32 => DType::F32,
        IrDType::S8 => DType::S8,
        IrDType::S32 => DType::S32,
    }
}

/// Evaluate the whole graph on one input tensor.
pub fn evaluate(g: &Graph, input: &TensorData) -> Result<TensorData> {
    let live = g.live_set();
    let mut env: Vec<Option<TensorData>> = vec![None; g.nodes.len()];
    for node in &g.nodes {
        if !live[node.id] {
            continue;
        }
        let value = eval_node(g, node, &env, input)?;
        env[node.id] = Some(value);
    }
    env[g.output]
        .take()
        .ok_or_else(|| anyhow!("output node not evaluated"))
}

/// Evaluate a single node given an environment (used by constant folding
/// with an empty environment and by group-wise execution in fusion tests).
pub fn eval_node(
    g: &Graph,
    node: &Node,
    env: &[Option<TensorData>],
    input: &TensorData,
) -> Result<TensorData> {
    let arg = |i: usize| -> Result<&TensorData> {
        let id = node.inputs[i];
        env[id]
            .as_ref()
            .ok_or_else(|| anyhow!("node {} input {} unevaluated", node.name, id))
    };
    let out = match &node.op {
        Op::Input => {
            if input.shape != node.ty.shape || input.dtype != to_dtype(node.ty.dtype) {
                return Err(anyhow!(
                    "input {:?}/{:?} != declared {:?}/{:?}",
                    input.shape, input.dtype, node.ty.shape, node.ty.dtype
                ));
            }
            input.clone()
        }
        Op::Constant(c) => match c {
            ConstValue::F32(v) => TensorData::from_f32(node.ty.shape.clone(), v)?,
            ConstValue::I8(v) => TensorData::from_i8(node.ty.shape.clone(), v)?,
        },
        Op::Conv2d { stride, padding, layout } => {
            conv2d(arg(0)?, arg(1)?, *stride, *padding, *layout, &node.ty.shape)?
        }
        Op::Dense => dense(arg(0)?, arg(1)?)?,
        Op::BiasAdd { layout } => bias_add(arg(0)?, arg(1)?, *layout)?,
        Op::Relu => relu(arg(0)?)?,
        Op::Add => add(arg(0)?, arg(1)?)?,
        Op::MaxPool { window, stride, padding, layout } => {
            maxpool(arg(0)?, *window, *stride, *padding, *layout, &node.ty.shape)?
        }
        Op::GlobalAvgPool { layout } => global_avgpool(arg(0)?, *layout)?,
        Op::Quantize { scale } => {
            let q = crate::quant::quantize(&arg(0)?.as_f32()?, *scale);
            TensorData::from_i8(node.ty.shape.clone(), &q)?
        }
        Op::Dequantize { scale } => {
            let x = arg(0)?;
            let vals: Vec<f32> = match x.dtype {
                DType::S8 => x.as_i8()?.iter().map(|v| *v as f32 * scale).collect(),
                DType::S32 => x.as_i32()?.iter().map(|v| *v as f32 * scale).collect(),
                DType::F32 => return Err(anyhow!("dequantize of f32")),
            };
            TensorData::from_f32(node.ty.shape.clone(), &vals)?
        }
        Op::LayoutTransform { from, to } => layout_transform(arg(0)?, *from, *to, &node.ty.shape)?,
    };
    if out.shape != node.ty.shape {
        return Err(anyhow!(
            "node {} produced shape {:?}, typed {:?}",
            node.name, out.shape, node.ty.shape
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Operator implementations
// ---------------------------------------------------------------------------

fn conv2d(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    layout: Layout,
    out_shape: &[usize],
) -> Result<TensorData> {
    match (x.dtype, w.dtype) {
        (DType::F32, DType::F32) => match layout {
            Layout::Nchw => conv2d_nchw_f32(x, w, stride, padding, out_shape),
            Layout::Nhwc => conv2d_nhwc_f32(x, w, stride, padding, out_shape),
            Layout::Nchwc(cb) => conv2d_nchwc_f32(x, w, stride, padding, cb, out_shape),
        },
        (DType::S8, DType::S8) => match layout {
            Layout::Nchw => conv2d_nchw_i8(x, w, stride, padding, out_shape),
            Layout::Nhwc => conv2d_nhwc_i8(x, w, stride, padding, out_shape),
            Layout::Nchwc(cb) => conv2d_nchwc_i8(x, w, stride, padding, cb, out_shape),
        },
        other => Err(anyhow!("conv dtype combination {:?}", other)),
    }
}

pub fn conv2d_nchw_f32(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_f32()?;
    let wv = w.as_f32()?;
    let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (k, _, r, s) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (oh, ow) = (out_shape[2], out_shape[3]);
    let mut out = vec![0f32; n * k * oh * ow];
    for ni in 0..n {
        for ki in 0..k {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0f32;
                    for ci in 0..c {
                        for ry in 0..r {
                            let iy = oy * stride + ry;
                            if iy < padding || iy >= h + padding {
                                continue;
                            }
                            let iy = iy - padding;
                            for sx in 0..s {
                                let ix = ox * stride + sx;
                                if ix < padding || ix >= wd + padding {
                                    continue;
                                }
                                let ix = ix - padding;
                                acc += xv[((ni * c + ci) * h + iy) * wd + ix]
                                    * wv[((ki * c + ci) * r + ry) * s + sx];
                            }
                        }
                    }
                    out[((ni * k + ki) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    TensorData::from_f32(out_shape.to_vec(), &out)
}

fn conv2d_nchw_i8(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_i8()?;
    let wv = w.as_i8()?;
    let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (k, _, r, s) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (oh, ow) = (out_shape[2], out_shape[3]);
    let mut out = vec![0i32; n * k * oh * ow];
    for ni in 0..n {
        for ki in 0..k {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ci in 0..c {
                        for ry in 0..r {
                            let iy = oy * stride + ry;
                            if iy < padding || iy >= h + padding {
                                continue;
                            }
                            let iy = iy - padding;
                            for sx in 0..s {
                                let ix = ox * stride + sx;
                                if ix < padding || ix >= wd + padding {
                                    continue;
                                }
                                let ix = ix - padding;
                                acc += xv[((ni * c + ci) * h + iy) * wd + ix] as i32
                                    * wv[((ki * c + ci) * r + ry) * s + sx] as i32;
                            }
                        }
                    }
                    out[((ni * k + ki) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    TensorData::from_i32(out_shape.to_vec(), &out)
}

fn conv2d_nhwc_f32(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_f32()?;
    let wv = w.as_f32()?; // HWIO
    let (n, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (r, s, _, k) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (oh, ow) = (out_shape[1], out_shape[2]);
    let mut out = vec![0f32; n * oh * ow * k];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ki in 0..k {
                    let mut acc = 0f32;
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            for ci in 0..c {
                                acc += xv[((ni * h + iy) * wd + ix) * c + ci]
                                    * wv[((ry * s + sx) * c + ci) * k + ki];
                            }
                        }
                    }
                    out[((ni * oh + oy) * ow + ox) * k + ki] = acc;
                }
            }
        }
    }
    TensorData::from_f32(out_shape.to_vec(), &out)
}

/// Packed conv: data NCHW{cb}, weight OIHW{i}{o}.  The inner `ci` loop is
/// unit-stride on both operands — the Figure-1 payoff.
pub fn conv2d_nchwc_f32(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    cb: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_f32()?;
    let wv = w.as_f32()?;
    let (n, co, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (ko, _, r, s, _, kb) = (
        w.shape[0], w.shape[1], w.shape[2], w.shape[3], w.shape[4], w.shape[5],
    );
    let (oh, ow) = (out_shape[2], out_shape[3]);
    let mut out = vec![0f32; n * ko * oh * ow * kb];
    for ni in 0..n {
        for ok in 0..ko {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = vec![0f32; kb];
                    for oc in 0..co {
                        for ry in 0..r {
                            let iy = oy * stride + ry;
                            if iy < padding || iy >= h + padding {
                                continue;
                            }
                            let iy = iy - padding;
                            for sx in 0..s {
                                let ix = ox * stride + sx;
                                if ix < padding || ix >= wd + padding {
                                    continue;
                                }
                                let ix = ix - padding;
                                let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                                let wbase =
                                    ((((ok * co + oc) * r + ry) * s + sx) * cb) * kb;
                                for ci in 0..cb {
                                    let xi = xv[xbase + ci];
                                    let wrow = wbase + ci * kb;
                                    for ki in 0..kb {
                                        acc[ki] += xi * wv[wrow + ki];
                                    }
                                }
                            }
                        }
                    }
                    let obase = (((ni * ko + ok) * oh + oy) * ow + ox) * kb;
                    out[obase..obase + kb].copy_from_slice(&acc);
                }
            }
        }
    }
    TensorData::from_f32(out_shape.to_vec(), &out)
}

/// int8 NHWC conv (HWIO weight), i32 accumulation.  The inner `ci` loop is
/// unit-stride on the data operand — NHWC's channel-innermost payoff.
fn conv2d_nhwc_i8(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_i8()?;
    let wv = w.as_i8()?; // HWIO
    let (n, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (r, s, _, k) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (oh, ow) = (out_shape[1], out_shape[2]);
    let mut out = vec![0i32; n * oh * ow * k];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ki in 0..k {
                    let mut acc = 0i32;
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            for ci in 0..c {
                                acc += xv[((ni * h + iy) * wd + ix) * c + ci] as i32
                                    * wv[((ry * s + sx) * c + ci) * k + ki] as i32;
                            }
                        }
                    }
                    out[((ni * oh + oy) * ow + ox) * k + ki] = acc;
                }
            }
        }
    }
    TensorData::from_i32(out_shape.to_vec(), &out)
}

/// int8 packed conv: data NCHW{cb}, weight OIHW{i}{o}, i32 accumulation
/// over the `cb` input lanes into `kb` output lanes — the channel-blocked
/// inner loop that stands in for the paper's int8 tensorization: both
/// operand walks are unit-stride inside the block.
fn conv2d_nchwc_i8(
    x: &TensorData,
    w: &TensorData,
    stride: usize,
    padding: usize,
    cb: usize,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_i8()?;
    let wv = w.as_i8()?;
    let (n, co, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (ko, _, r, s, wcb, kb) = (
        w.shape[0], w.shape[1], w.shape[2], w.shape[3], w.shape[4], w.shape[5],
    );
    if wcb != cb || kb != cb {
        // The IR types a packed conv's output with the *input* block size,
        // so asymmetric blocks would mistype every downstream op.
        return Err(anyhow!("packed conv blocks i={wcb}/o={kb} != layout block {cb}"));
    }
    let (oh, ow) = (out_shape[2], out_shape[3]);
    let mut out = vec![0i32; n * ko * oh * ow * kb];
    let mut acc = vec![0i32; kb];
    for ni in 0..n {
        for ok in 0..ko {
            for oy in 0..oh {
                for ox in 0..ow {
                    acc.fill(0);
                    for oc in 0..co {
                        for ry in 0..r {
                            let iy = oy * stride + ry;
                            if iy < padding || iy >= h + padding {
                                continue;
                            }
                            let iy = iy - padding;
                            for sx in 0..s {
                                let ix = ox * stride + sx;
                                if ix < padding || ix >= wd + padding {
                                    continue;
                                }
                                let ix = ix - padding;
                                let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                                let wbase =
                                    ((((ok * co + oc) * r + ry) * s + sx) * cb) * kb;
                                for ci in 0..cb {
                                    let xi = xv[xbase + ci] as i32;
                                    let wrow = wbase + ci * kb;
                                    for ki in 0..kb {
                                        acc[ki] += xi * wv[wrow + ki] as i32;
                                    }
                                }
                            }
                        }
                    }
                    let obase = (((ni * ko + ok) * oh + oy) * ow + ox) * kb;
                    out[obase..obase + kb].copy_from_slice(&acc);
                }
            }
        }
    }
    TensorData::from_i32(out_shape.to_vec(), &out)
}

fn dense(x: &TensorData, w: &TensorData) -> Result<TensorData> {
    let (m, k) = (x.shape[0], x.shape[1]);
    let n = w.shape[1];
    match (x.dtype, w.dtype) {
        (DType::F32, DType::F32) => {
            let (xv, wv) = (x.as_f32()?, w.as_f32()?);
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xik = xv[i * k + kk];
                    for j in 0..n {
                        out[i * n + j] += xik * wv[kk * n + j];
                    }
                }
            }
            TensorData::from_f32(vec![m, n], &out)
        }
        (DType::S8, DType::S8) => {
            let (xv, wv) = (x.as_i8()?, w.as_i8()?);
            let mut out = vec![0i32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xik = xv[i * k + kk] as i32;
                    for j in 0..n {
                        out[i * n + j] += xik * wv[kk * n + j] as i32;
                    }
                }
            }
            TensorData::from_i32(vec![m, n], &out)
        }
        other => Err(anyhow!("dense dtypes {:?}", other)),
    }
}

/// Thin allocating wrapper over the shared core (`graph::kernels`) — the
/// arena executor runs the identical loop over its pre-placed windows, so
/// the two tiers cannot drift.
fn bias_add(x: &TensorData, b: &TensorData, layout: Layout) -> Result<TensorData> {
    let xv = x.as_f32_slice()?;
    let bv = b.as_f32_slice()?;
    let mut out = vec![0f32; xv.len()];
    super::kernels::bias_add_f32(xv, &x.shape, bv, layout, &mut out)?;
    TensorData::from_f32(x.shape.clone(), &out)
}

fn relu(x: &TensorData) -> Result<TensorData> {
    match x.dtype {
        DType::F32 => {
            let v: Vec<f32> = x.as_f32()?.iter().map(|v| v.max(0.0)).collect();
            TensorData::from_f32(x.shape.clone(), &v)
        }
        DType::S32 => {
            let v: Vec<i32> = x.as_i32()?.iter().map(|v| (*v).max(0)).collect();
            TensorData::from_i32(x.shape.clone(), &v)
        }
        DType::S8 => {
            let v: Vec<i8> = x.as_i8()?.iter().map(|v| (*v).max(0)).collect();
            TensorData::from_i8(x.shape.clone(), &v)
        }
    }
}

fn add(a: &TensorData, b: &TensorData) -> Result<TensorData> {
    if a.shape != b.shape || a.dtype != b.dtype {
        return Err(anyhow!("add mismatch"));
    }
    match a.dtype {
        DType::F32 => {
            let v: Vec<f32> =
                a.as_f32()?.iter().zip(b.as_f32()?).map(|(x, y)| x + y).collect();
            TensorData::from_f32(a.shape.clone(), &v)
        }
        DType::S32 => {
            let v: Vec<i32> =
                a.as_i32()?.iter().zip(b.as_i32()?).map(|(x, y)| x + y).collect();
            TensorData::from_i32(a.shape.clone(), &v)
        }
        DType::S8 => {
            let v: Vec<i8> = a
                .as_i8()?
                .iter()
                .zip(b.as_i8()?)
                .map(|(x, y)| x.saturating_add(y))
                .collect();
            TensorData::from_i8(a.shape.clone(), &v)
        }
    }
}

/// Shared-core wrapper; see [`bias_add`].
fn maxpool(
    x: &TensorData,
    window: usize,
    stride: usize,
    padding: usize,
    layout: Layout,
    out_shape: &[usize],
) -> Result<TensorData> {
    let xv = x.as_f32_slice()?;
    let mut out = vec![0f32; out_shape.iter().product()];
    super::kernels::maxpool_f32(
        xv, &x.shape, window, stride, padding, layout, &mut out, out_shape,
    )?;
    TensorData::from_f32(out_shape.to_vec(), &out)
}

/// Shared-core wrapper; see [`bias_add`].
fn global_avgpool(x: &TensorData, layout: Layout) -> Result<TensorData> {
    let xv = x.as_f32_slice()?;
    let (n, c, _, _) = dims_of(&x.shape, layout)?;
    let mut out = vec![0f32; n * c];
    super::kernels::global_avgpool_f32(xv, &x.shape, layout, &mut out)?;
    TensorData::from_f32(vec![n, c], &out)
}

fn layout_transform(
    x: &TensorData,
    from: Layout,
    to: Layout,
    out_shape: &[usize],
) -> Result<TensorData> {
    use crate::layout as L;
    let (n, c, h, w) = dims_of(&x.shape, from)?;
    let d = L::Nchw { n, c, h, w };
    let xv = x.as_f32()?;
    // Normalize to NCHW, then to target.
    let nchw = match from {
        Layout::Nchw => xv,
        Layout::Nhwc => L::nhwc_to_nchw(&xv, d)?,
        Layout::Nchwc(cb) => L::unpack_nchwc(&xv, d, cb)?,
    };
    let out = match to {
        Layout::Nchw => nchw,
        Layout::Nhwc => L::nchw_to_nhwc(&nchw, d)?,
        Layout::Nchwc(cb) => L::pack_nchwc(&nchw, d, cb)?,
    };
    TensorData::from_f32(out_shape.to_vec(), &out)
}
