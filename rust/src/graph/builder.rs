//! Graph builders: seeded test networks for the IR pipeline, pass tests,
//! and the Figure-1 / ablation benches.
//!
//! Weights here are rust-side (seeded ChaCha8) — independent of the AOT
//! artifacts, which bake their own weights.  The IR layer is the in-process
//! compile pipeline; the artifacts are the AOT one.

use anyhow::{anyhow, Result};

use crate::util::rng::Rng64;

use super::ir::{conv_out_size, Graph, Layout, NodeId, Op, TensorTy};
use crate::runtime::TensorData;

/// Spec for a small conv net: a stack of conv+bias+relu stages with
/// optional residual links, ending in global-avg-pool + dense.
#[derive(Debug, Clone)]
pub struct NetSpec {
    pub batch: usize,
    pub image: usize,
    pub in_channels: usize,
    pub stages: Vec<StageSpec>,
    pub classes: usize,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct StageSpec {
    pub channels: usize,
    pub kernel: usize,
    pub stride: usize,
    pub residual: bool,
}

impl NetSpec {
    /// The default IR demo net: CIFAR-scale, 4 stages, one residual.
    pub fn small(batch: usize) -> Self {
        NetSpec {
            batch,
            image: 16,
            in_channels: 3,
            stages: vec![
                StageSpec { channels: 16, kernel: 3, stride: 1, residual: false },
                StageSpec { channels: 16, kernel: 3, stride: 1, residual: true },
                StageSpec { channels: 32, kernel: 3, stride: 2, residual: false },
            ],
            classes: 10,
            seed: 7,
        }
    }
}

fn he_weights(rng: &mut Rng64, k: usize, c: usize, r: usize) -> Vec<f32> {
    let std = (2.0 / (c * r * r) as f32).sqrt();
    (0..k * c * r * r)
        .map(|_| (rng.f32() * 2.0 - 1.0) * 1.73 * std)
        .collect()
}

/// Add a conv weight constant for `layout`: the same seeded OIHW draw in
/// every layout, permuted (NHWC: HWIO) or packed (NCHW{c}: OIHW{i}{o})
/// into the layout's weight format — so a model built in any layout is
/// the *same function*, only stored differently.
fn add_conv_weight(
    g: &mut Graph,
    rng: &mut Rng64,
    name: &str,
    cout: usize,
    cin: usize,
    k: usize,
    layout: Layout,
) -> Result<NodeId> {
    let oihw = he_weights(rng, cout, cin, k);
    match layout {
        Layout::Nchw => g.add_const_f32(format!("{name}.w"), vec![cout, cin, k, k], oihw),
        Layout::Nhwc => {
            let mut hwio = vec![0f32; oihw.len()];
            for ko in 0..cout {
                for ci in 0..cin {
                    for ry in 0..k {
                        for sx in 0..k {
                            hwio[((ry * k + sx) * cin + ci) * cout + ko] =
                                oihw[((ko * cin + ci) * k + ry) * k + sx];
                        }
                    }
                }
            }
            g.add_const_f32(format!("{name}.w"), vec![k, k, cin, cout], hwio)
        }
        Layout::Nchwc(cb) => {
            let packed = crate::layout::pack_oihw(&oihw, cout, cin, k, k, cb, cb)?;
            g.add_const_f32(
                format!("{name}.w"),
                vec![cout / cb, cin / cb, k, k, cb, cb],
                packed,
            )
        }
    }
}

/// Build a conv net per spec (NCHW, fp32).
pub fn build_conv_net(spec: &NetSpec) -> Result<Graph> {
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(spec.seed);
    let x = g.add_input(
        "data",
        TensorTy::f32(vec![spec.batch, spec.in_channels, spec.image, spec.image]),
    );
    let mut cur: NodeId = x;
    let mut c = spec.in_channels;
    let mut hw = spec.image;
    for (i, st) in spec.stages.iter().enumerate() {
        let name = format!("conv{i}");
        let pad = st.kernel / 2;
        let w = g.add_const_f32(
            format!("{name}.w"),
            vec![st.channels, c, st.kernel, st.kernel],
            he_weights(&mut rng, st.channels, c, st.kernel),
        )?;
        let b = g.add_const_f32(
            format!("{name}.b"),
            vec![st.channels],
            (0..st.channels).map(|_| rng.f32() * 0.1 - 0.05).collect(),
        )?;
        let conv = g.add(
            name.clone(),
            Op::Conv2d { stride: st.stride, padding: pad, layout: Layout::Nchw },
            vec![cur, w],
        )?;
        let biased = g.add(
            format!("{name}.bias"),
            Op::BiasAdd { layout: Layout::Nchw },
            vec![conv, b],
        )?;
        let act = g.add(format!("{name}.relu"), Op::Relu, vec![biased])?;
        cur = if st.residual && st.stride == 1 && st.channels == c {
            g.add(format!("{name}.skip"), Op::Add, vec![act, cur])?
        } else {
            act
        };
        c = st.channels;
        hw = conv_out_size(hw, st.kernel, st.stride, pad);
        let _ = hw;
    }
    let pooled = g.add(
        "gap",
        Op::GlobalAvgPool { layout: Layout::Nchw },
        vec![cur],
    )?;
    let wd = g.add_const_f32(
        "fc.w",
        vec![c, spec.classes],
        (0..c * spec.classes)
            .map(|_| (rng.f32() * 2.0 - 1.0) / (c as f32).sqrt())
            .collect(),
    )?;
    let logits = g.add("fc", Op::Dense, vec![pooled, wd])?;
    g.output = logits;
    g.validate()?;
    Ok(g)
}

/// The ResNet-10-shaped IR (mirrors the python `resnet10` arch) in NCHW —
/// used by the compile-pipeline demo so pass statistics refer to the real
/// model.
pub fn build_resnet_ir(batch: usize, image: usize, seed: u64) -> Result<Graph> {
    build_resnet_ir_in(batch, image, seed, Layout::Nchw)
}

/// [`build_resnet_ir`] with the activations natively in `layout`:
///
/// - `Nchw` — the original model, byte-identical weights per seed.
/// - `Nhwc` — input `(N,H,W,C)`, every conv/bias/pool NHWC with HWIO
///   weights (the same seeded draw, permuted).
/// - `Nchwc(cb)` — the stem consumes the 3 input channels no block
///   divides, so it runs NCHW and a single pack transform moves its
///   activation into the blocked layout; every residual block then runs
///   natively packed (conv + bias + relu + skip add on `(N,C/cb,H,W,cb)`
///   tensors with pre-packed OIHW{i}{o} weights), exactly the shape real
///   NCHWc deployments take.  Block widths must divide the stage widths
///   (16/32/64/128), i.e. `cb ∈ {2,4,8,16}`.
///
/// Same seed → the same function in every layout (weights are one draw,
/// restored per layout), so cross-layout benches compare storage, not
/// models.
pub fn build_resnet_ir_in(
    batch: usize,
    image: usize,
    seed: u64,
    layout: Layout,
) -> Result<Graph> {
    if let Layout::Nchwc(cb) = layout {
        if cb == 0 || 16 % cb != 0 {
            return Err(anyhow!("resnet block widths need cb | 16, got {cb}"));
        }
    }
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(seed);
    let stem_layout = match layout {
        Layout::Nhwc => Layout::Nhwc,
        _ => Layout::Nchw,
    };
    let input_ty = match stem_layout {
        Layout::Nhwc => TensorTy::f32(vec![batch, image, image, 3]),
        _ => TensorTy::f32(vec![batch, 3, image, image]),
    };
    let x = g.add_input("data", input_ty);

    let mut add_conv = |g: &mut Graph,
                        rng: &mut Rng64,
                        name: &str,
                        input: NodeId,
                        cin: usize,
                        cout: usize,
                        kernel: usize,
                        stride: usize,
                        pad: usize,
                        relu: bool,
                        lay: Layout|
     -> Result<NodeId> {
        let w = add_conv_weight(g, rng, name, cout, cin, kernel, lay)?;
        let b = g.add_const_f32(
            format!("{name}.b"),
            vec![cout],
            (0..cout).map(|_| rng.f32() * 0.1 - 0.05).collect(),
        )?;
        let conv = g.add(
            name.to_string(),
            Op::Conv2d { stride, padding: pad, layout: lay },
            vec![input, w],
        )?;
        let biased = g.add(
            format!("{name}.bias"),
            Op::BiasAdd { layout: lay },
            vec![conv, b],
        )?;
        if relu {
            g.add(format!("{name}.relu"), Op::Relu, vec![biased])
        } else {
            Ok(biased)
        }
    };

    let mut cur = add_conv(&mut g, &mut rng, "stem", x, 3, 16, 3, 1, 1, true, stem_layout)?;
    if layout != stem_layout {
        cur = g.add(
            "stem.pack",
            Op::LayoutTransform { from: stem_layout, to: layout },
            vec![cur],
        )?;
    }
    let mut cin = 16;
    for (bi, (cout, stride)) in [(16usize, 1usize), (32, 2), (64, 2), (128, 2)]
        .into_iter()
        .enumerate()
    {
        let name = format!("block{bi}");
        let m1 = add_conv(
            &mut g, &mut rng, &format!("{name}.conv1"), cur, cin, cout, 3, stride, 1, true,
            layout,
        )?;
        let m2 = add_conv(
            &mut g, &mut rng, &format!("{name}.conv2"), m1, cout, cout, 3, 1, 1, false,
            layout,
        )?;
        let skip = if stride != 1 || cin != cout {
            add_conv(
                &mut g, &mut rng, &format!("{name}.down"), cur, cin, cout, 1, stride, 0, false,
                layout,
            )?
        } else {
            cur
        };
        let sum = g.add(format!("{name}.add"), Op::Add, vec![m2, skip])?;
        cur = g.add(format!("{name}.relu"), Op::Relu, vec![sum])?;
        cin = cout;
    }
    let pooled = g.add("gap", Op::GlobalAvgPool { layout }, vec![cur])?;
    let wd = g.add_const_f32(
        "fc.w",
        vec![cin, 10],
        (0..cin * 10)
            .map(|_| (rng.f32() * 2.0 - 1.0) / (cin as f32).sqrt())
            .collect(),
    )?;
    g.output = g.add("fc", Op::Dense, vec![pooled, wd])?;
    g.validate()?;
    Ok(g)
}

/// Re-instantiate `g` at a different leading batch dimension **without
/// rebuilding its weights**: constants are cloned by `Arc` (one shared
/// payload across every re-batched copy), the input's batch dim is
/// rewritten, and every other node's type is re-inferred at the new size.
/// Node ids map 1:1, so node-id-keyed metadata (calibration scales)
/// transfers unchanged.  This is how the serving factory compiles one
/// engine per batch bucket from a single template model.
pub fn rebatch_graph(g: &Graph, batch: usize) -> Result<Graph> {
    let mut out = Graph::new();
    for node in &g.nodes {
        match &node.op {
            Op::Input => {
                let mut ty = node.ty.clone();
                let Some(dim) = ty.shape.first_mut() else {
                    return Err(anyhow!("rebatch: input {} has no batch dim", node.name));
                };
                *dim = batch;
                out.add_input(node.name.clone(), ty);
            }
            // `add_clone` keeps the constant's explicit type and clones the
            // payload Arc — no weight bytes are copied.
            Op::Constant(_) => {
                out.add_clone(node, vec![])?;
            }
            _ => {
                out.add(node.name.clone(), node.op.clone(), node.inputs.clone())?;
            }
        }
    }
    out.input = g.input;
    out.output = g.output;
    out.validate()?;
    Ok(out)
}

/// Seeded input batch for IR evaluation.
pub fn calibrate_ir(g: &Graph, seed: u64) -> TensorData {
    let ty = &g.nodes[g.input].ty;
    let mut rng = Rng64::seed_from_u64(seed);
    let vals: Vec<f32> = (0..ty.element_count())
        .map(|_| {
            let s: f32 = (0..4).map(|_| rng.f32()).sum::<f32>() - 2.0;
            s * 0.866
        })
        .collect();
    TensorData::from_f32(ty.shape.clone(), &vals).expect("input shape")
}
