//! The dataflow IR: typed nodes in an append-only (hence topologically
//! ordered) graph.

use std::sync::Arc;

use anyhow::{anyhow, Result};

pub type NodeId = usize;

// `Ord` gives schedule-override keys (`graph::compile::ClassKey`) a
// deterministic sort for the tuner's seeded samplers and records files.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Layout {
    Nchw,
    Nhwc,
    /// Channel-blocked NCHW{c} with the given block (Figure 1).
    Nchwc(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IrDType {
    F32,
    S8,
    S32,
}

impl IrDType {
    pub fn size_bytes(&self) -> usize {
        match self {
            IrDType::F32 | IrDType::S32 => 4,
            IrDType::S8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorTy {
    pub shape: Vec<usize>,
    pub dtype: IrDType,
}

impl TensorTy {
    pub fn f32(shape: Vec<usize>) -> Self {
        Self { shape, dtype: IrDType::F32 }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }
}

/// Constant payloads (weights, biases, quantized weights).
#[derive(Debug, Clone)]
pub enum ConstValue {
    F32(Arc<Vec<f32>>),
    I8(Arc<Vec<i8>>),
}

impl ConstValue {
    pub fn len(&self) -> usize {
        match self {
            ConstValue::F32(v) => v.len(),
            ConstValue::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> IrDType {
        match self {
            ConstValue::F32(_) => IrDType::F32,
            ConstValue::I8(_) => IrDType::S8,
        }
    }
}

/// Operator set: the ResNet inference vocabulary plus the qnn boundary ops
/// and layout transforms — what TVM's relay level sees for this workload.
#[derive(Debug, Clone)]
pub enum Op {
    Input,
    Constant(ConstValue),
    /// inputs: [data, weight].  Weight layout follows `layout`:
    /// OIHW for Nchw, HWIO for Nhwc, OIHW{i}{o} for Nchwc.
    Conv2d { stride: usize, padding: usize, layout: Layout },
    /// inputs: [x (M,K), w (K,N)]
    Dense,
    /// inputs: [x, bias(C)]
    BiasAdd { layout: Layout },
    Relu,
    /// inputs: [a, b] (same type)
    Add,
    MaxPool { window: usize, stride: usize, padding: usize, layout: Layout },
    GlobalAvgPool { layout: Layout },
    /// fp32 -> int8 at a static scale (realized quantization).
    Quantize { scale: f32 },
    /// int8/int32 -> fp32 at a static scale.
    Dequantize { scale: f32 },
    LayoutTransform { from: Layout, to: Layout },
}

impl Op {
    /// Anchor ops start fusion groups; elementwise/injective ops get fused
    /// into their producer's group (TVM's `kOutEWiseFusable` / injective
    /// classification, distilled).
    pub fn is_anchor(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Dense)
    }

    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            Op::BiasAdd { .. } | Op::Relu | Op::Add | Op::Quantize { .. } | Op::Dequantize { .. }
        )
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Constant(_) => "constant",
            Op::Conv2d { .. } => "conv2d",
            Op::Dense => "dense",
            Op::BiasAdd { .. } => "bias_add",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::MaxPool { .. } => "max_pool",
            Op::GlobalAvgPool { .. } => "global_avg_pool",
            Op::Quantize { .. } => "quantize",
            Op::Dequantize { .. } => "dequantize",
            Op::LayoutTransform { .. } => "layout_transform",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub ty: TensorTy,
}

impl Node {
    /// For a binary node, the operand that is not `id`.  `None` when the
    /// node is not binary, `id` is not an operand, or both operands are
    /// `id` (so callers never mistake `add(x, x)` for a residual link).
    pub fn other_input(&self, id: NodeId) -> Option<NodeId> {
        match self.inputs.as_slice() {
            &[a, b] if a == id && b != id => Some(b),
            &[a, b] if b == id && a != id => Some(a),
            _ => None,
        }
    }
}

/// Append-only dataflow graph; node ids are topologically ordered by
/// construction (inputs always precede users).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub input: NodeId,
    pub output: NodeId,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: Vec<NodeId>) -> Result<NodeId> {
        let id = self.nodes.len();
        for &i in &inputs {
            if i >= id {
                return Err(anyhow!("node {:?} input {} not yet defined", name.into(), i));
            }
        }
        let in_tys: Vec<&TensorTy> = inputs.iter().map(|&i| &self.nodes[i].ty).collect();
        let ty = infer_type(&op, &in_tys)?;
        self.nodes.push(Node { id, name: name.into(), op, inputs, ty });
        Ok(id)
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Users of each node (computed on demand).
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                users[i].push(n.id);
            }
        }
        users
    }

    /// Nodes reachable from the output (for DCE and validation).
    pub fn live_set(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack = vec![self.output];
        while let Some(id) = stack.pop() {
            if live[id] {
                continue;
            }
            live[id] = true;
            stack.extend(self.nodes[id].inputs.iter().copied());
        }
        live
    }

    /// Structural validation: ids consistent, output in range, types okay.
    pub fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(anyhow!("node {} has id {}", i, n.id));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(anyhow!("node {} uses later node {}", i, inp));
                }
            }
            match &n.op {
                // Inputs/constants carry explicit shapes; check consistency
                // (inference cannot reconstruct a constant's rank).
                Op::Input => {}
                Op::Constant(c) => {
                    if n.ty.element_count() != c.len() || n.ty.dtype != c.dtype() {
                        return Err(anyhow!(
                            "constant {} ty {:?} != payload ({} x {:?})",
                            n.name, n.ty, c.len(), c.dtype()
                        ));
                    }
                }
                op => {
                    let in_tys: Vec<&TensorTy> =
                        n.inputs.iter().map(|&x| &self.nodes[x].ty).collect();
                    let want = infer_type(op, &in_tys)?;
                    if want != n.ty {
                        return Err(anyhow!(
                            "node {} ({}) type {:?} != inferred {:?}",
                            n.name, op.kind_name(), n.ty, want
                        ));
                    }
                }
            }
        }
        if self.output >= self.nodes.len() {
            return Err(anyhow!("output id out of range"));
        }
        Ok(())
    }

    /// Total constant (weight) bytes — the memory-accounting input.
    pub fn const_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                Op::Constant(c) => c.len() * c.dtype().size_bytes(),
                _ => 0,
            })
            .sum()
    }
}

pub fn conv_out_size(size: usize, r: usize, stride: usize, padding: usize) -> usize {
    (size + 2 * padding - r) / stride + 1
}

/// Shape/dtype inference for every operator.
pub fn infer_type(op: &Op, inputs: &[&TensorTy]) -> Result<TensorTy> {
    let need = |n: usize| -> Result<()> {
        if inputs.len() != n {
            return Err(anyhow!("{} expects {} inputs, got {}", op.kind_name(), n, inputs.len()));
        }
        Ok(())
    };
    match op {
        Op::Input => Err(anyhow!("input type must be set explicitly via add_input")),
        Op::Constant(c) => Ok(TensorTy { shape: vec![c.len()], dtype: c.dtype() }),
        Op::Conv2d { stride, padding, layout } => {
            need(2)?;
            conv2d_type(inputs[0], inputs[1], *stride, *padding, *layout)
        }
        Op::Dense => {
            need(2)?;
            let (x, w) = (inputs[0], inputs[1]);
            if x.shape.len() != 2 || w.shape.len() != 2 || x.shape[1] != w.shape[0] {
                return Err(anyhow!("dense shapes {:?} x {:?}", x.shape, w.shape));
            }
            let dtype = match (x.dtype, w.dtype) {
                (IrDType::F32, IrDType::F32) => IrDType::F32,
                (IrDType::S8, IrDType::S8) => IrDType::S32,
                other => return Err(anyhow!("dense dtypes {:?}", other)),
            };
            Ok(TensorTy { shape: vec![x.shape[0], w.shape[1]], dtype })
        }
        Op::BiasAdd { layout } => {
            need(2)?;
            let (x, b) = (inputs[0], inputs[1]);
            let (_, c, _, _) = dims_of(&x.shape, *layout)?;
            if b.shape != vec![c] {
                return Err(anyhow!("bias shape {:?} for C={}", b.shape, c));
            }
            if x.dtype != IrDType::F32 || b.dtype != IrDType::F32 {
                return Err(anyhow!("bias_add requires f32"));
            }
            Ok(x.clone())
        }
        Op::Relu => {
            need(1)?;
            Ok(inputs[0].clone())
        }
        Op::Add => {
            need(2)?;
            if inputs[0] != inputs[1] {
                return Err(anyhow!("add type mismatch {:?} vs {:?}", inputs[0], inputs[1]));
            }
            Ok(inputs[0].clone())
        }
        Op::MaxPool { window, stride, padding, layout } => {
            need(1)?;
            let x = inputs[0];
            let (n, c, h, w) = dims_of(&x.shape, *layout)?;
            let oh = conv_out_size(h, *window, *stride, *padding);
            let ow = conv_out_size(w, *window, *stride, *padding);
            Ok(TensorTy { shape: shape_of(n, c, oh, ow, *layout), dtype: x.dtype })
        }
        Op::GlobalAvgPool { layout } => {
            need(1)?;
            let (n, c, _, _) = dims_of(&inputs[0].shape, *layout)?;
            Ok(TensorTy { shape: vec![n, c], dtype: inputs[0].dtype })
        }
        Op::Quantize { .. } => {
            need(1)?;
            if inputs[0].dtype != IrDType::F32 {
                return Err(anyhow!("quantize input must be f32"));
            }
            Ok(TensorTy { shape: inputs[0].shape.clone(), dtype: IrDType::S8 })
        }
        Op::Dequantize { .. } => {
            need(1)?;
            if inputs[0].dtype == IrDType::F32 {
                return Err(anyhow!("dequantize input must be integer"));
            }
            Ok(TensorTy { shape: inputs[0].shape.clone(), dtype: IrDType::F32 })
        }
        Op::LayoutTransform { from, to } => {
            need(1)?;
            let (n, c, h, w) = dims_of(&inputs[0].shape, *from)?;
            Ok(TensorTy { shape: shape_of(n, c, h, w, *to), dtype: inputs[0].dtype })
        }
    }
}

fn conv2d_type(
    x: &TensorTy,
    w: &TensorTy,
    stride: usize,
    padding: usize,
    layout: Layout,
) -> Result<TensorTy> {
    let out_dtype = match (x.dtype, w.dtype) {
        (IrDType::F32, IrDType::F32) => IrDType::F32,
        (IrDType::S8, IrDType::S8) => IrDType::S32,
        other => return Err(anyhow!("conv dtypes {:?}", other)),
    };
    let (n, c, h, wd) = dims_of(&x.shape, layout)?;
    let (k, cw, r, s) = match layout {
        Layout::Nchw => {
            if w.shape.len() != 4 {
                return Err(anyhow!("OIHW weight rank {:?}", w.shape));
            }
            (w.shape[0], w.shape[1], w.shape[2], w.shape[3])
        }
        Layout::Nhwc => {
            if w.shape.len() != 4 {
                return Err(anyhow!("HWIO weight rank {:?}", w.shape));
            }
            (w.shape[3], w.shape[2], w.shape[0], w.shape[1])
        }
        Layout::Nchwc(cb) => {
            // OIHW{i}{o}: (K/kb, C/cb, R, S, cb, kb).  The output tensor is
            // typed with the *input* block size, so the filter block must
            // equal it (kb == cb) or every downstream op would misindex.
            if w.shape.len() != 6 || w.shape[4] != cb || w.shape[5] != cb {
                return Err(anyhow!("OIHWio weight shape {:?} (cb={})", w.shape, cb));
            }
            (
                w.shape[0] * w.shape[5],
                w.shape[1] * w.shape[4],
                w.shape[2],
                w.shape[3],
            )
        }
    };
    if c != cw {
        return Err(anyhow!("conv channel mismatch {} vs {}", c, cw));
    }
    let oh = conv_out_size(h, r, stride, padding);
    let ow = conv_out_size(wd, s, stride, padding);
    Ok(TensorTy { shape: shape_of(n, k, oh, ow, layout), dtype: out_dtype })
}

pub fn dims_of(shape: &[usize], layout: Layout) -> Result<(usize, usize, usize, usize)> {
    match layout {
        Layout::Nchw => {
            if shape.len() != 4 {
                return Err(anyhow!("NCHW rank {:?}", shape));
            }
            Ok((shape[0], shape[1], shape[2], shape[3]))
        }
        Layout::Nhwc => {
            if shape.len() != 4 {
                return Err(anyhow!("NHWC rank {:?}", shape));
            }
            Ok((shape[0], shape[3], shape[1], shape[2]))
        }
        Layout::Nchwc(cb) => {
            if shape.len() != 5 || shape[4] != cb {
                return Err(anyhow!("NCHW{}c rank {:?}", cb, shape));
            }
            Ok((shape[0], shape[1] * cb, shape[2], shape[3]))
        }
    }
}

/// Flat element offset of logical coordinate `(ni, ci, y, x)` in a tensor
/// of logical dims `(C, H, W)` stored under `layout`.  One source of truth
/// for the index arithmetic the kernels and the interpreter share.
#[inline]
pub fn layout_offset(
    layout: Layout,
    c: usize,
    h: usize,
    w: usize,
    ni: usize,
    ci: usize,
    y: usize,
    x: usize,
) -> usize {
    match layout {
        Layout::Nchw => ((ni * c + ci) * h + y) * w + x,
        Layout::Nhwc => ((ni * h + y) * w + x) * c + ci,
        Layout::Nchwc(cb) => {
            ((((ni * (c / cb)) + ci / cb) * h + y) * w + x) * cb + ci % cb
        }
    }
}

pub fn shape_of(n: usize, c: usize, h: usize, w: usize, layout: Layout) -> Vec<usize> {
    match layout {
        Layout::Nchw => vec![n, c, h, w],
        Layout::Nhwc => vec![n, h, w, c],
        Layout::Nchwc(cb) => vec![n, c / cb, h, w, cb],
    }
}

impl Graph {
    /// Add the (single) graph input with an explicit type.
    pub fn add_input(&mut self, name: impl Into<String>, ty: TensorTy) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.into(), op: Op::Input, inputs: vec![], ty });
        self.input = id;
        id
    }

    /// Add an f32 constant with an explicit shape.
    pub fn add_const_f32(
        &mut self,
        name: impl Into<String>,
        shape: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<NodeId> {
        if shape.iter().product::<usize>() != values.len() {
            return Err(anyhow!("const shape {:?} != {} values", shape, values.len()));
        }
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.into(),
            op: Op::Constant(ConstValue::F32(Arc::new(values))),
            inputs: vec![],
            ty: TensorTy { shape, dtype: IrDType::F32 },
        });
        Ok(id)
    }

    /// Clone a node from another graph with remapped inputs, preserving
    /// explicit types for inputs/constants and re-inferring the rest.
    pub fn add_clone(&mut self, node: &Node, inputs: Vec<NodeId>) -> Result<NodeId> {
        match &node.op {
            Op::Input => Ok(self.add_input(node.name.clone(), node.ty.clone())),
            Op::Constant(_) => {
                let id = self.nodes.len();
                self.nodes.push(Node {
                    id,
                    name: node.name.clone(),
                    op: node.op.clone(),
                    inputs: vec![],
                    ty: node.ty.clone(),
                });
                Ok(id)
            }
            _ => self.add(node.name.clone(), node.op.clone(), inputs),
        }
    }

    /// Add an int8 constant (quantized weights).
    pub fn add_const_i8(
        &mut self,
        name: impl Into<String>,
        shape: Vec<usize>,
        values: Vec<i8>,
    ) -> Result<NodeId> {
        if shape.iter().product::<usize>() != values.len() {
            return Err(anyhow!("const shape {:?} != {} values", shape, values.len()));
        }
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.into(),
            op: Op::Constant(ConstValue::I8(Arc::new(values))),
            inputs: vec![],
            ty: TensorTy { shape, dtype: IrDType::S8 },
        });
        Ok(id)
    }
}
