//! Relay-like graph IR + the graph-level optimization layer.
//!
//! "TVM comprises two optimization layers.  The first layer focuses on
//! computation graph optimization, addressing high-level dataflow
//! rewriting." (§1.1.2)  This module is that layer, rebuilt: a dataflow IR
//! over typed tensors, a reference interpreter (the semantic oracle the
//! pass tests check against), and the passes the paper's analysis leans on —
//! operator fusion, constant folding, layout transformation (Figure 1), and
//! the quantize annotate/calibrate/realize pipeline.
//!
//! The compiled artifacts the executors run are produced by the *python*
//! compile path; this rust IR is the in-process counterpart used by the
//! `tvmq compile` pipeline demo, the pass ablations, and the Figure-1
//! bench — i.e. the substrate TVM provides that the paper's experiments
//! assume.

pub mod builder;
pub mod compile;
pub mod interp;
pub mod ir;
pub mod kernels;
pub mod passes;

pub use builder::{
    build_conv_net, build_resnet_ir, build_resnet_ir_in, calibrate_ir, rebatch_graph, NetSpec,
    StageSpec,
};
pub use compile::{
    compile_calls, compile_graph, compile_graph_with, AnchorOp, ClassKey, CompiledGraph,
    MicroKernel, PackedWeight, ScheduleOverrides, ShapeKey, StepSched,
};
pub use interp::evaluate;
pub use ir::{Graph, IrDType, Layout, Node, NodeId, Op, TensorTy};
