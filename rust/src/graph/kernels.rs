//! Shared slice-level operator cores.
//!
//! The reference interpreter ([`super::interp`]) and the arena executor
//! ([`crate::executor::ArenaExec`]) must agree **bit-for-bit**; for the
//! elementwise/pooling operators whose loop order is the entire semantic
//! content, keeping two hand-synchronized twin loops was an invitation to
//! drift (ROADMAP item).  These cores are that single source of truth:
//! the interpreter calls them through allocating wrappers, the executor
//! through pre-placed arena windows.  All index arithmetic goes through
//! [`super::ir::layout_offset`].
//!
//! # Layout semantics
//!
//! Every core takes the tensor's [`Layout`] and a *logical* channel
//! vocabulary: channel `c` of an `NCHW{cb}c` tensor lives at block
//! `c / cb`, lane `c % cb`, and per-channel operands (the bias vector)
//! are always indexed by the logical channel — one `[C]` constant serves
//! all three layouts.  Spatial walks use [`layout_offset`], so a kernel
//! body is layout-blind; only the stride pattern (and therefore speed)
//! changes.  Conv kernels live with their tiers (the interpreter's naive
//! loops, the executor's banded ones), but both index identically:
//! NCHW/NCHW{c} weights are OIHW / OIHW{i}{o}, NHWC weights are HWIO,
//! and int8 convs accumulate in i32 in every layout.

use anyhow::Result;

use super::ir::{dims_of, Layout, layout_offset};

/// Per-channel bias: `out[i] = x[i] + b[channel(i)]` under `layout`.
pub fn bias_add_f32(
    x: &[f32], xs: &[usize], b: &[f32], layout: Layout, out: &mut [f32],
) -> Result<()> {
    let (_, c, _, _) = dims_of(xs, layout)?;
    match layout {
        Layout::Nchw => {
            let hw = xs[2] * xs[3];
            for (i, d) in out.iter_mut().enumerate() {
                *d = x[i] + b[(i / hw) % c];
            }
        }
        Layout::Nhwc => {
            for (i, d) in out.iter_mut().enumerate() {
                *d = x[i] + b[i % c];
            }
        }
        Layout::Nchwc(cb) => {
            let hw = xs[2] * xs[3];
            let co = xs[1];
            for (i, d) in out.iter_mut().enumerate() {
                let ci = i % cb;
                let oc = (i / (cb * hw)) % co;
                *d = x[i] + b[oc * cb + ci];
            }
        }
    }
    Ok(())
}

/// Windowed max pooling; every output element is written.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_f32(
    x: &[f32], xs: &[usize], window: usize, stride: usize, padding: usize,
    layout: Layout, out: &mut [f32], os: &[usize],
) -> Result<()> {
    let (n, c, h, w) = dims_of(xs, layout)?;
    let (_, _, oh, ow) = dims_of(os, layout)?;
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for ry in 0..window {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        for rx in 0..window {
                            let ix = ox * stride + rx;
                            if ix < padding || ix >= w + padding {
                                continue;
                            }
                            m = m.max(
                                x[layout_offset(
                                    layout, c, h, w, ni, ci, iy - padding, ix - padding,
                                )],
                            );
                        }
                    }
                    out[layout_offset(layout, c, oh, ow, ni, ci, oy, ox)] = m;
                }
            }
        }
    }
    Ok(())
}

/// Global average pooling to `(N, C)`; accumulation order is h-major,
/// which is observable in f32 and therefore fixed here for both tiers.
pub fn global_avgpool_f32(
    x: &[f32], xs: &[usize], layout: Layout, out: &mut [f32],
) -> Result<()> {
    let (n, c, h, w) = dims_of(xs, layout)?;
    for ni in 0..n {
        for ci in 0..c {
            let mut s = 0f32;
            for y in 0..h {
                for xx in 0..w {
                    s += x[layout_offset(layout, c, h, w, ni, ci, y, xx)];
                }
            }
            out[ni * c + ci] = s / (h * w) as f32;
        }
    }
    Ok(())
}
