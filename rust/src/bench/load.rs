//! `bench-serve --load` — open-loop load generation against the sharded
//! serving tier.
//!
//! The closed-loop `serve_bench` clients (submit, wait, submit again)
//! self-throttle: when the server slows down, so do they, which hides
//! exactly the tail behavior a serving tier is judged on.  This harness
//! replays a **seeded arrival trace** instead — requests are submitted at
//! their scheduled instants whether or not earlier ones have finished —
//! so queueing delay, load shedding, and the p999 tail are all visible.
//!
//! Two trace shapes per run, same offered rate:
//!
//! - **poisson** — exponential inter-arrivals (`-ln(1-u)/λ`, seeded), the
//!   standard memoryless open-loop workload;
//! - **bursty** — the same mean rate delivered as back-to-back bursts
//!   with idle gaps, the worst case for head-of-line blocking and the
//!   shape that exercises admission shedding.
//!
//! Every reply's logits are compared **bit-for-bit** against
//! `graph::interp::evaluate` on the factory's own template graph — a
//! load run that returns wrong answers fails, it does not get to report
//! a throughput.  Shed submissions (typed [`Rejected::Overloaded`]) are
//! counted into the shed rate; they are the backpressure working, not
//! errors.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{InferenceServer, PendingReply, Rejected, ServeConfig, WaitError};
use crate::executor::{EngineKind, EngineSpec, NativeArenaFactory};
use crate::graph::evaluate;
use crate::metrics::{fmt_ms, EpochStats, Table};
use crate::runtime::{synthetic_images, TensorData};
use crate::telem::{DriftConfig, GaugeId, HistId, Telemetry};
use crate::util::rng::Rng64;

/// Distinct request images per run; oracle logits are precomputed once
/// per image and every reply is checked against its image's oracle.
const LOAD_IMAGES: usize = 8;

/// Reply-collector fan-in threads (the submitter round-robins pending
/// replies across them so waiting never backpressures the trace clock).
const COLLECTORS: usize = 4;

/// How long a collector waits for any single reply before calling it a
/// client-side timeout.  Generous: a healthy run never hits it.
const COLLECT_WAIT: Duration = Duration::from_secs(30);

/// One load run's shape.
#[derive(Debug, Clone)]
pub struct LoadOpts {
    pub buckets: Vec<usize>,
    pub image: usize,
    pub threads: usize,
    pub workers: usize,
    pub queue_bound: usize,
    pub batch_timeout: Duration,
    /// Offered rate, requests/second (both traces share it).
    pub rate_rps: f64,
    /// Requests per trace.
    pub requests: usize,
    /// Burst size for the bursty trace.
    pub burst: usize,
    pub seed: u64,
}

impl LoadOpts {
    /// CI smoke shape: 2 workers, a short bounded trace, and a queue
    /// bound tight enough that the bursty trace actually exercises the
    /// shedding path on most machines.
    pub fn quick() -> Self {
        LoadOpts {
            buckets: vec![1, 4, 8],
            image: 16,
            threads: 1,
            workers: 2,
            queue_bound: 32,
            batch_timeout: Duration::from_millis(2),
            rate_rps: 2000.0,
            requests: 600,
            burst: 48,
            seed: 7,
        }
    }
}

/// One trace's results — the machine-readable perf record.
#[derive(Debug, Clone)]
pub struct LoadRow {
    pub trace: String,
    pub offered: usize,
    /// Replies served OK (and oracle-verified).
    pub served: usize,
    /// Submissions shed at the admission gate.
    pub shed: usize,
    /// Everything else that went wrong, by kind.
    pub worker_died: usize,
    pub timeouts: usize,
    pub other_errors: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// Reply latency percentiles — `None` when the trace served nothing
    /// (e.g. everything shed), never silently 0.
    pub p50_ms: Option<f64>,
    pub p99_ms: Option<f64>,
    pub p999_ms: Option<f64>,
    pub shed_rate: f64,
    pub mean_batch: f64,
    /// Peak admission-queue depth observed by the registry during this
    /// trace (the `queue_depth_max` gauge, reset between traces).
    pub queue_depth_max: u64,
    /// Queue-wait percentiles from the registry's `queue_wait_us`
    /// histogram delta over this trace — `None` when no job was gathered.
    pub queue_wait_p50_ms: Option<f64>,
    pub queue_wait_p99_ms: Option<f64>,
}

/// Cumulative arrival offsets (seconds) with exponential inter-arrivals.
fn poisson_offsets(n: usize, rate_rps: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.f32() as f64;
            t += -(1.0 - u).ln() / rate_rps;
            t
        })
        .collect()
}

/// Same mean rate, delivered as back-to-back bursts separated by idle
/// gaps: `burst` arrivals at one instant, then silence for `burst/rate`.
fn bursty_offsets(n: usize, rate_rps: f64, burst: usize) -> Vec<f64> {
    let burst = burst.max(1);
    let gap = burst as f64 / rate_rps;
    (0..n).map(|i| (i / burst) as f64 * gap).collect()
}

struct TraceOutcome {
    served: usize,
    shed: usize,
    worker_died: usize,
    timeouts: usize,
    other_errors: usize,
    mismatches: usize,
    wall_s: f64,
    latencies_ms: Vec<f64>,
}

/// Replay one arrival trace open-loop against `server`, verifying every
/// reply against `oracle` (indexed like `images`).
fn run_trace(
    server: &Arc<InferenceServer>,
    images: &[TensorData],
    oracle: &Arc<Vec<TensorData>>,
    offsets: &[f64],
) -> Result<TraceOutcome> {
    type Pending = (usize, PendingReply, Instant);
    let mut txs: Vec<mpsc::Sender<Pending>> = Vec::with_capacity(COLLECTORS);
    let mut collectors = Vec::with_capacity(COLLECTORS);
    for _ in 0..COLLECTORS {
        let (tx, rx) = mpsc::channel::<Pending>();
        txs.push(tx);
        let oracle = Arc::clone(oracle);
        collectors.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            let (mut ok, mut died, mut timed_out, mut other, mut bad) = (0, 0, 0, 0, 0);
            while let Ok((idx, pending, t0)) = rx.recv() {
                match pending.wait_timeout(COLLECT_WAIT) {
                    Ok(reply) => {
                        lat.push(t0.elapsed().as_secs_f64() * 1e3);
                        // Bit-identical or it does not count as served.
                        if reply.logits.data == oracle[idx].data {
                            ok += 1;
                        } else {
                            bad += 1;
                        }
                    }
                    Err(e) => match e.downcast_ref::<WaitError>() {
                        Some(WaitError::WorkerDied) => died += 1,
                        Some(WaitError::Timeout) => timed_out += 1,
                        None => other += 1,
                    },
                }
            }
            (lat, ok, died, timed_out, other, bad)
        }));
    }

    let start = Instant::now();
    let mut shed = 0usize;
    let mut submit_other = 0usize;
    for (i, &off) in offsets.iter().enumerate() {
        // Open loop: hold to the trace clock, never to the server's pace.
        let target = start + Duration::from_secs_f64(off);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let idx = i % images.len();
        match server.submit(images[idx].clone()) {
            Ok(pending) => {
                let _ = txs[i % COLLECTORS].send((idx, pending, Instant::now()));
            }
            Err(e) => match e.downcast_ref::<Rejected>() {
                Some(Rejected::Overloaded { .. }) => shed += 1,
                _ => submit_other += 1,
            },
        }
    }
    drop(txs);

    let mut out = TraceOutcome {
        served: 0,
        shed,
        worker_died: 0,
        timeouts: 0,
        other_errors: submit_other,
        mismatches: 0,
        wall_s: 0.0,
        latencies_ms: Vec::new(),
    };
    for c in collectors {
        let (lat, ok, died, timed_out, other, bad) =
            c.join().map_err(|_| anyhow!("load collector panicked"))?;
        out.latencies_ms.extend(lat);
        out.served += ok;
        out.worker_died += died;
        out.timeouts += timed_out;
        out.other_errors += other;
        out.mismatches += bad;
    }
    out.wall_s = start.elapsed().as_secs_f64();
    Ok(out)
}

/// Run both traces against a fresh sharded server and report.  Fails on
/// any oracle mismatch, any client-side timeout, and (absent worker
/// faults there is nothing to die) any lost reply — shed submissions are
/// the only acceptable non-answers.
pub fn load_bench(opts: &LoadOpts) -> Result<(Table, Vec<LoadRow>)> {
    let spec = EngineSpec::new(EngineKind::Arena);
    let factory = NativeArenaFactory::new(spec, &opts.buckets, opts.image, opts.threads)?;
    let buckets = factory.buckets();

    // Seeded request images + their interpreter-oracle logits, computed
    // on the factory's OWN template graph (same weights the engines
    // compiled), before any load is offered.
    let g1 = factory.graph(1)?;
    let images: Vec<TensorData> = (0..LOAD_IMAGES)
        .map(|k| synthetic_images(1, &[3, opts.image, opts.image], opts.seed + k as u64))
        .collect();
    let oracle: Arc<Vec<TensorData>> = Arc::new(
        images.iter().map(|x| evaluate(&g1, x)).collect::<Result<_>>()?,
    );

    let cfg = ServeConfig {
        spec,
        max_batch: *buckets.last().expect("non-empty buckets"),
        batch_timeout: opts.batch_timeout,
        workers: opts.workers,
        queue_bound: opts.queue_bound,
    };
    // Telemetry spine: queue depth/wait come from the registry, not from
    // client-side clocks — the same cells `tvmq serve` exports.
    let telem = Telemetry::new(DriftConfig::default());
    let server = Arc::new(InferenceServer::start_with_telemetry(
        factory,
        cfg,
        Some(Arc::clone(&telem)),
    )?);

    let mut t = Table::new(
        format!(
            "bench-serve --load — open-loop arrival traces \
             ({} req @ {:.0} rps, {} worker(s), queue bound {}, buckets {:?}, image {})",
            opts.requests, opts.rate_rps, opts.workers, opts.queue_bound, buckets, opts.image
        ),
        &["Trace", "Served", "Shed", "Shed %", "Req/s", "p50 (ms)", "p99 (ms)",
          "p999 (ms)", "Mean batch", "Q depth max", "Q wait p50 (ms)",
          "Q wait p99 (ms)", "Errors"],
    );

    let traces: [(&str, Vec<f64>); 2] = [
        ("poisson", poisson_offsets(opts.requests, opts.rate_rps, opts.seed)),
        ("bursty", bursty_offsets(opts.requests, opts.rate_rps, opts.burst)),
    ];
    let mut rows = Vec::with_capacity(traces.len());
    for (name, offsets) in traces {
        let before = server.stats();
        telem.registry.gauge_reset(GaugeId::QueueDepthMax);
        let wait_before = telem.registry.hist(HistId::QueueWaitUs);
        let outcome = run_trace(&server, &images, &oracle, &offsets)?;
        let after = server.stats();
        let queue_depth_max = telem.registry.gauge(GaugeId::QueueDepthMax);
        let wait = telem.registry.hist(HistId::QueueWaitUs).delta(&wait_before);
        let wait_ms = |q: f64| wait.quantile(q).map(|us| us as f64 / 1e3);
        let (qw50, qw99) = (wait_ms(0.50), wait_ms(0.99));
        if outcome.mismatches > 0 {
            bail!(
                "{name}: {} replies were NOT bit-identical to the interpreter oracle",
                outcome.mismatches
            );
        }
        if outcome.timeouts > 0 || outcome.worker_died > 0 || outcome.other_errors > 0 {
            bail!(
                "{name}: {} timeouts, {} dead-worker replies, {} other errors \
                 (a fault-free load run may shed, never fail)",
                outcome.timeouts, outcome.worker_died, outcome.other_errors
            );
        }
        // A fully-shed trace has no latency samples; keep that typed
        // rather than reporting zeros.
        let lat = EpochStats::from_samples(&outcome.latencies_ms, 0);
        // Mean gathered batch over THIS trace's batches only.
        let d_req = after.requests.saturating_sub(before.requests);
        let d_batches = after.batches.saturating_sub(before.batches);
        let mean_batch =
            if d_batches == 0 { 0.0 } else { d_req as f64 / d_batches as f64 };
        let shed_rate = outcome.shed as f64 / offsets.len().max(1) as f64;
        let throughput = outcome.served as f64 / outcome.wall_s.max(1e-9);
        let dash = || "-".to_string();
        let opt_ms = |v: Option<f64>| v.map(fmt_ms).unwrap_or_else(dash);
        t.row(vec![
            name.into(),
            outcome.served.to_string(),
            outcome.shed.to_string(),
            format!("{:.1}%", 100.0 * shed_rate),
            format!("{throughput:.1}"),
            opt_ms(lat.map(|s| s.p50_ms)),
            opt_ms(lat.map(|s| s.p99_ms)),
            opt_ms(lat.map(|s| s.p999_ms)),
            format!("{mean_batch:.2}"),
            queue_depth_max.to_string(),
            opt_ms(qw50),
            opt_ms(qw99),
            (outcome.timeouts + outcome.worker_died + outcome.other_errors).to_string(),
        ]);
        rows.push(LoadRow {
            trace: name.into(),
            offered: offsets.len(),
            served: outcome.served,
            shed: outcome.shed,
            worker_died: outcome.worker_died,
            timeouts: outcome.timeouts,
            other_errors: outcome.other_errors,
            wall_s: outcome.wall_s,
            throughput_rps: throughput,
            p50_ms: lat.map(|s| s.p50_ms),
            p99_ms: lat.map(|s| s.p99_ms),
            p999_ms: lat.map(|s| s.p999_ms),
            shed_rate,
            mean_batch,
            queue_depth_max,
            queue_wait_p50_ms: qw50,
            queue_wait_p99_ms: qw99,
        });
    }

    // Cross-check the client-side ledger against the server's: every
    // offered request settled exactly one way.
    let stats = server.stats();
    let settled: usize = rows.iter().map(|r| r.served + r.shed).sum();
    if settled != 2 * opts.requests {
        bail!(
            "load ledger mismatch: {} served+shed across both traces, offered {} \
             (server saw {} ok / {} errors / {} shed)",
            settled, 2 * opts.requests, stats.requests, stats.errors, stats.shed
        );
    }
    Arc::try_unwrap(server)
        .map_err(|_| anyhow!("load clients still hold server handles"))?
        .shutdown()?;
    Ok((t, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_seeded_and_monotone() {
        let a = poisson_offsets(64, 500.0, 9);
        let b = poisson_offsets(64, 500.0, 9);
        assert_eq!(a, b, "same seed, same trace");
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "offsets must be non-decreasing");
        assert!(a[0] > 0.0);
        // Mean inter-arrival should land near 1/rate (loose bound: the
        // trace is short and the check only guards unit mistakes).
        let mean = a.last().unwrap() / a.len() as f64;
        assert!((0.2e-3..=10.0e-3).contains(&mean), "mean inter-arrival {mean}");
    }

    #[test]
    fn bursty_trace_groups_arrivals() {
        let off = bursty_offsets(10, 100.0, 4);
        // Bursts of 4 at t=0, t=0.04, t=0.08.
        assert_eq!(&off[..4], &[0.0; 4]);
        assert!(off[4] > 0.0 && (off[4] - 0.04).abs() < 1e-12);
        assert_eq!(off[4], off[7]);
        assert_eq!(off[8], off[9]);
    }
}
