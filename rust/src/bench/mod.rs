//! Paper-table harnesses: one function per table/figure, each printing the
//! same rows the paper reports (DESIGN.md §5).  Shared by the `tvmq
//! bench-*` CLI and the criterion benches.

mod load;

pub use load::{load_bench, LoadOpts, LoadRow};

use std::rc::Rc;

use anyhow::Result;

use crate::executor::{
    EngineKind, EngineSpec, Executor, GraphExecutor, LayoutTag, Precision, Schedule,
    VmExecutor,
};
use crate::manifest::Manifest;
use crate::metrics::{fmt_mib, fmt_ms, fmt_pct, improvement_pct, measure, EpochStats, Table};
use crate::perfmodel::{
    int8_alu_factor, resnet10_activation_bytes, resnet10_flops, roofline_fraction,
    schedule_table, MachineModel,
};
use crate::runtime::{synthetic_images, Runtime, TensorData};

/// Paper protocol defaults (§2.2): 110 epochs, 10 warm-up.  Overridable for
/// quick runs.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub epochs: usize,
    pub warmup: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { epochs: 110, warmup: 10 }
    }
}

impl BenchOpts {
    pub fn quick() -> Self {
        BenchOpts { epochs: 30, warmup: 5 }
    }
}

pub struct BenchCtx {
    pub rt: Rc<Runtime>,
    pub manifest: Manifest,
    pub opts: BenchOpts,
}

impl BenchCtx {
    pub fn new(artifacts: &std::path::Path, opts: BenchOpts) -> Result<Self> {
        Ok(BenchCtx {
            rt: Rc::new(Runtime::new()?),
            manifest: Manifest::load(artifacts)?,
            opts,
        })
    }

    fn image(&self, batch: usize, layout: LayoutTag) -> TensorData {
        let m = &self.manifest;
        // NHWC variants take channels-last images; NCHW and packed NCHWc
        // variants both take plain NCHW (the packed stem is unblocked).
        let rest = if layout == LayoutTag::Nhwc {
            vec![m.image_size, m.image_size, m.in_channels]
        } else {
            vec![m.in_channels, m.image_size, m.image_size]
        };
        synthetic_images(batch, &rest, 42)
    }

    fn bench_exec(&self, exec: &dyn Executor, layout: LayoutTag) -> Result<EpochStats> {
        let x = self.image(exec.batch(), layout);
        measure(self.opts.epochs, self.opts.warmup, || {
            exec.run(&x).map(|_| ())
        })
    }

    fn graph_exec(&self, spec: EngineSpec, batch: usize) -> Result<GraphExecutor> {
        let b = self.manifest.find(spec, batch)?;
        GraphExecutor::new(self.rt.clone(), &self.manifest, b)
    }

    fn vm_exec(
        &self,
        spec: EngineSpec,
        batch: usize,
        device_chaining: bool,
    ) -> Result<VmExecutor> {
        let b = self.manifest.find(spec, batch)?;
        VmExecutor::with_options(self.rt.clone(), &self.manifest, b, device_chaining)
    }
}

/// Shorthand for the bench combos: a typed spec from the three variant
/// axes plus the engine tier.
fn spec(
    layout: LayoutTag,
    schedule: Schedule,
    precision: Precision,
    engine: EngineKind,
) -> EngineSpec {
    EngineSpec { layout, schedule, precision, engine }
}

/// Row of a timing table.
#[derive(Debug, Clone)]
pub struct TimedRow {
    pub label: String,
    pub layout: String,
    pub schedule: String,
    pub precision: String,
    pub mean_ms: f64,
    pub improvement_pct: f64,
    /// Measured time with the int8 ALU-width factor applied (the mechanism
    /// the substrate cannot execute; perfmodel::int8_alu_factor).
    pub projected_ms: f64,
    pub projected_improvement_pct: f64,
}

fn project(mean_ms: f64, precision: Precision) -> f64 {
    if precision == Precision::Int8 {
        mean_ms / int8_alu_factor(&MachineModel::default())
    } else {
        mean_ms
    }
}

// ---------------------------------------------------------------------------
// Table 1: executor comparison (the bug + the fix)
// ---------------------------------------------------------------------------

pub fn table1(ctx: &BenchCtx) -> Result<(Table, Vec<TimedRow>)> {
    // Rows mirror the paper: eager fp32 / TVM fp32 / TVM-Quant (VM int8) /
    // TVM-Quant-Graph (graph int8).  The eager row runs the reference
    // schedule through the VM (per-op dispatch, no fusion) — the role
    // PyTorch plays in the paper's table.
    let eager = self_timed(ctx, || {
        let s = spec(LayoutTag::Nchw, Schedule::Reference, Precision::Fp32, EngineKind::Vm);
        Ok(Box::new(ctx.vm_exec(s, 1, false)?) as Box<dyn Executor>)
    }, LayoutTag::Nchw)?;
    let tvm_fp32 = self_timed(ctx, || {
        let s = spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Fp32, EngineKind::Graph);
        Ok(Box::new(ctx.graph_exec(s, 1)?) as Box<dyn Executor>)
    }, LayoutTag::Nchw)?;
    // The bug row: the VM partition loses AlterOpLayout (a graph-level
    // pass), so the quantized model runs the unpacked simd schedule per-op
    // under the VM's dispatch + dynamic allocation.
    let quant_vm = self_timed(ctx, || {
        let s = spec(LayoutTag::Nchw, Schedule::Simd, Precision::Int8, EngineKind::Vm);
        Ok(Box::new(ctx.vm_exec(s, 1, false)?) as Box<dyn Executor>)
    }, LayoutTag::Nchw)?;
    let quant_graph = self_timed(ctx, || {
        let s = spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8, EngineKind::Graph);
        Ok(Box::new(ctx.graph_exec(s, 1)?) as Box<dyn Executor>)
    }, LayoutTag::Nchw)?;

    let base = tvm_fp32.1.mean_ms;
    let mut rows = Vec::new();
    let mut t = Table::new(
        "Table 1 — ResNet inference: executor comparison (batch 1)",
        &["Framework", "Layout", "Schedule", "Precision", "Executor",
          "Time (ms)", "Improvement", "A72-proj (ms)", "Proj. improvement"],
    );
    for (label, stats, schedule_note, precision, executor) in [
        ("Eager (PyTorch row)", &eager.1, "reference", Precision::Fp32, "vm/per-op"),
        ("tvmq", &tvm_fp32.1, "spatial_pack", Precision::Fp32, "graph"),
        ("tvmq-Quant", &quant_vm.1, "simd (no alter-layout)", Precision::Int8, "vm"),
        ("tvmq-Quant-Graph", &quant_graph.1, "spatial_pack", Precision::Int8, "graph"),
    ] {
        let imp = improvement_pct(base, stats.mean_ms);
        let proj = project(stats.mean_ms, precision);
        let pimp = improvement_pct(base, proj);
        t.row(vec![
            label.into(), "NCHW".into(), schedule_note.into(), precision.to_string(),
            executor.into(), fmt_ms(stats.mean_ms),
            if label == "Eager (PyTorch row)" { "-".into() } else { fmt_pct(imp) },
            fmt_ms(proj),
            if label == "Eager (PyTorch row)" { "-".into() } else { fmt_pct(pimp) },
        ]);
        rows.push(TimedRow {
            label: label.into(), layout: "NCHW".into(), schedule: schedule_note.into(),
            precision: precision.to_string(), mean_ms: stats.mean_ms, improvement_pct: imp,
            projected_ms: proj, projected_improvement_pct: pimp,
        });
    }

    // The arena tier, same protocol: the native engine whose mechanism
    // (fusion + static plan) the graph-executor fix is made of.  Runs the
    // in-process IR model rather than the AOT artifacts, so its row is a
    // mechanism cross-check, not a like-for-like model timing.
    {
        use crate::executor::ArenaExec;
        use crate::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
        use crate::graph::{build_resnet_ir, calibrate_ir};
        let g = build_resnet_ir(1, 32, 7)?;
        let calib = calibrate_ir(&g, 1);
        let scales = calibrate_graph(&g, &calib)?;
        let qg = QuantizeRealize { scales }.run(&g)?;
        let exec = ArenaExec::with_options(&qg, true, 1)?;
        let x = calibrate_ir(&qg, 42);
        let stats = measure(ctx.opts.epochs, ctx.opts.warmup, || exec.run(&x).map(|_| ()))?;
        let imp = improvement_pct(base, stats.mean_ms);
        let proj = project(stats.mean_ms, Precision::Int8);
        let pimp = improvement_pct(base, proj);
        t.row(vec![
            "tvmq-Arena (IR engine)".into(), "NCHW".into(), "arena/fused".into(),
            "int8".into(), "arena".into(), fmt_ms(stats.mean_ms), fmt_pct(imp),
            fmt_ms(proj), fmt_pct(pimp),
        ]);
        rows.push(TimedRow {
            label: "tvmq-Arena".into(), layout: "NCHW".into(),
            schedule: "arena/fused".into(), precision: "int8".into(),
            mean_ms: stats.mean_ms, improvement_pct: imp, projected_ms: proj,
            projected_improvement_pct: pimp,
        });
    }
    Ok((t, rows))
}

fn self_timed(
    ctx: &BenchCtx,
    build: impl FnOnce() -> Result<Box<dyn Executor>>,
    layout: LayoutTag,
) -> Result<(Box<dyn Executor>, EpochStats)> {
    let exec = build()?;
    let stats = ctx.bench_exec(exec.as_ref(), layout)?;
    Ok((exec, stats))
}

// ---------------------------------------------------------------------------
// Table 2: schedule × layout × precision sweep (batch 1)
// ---------------------------------------------------------------------------

pub fn table2(ctx: &BenchCtx) -> Result<(Table, Vec<TimedRow>)> {
    let machine = MachineModel::default();
    let ideals = schedule_table(&machine);
    let combos = [
        spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Fp32, EngineKind::Graph),
        spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8, EngineKind::Graph),
        spec(LayoutTag::Nchw, Schedule::Simd, Precision::Int8, EngineKind::Graph),
        spec(LayoutTag::Nhwc, Schedule::SpatialPack, Precision::Fp32, EngineKind::Graph),
        spec(LayoutTag::Nhwc, Schedule::Interleaved, Precision::Int8, EngineKind::Graph),
    ];
    let mut rows = Vec::new();
    let mut t = Table::new(
        "Table 2 — batch-1 schedule comparison under the graph executor",
        &["Layout", "Schedule", "Precision", "Time (ms)", "Improvement",
          "A72-proj (ms)", "Proj. improvement", "Ideal Speedup"],
    );
    let mut base = None;
    for (i, &s) in combos.iter().enumerate() {
        let exec = ctx.graph_exec(s, 1)?;
        let stats = ctx.bench_exec(&exec, s.layout)?;
        let b = *base.get_or_insert(stats.mean_ms);
        let imp = improvement_pct(b, stats.mean_ms);
        let proj = project(stats.mean_ms, s.precision);
        let pimp = improvement_pct(b, proj);
        t.row(vec![
            s.layout.to_string(), s.schedule.to_string(), s.precision.to_string(),
            fmt_ms(stats.mean_ms), fmt_pct(imp), fmt_ms(proj), fmt_pct(pimp),
            format!("{}x", ideals[i].ideal_speedup),
        ]);
        rows.push(TimedRow {
            label: format!("{}/{}/{}", s.layout, s.schedule, s.precision),
            layout: s.layout.to_string(), schedule: s.schedule.to_string(),
            precision: s.precision.to_string(), mean_ms: stats.mean_ms,
            improvement_pct: imp, projected_ms: proj,
            projected_improvement_pct: pimp,
        });
    }
    Ok((t, rows))
}

// ---------------------------------------------------------------------------
// Table 3: batch-size sweep (memory-bound)
// ---------------------------------------------------------------------------

pub fn table3(ctx: &BenchCtx, batches: &[usize]) -> Result<(Table, Vec<TimedRow>)> {
    let mut rows = Vec::new();
    let mut t = Table::new(
        "Table 3 — batch sweep, best layout/schedule (NCHW spatial_pack)",
        &["Batch", "Memory (MiB)", "Precision", "Time/img (ms)", "Improvement",
          "A72-proj (ms)", "Proj. improvement"],
    );
    for &batch in batches {
        let mut base = None;
        for precision in [Precision::Fp32, Precision::Int8] {
            let s = spec(LayoutTag::Nchw, Schedule::SpatialPack, precision, EngineKind::Graph);
            let bundle = ctx.manifest.find(s, batch)?;
            let fp = crate::quant::footprint(&ctx.manifest, bundle);
            let exec = GraphExecutor::new(ctx.rt.clone(), &ctx.manifest, bundle)?;
            let stats = ctx.bench_exec(&exec, s.layout)?;
            let per_img = stats.mean_ms / batch as f64;
            let b = *base.get_or_insert(per_img);
            let imp = improvement_pct(b, per_img);
            let proj = project(per_img, precision);
            let pimp = improvement_pct(b, proj);
            t.row(vec![
                batch.to_string(),
                fmt_mib(fp.total()),
                precision.to_string(),
                fmt_ms(per_img),
                fmt_pct(imp),
                fmt_ms(proj),
                fmt_pct(pimp),
            ]);
            rows.push(TimedRow {
                label: format!("b{batch}/{precision}"),
                layout: "NCHW".into(), schedule: "spatial_pack".into(),
                precision: precision.to_string(), mean_ms: per_img, improvement_pct: imp,
                projected_ms: proj, projected_improvement_pct: pimp,
            });
        }
    }
    Ok((t, rows))
}

// ---------------------------------------------------------------------------
// Figure 1: NCHW{c} packing — locality measured in-process
// ---------------------------------------------------------------------------

pub fn figure1(reps: usize) -> Result<Table> {
    use crate::graph::interp::{conv2d_nchw_f32, conv2d_nchwc_f32};
    use crate::layout::{pack_nchwc, pack_oihw, render_packing_diagram, Nchw};
    use std::time::Instant;

    println!("{}", render_packing_diagram(64, 16));

    let (n, c, h, w, k, r) = (1usize, 64usize, 32usize, 32usize, 64usize, 3usize);
    let mut rng_state = 1234u64;
    let mut next = || {
        // xorshift — deterministic, no rand dep in hot loop
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        (rng_state as f64 / u64::MAX as f64) as f32 - 0.5
    };
    let x: Vec<f32> = (0..n * c * h * w).map(|_| next()).collect();
    let wts: Vec<f32> = (0..k * c * r * r).map(|_| next()).collect();
    let xt = TensorData::from_f32(vec![n, c, h, w], &x)?;
    let wt = TensorData::from_f32(vec![k, c, r, r], &wts)?;
    let out_shape = vec![n, k, h, w];

    let mut t = Table::new(
        "Figure 1 — NCHW vs NCHW{c} packed conv (same math, measured locality)",
        &["Variant", "c_block", "Time (ms)", "Speedup", "Pack overhead (ms)"],
    );

    // Unpacked baseline.
    let t0 = Instant::now();
    let mut sink = 0f32;
    for _ in 0..reps {
        let o = conv2d_nchw_f32(&xt, &wt, 1, 1, &out_shape)?;
        sink += o.as_f32()?[0];
    }
    let base_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    t.row(vec!["NCHW (unpacked)".into(), "-".into(), fmt_ms(base_ms), "1.00x".into(), "-".into()]);

    for cb in [4usize, 8, 16] {
        let kb = cb;
        let tp = Instant::now();
        let xp = pack_nchwc(&x, Nchw { n, c, h, w }, cb)?;
        let wp = pack_oihw(&wts, k, c, r, r, cb, kb)?;
        let pack_ms = tp.elapsed().as_secs_f64() * 1e3;
        let xpt = TensorData::from_f32(vec![n, c / cb, h, w, cb], &xp)?;
        let wpt = TensorData::from_f32(vec![k / kb, c / cb, r, r, cb, kb], &wp)?;
        let po_shape = vec![n, k / kb, h, w, kb];
        let t1 = Instant::now();
        for _ in 0..reps {
            let o = conv2d_nchwc_f32(&xpt, &wpt, 1, 1, cb, &po_shape)?;
            sink += o.as_f32()?[0];
        }
        let ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;
        t.row(vec![
            format!("NCHW{cb}c (packed)"),
            cb.to_string(),
            fmt_ms(ms),
            format!("{:.2}x", base_ms / ms),
            format!("{pack_ms:.2}"),
        ]);
    }
    std::hint::black_box(sink);
    Ok(t)
}

// ---------------------------------------------------------------------------
// Ablations (§3 analysis claims)
// ---------------------------------------------------------------------------

pub fn ablations(ctx: &BenchCtx) -> Result<Table> {
    let mut t = Table::new(
        "Ablations — isolating the executor-gap mechanisms (batch 1, int8 best schedule)",
        &["Config", "Time (ms)", "Dispatches/inf", "Dyn allocs/inf", "Boundary KiB/inf"],
    );

    let best_graph =
        spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8, EngineKind::Graph);
    let best_vm = spec(LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8, EngineKind::Vm);

    // (a) graph executor (fused, static plan)
    let g = ctx.graph_exec(best_graph, 1)?;
    let gs = ctx.bench_exec(&g, LayoutTag::Nchw)?;
    let gc = g.counters();
    let per = |v: u64| v as f64 / gc.invocations.max(1) as f64;
    t.row(vec![
        "graph (fused module)".into(), fmt_ms(gs.mean_ms),
        format!("{:.0}", per(gc.dispatches)), format!("{:.0}", per(gc.dynamic_allocs)),
        "0".into(),
    ]);

    // (b) VM, host boundaries (the faithful bug)
    let v = ctx.vm_exec(best_vm, 1, false)?;
    let vs = ctx.bench_exec(&v, LayoutTag::Nchw)?;
    let vc = v.counters();
    let perv = |x: u64| x as f64 / vc.invocations.max(1) as f64;
    t.row(vec![
        "vm (host boundaries)".into(), fmt_ms(vs.mean_ms),
        format!("{:.0}", perv(vc.dispatches)), format!("{:.0}", perv(vc.dynamic_allocs)),
        format!("{:.1}", perv(vc.boundary_bytes) / 1024.0),
    ]);

    // (c) VM with device chaining (staging removed, dispatch kept)
    let vd = ctx.vm_exec(best_vm, 1, true)?;
    let vds = ctx.bench_exec(&vd, LayoutTag::Nchw)?;
    let vdc = vd.counters();
    let perd = |x: u64| x as f64 / vdc.invocations.max(1) as f64;
    t.row(vec![
        "vm (device chaining)".into(), fmt_ms(vds.mean_ms),
        format!("{:.0}", perd(vdc.dispatches)), format!("{:.0}", perd(vdc.dynamic_allocs)),
        "0".into(),
    ]);

    // (d) VM on fp32 (the executor penalty exists without quantization)
    let vf = ctx.vm_exec(best_vm.precision(Precision::Fp32), 1, false)?;
    let vfs = ctx.bench_exec(&vf, LayoutTag::Nchw)?;
    t.row(vec![
        "vm fp32 (no quant)".into(), fmt_ms(vfs.mean_ms), "-".into(), "-".into(), "-".into(),
    ]);

    Ok(t)
}

/// One measured arena-ablation variant — the machine-readable perf record
/// behind `bench-arena --json` (ns/iter so trajectory diffs keep sub-ms
/// moves).  `config` is the human row label; interpreter rows carry
/// `steps == 0`.  `schedule` is `"default"` or `"tuned"`, and for tuned
/// rows `knobs` names the chosen knob values, so the perf trajectory can
/// attribute wins to specific knobs.
#[derive(Debug, Clone)]
pub struct ArenaRow {
    pub batch: usize,
    pub layout: String,
    pub precision: String,
    pub config: String,
    pub fused: bool,
    pub threads: usize,
    pub schedule: String,
    pub knobs: String,
    pub mean_ms: f64,
    pub ns_per_iter: f64,
    pub steps: usize,
    pub fused_chains: usize,
    pub arena_bytes: usize,
    /// Cold engine construction time (ms): the `graph::compile` path this
    /// row's engine actually took.  0 for interpreter rows.
    pub compile_ms: f64,
    /// Warm-start construction time (ms): the same program rebuilt
    /// through an in-memory compile-cache round-trip (serialize → parse →
    /// validate → [`crate::executor::ArenaExec::from_compiled`]) — what
    /// `serve --cache-dir` pays on a hit instead of compiling.  0 for
    /// interpreter rows.
    pub compile_cached_ms: f64,
    /// Register-tile geometry the compiled steps with a pre-packed panel
    /// actually run under (`m{mr}n{nr}k{ku}`, `+`-joined when mixed);
    /// `"-"` means every anchor ran the scalar loops.
    pub micro: String,
    /// Achieved effective bandwidth (GiB/s) against the perfmodel's
    /// activation-traffic estimate for this cell's workload.
    pub gibs: f64,
    /// Achieved int8 MAC-op rate (ops/s) against the perfmodel's FLOP
    /// count; 0 for fp32 rows.
    pub int8_ops_per_s: f64,
    /// Fraction of [`crate::perfmodel::roofline_ms`] this row achieves
    /// (1.0 = at the model's bound) — the machine-readable
    /// compute-bound vs memory-bound contrast.
    pub roofline_frac: f64,
    /// Per-step attribution of this row's engine (a few profiled
    /// inferences after the timed measurement, so the timing itself is
    /// unaffected): ns per fused step keyed by op/shape/layout/precision/
    /// ISA/micro — the `bench-arena --json` per-step breakdown.  Empty
    /// for interpreter rows.
    pub step_rows: Vec<crate::telem::ProfileRow>,
}

/// Profile one engine's steps: attach a fresh sink, run a few sampled
/// inferences, detach.  Runs *after* the timed measurement so the row's
/// reported latency never includes profiling clocks.
fn profile_steps(
    exec: &mut crate::executor::ArenaExec,
    x: &TensorData,
) -> Result<Vec<crate::telem::ProfileRow>> {
    let sink = crate::telem::ProfileSink::new();
    exec.set_profiling(1, &sink);
    for _ in 0..3 {
        exec.run(x)?;
    }
    exec.set_profiling(0, &sink);
    Ok(sink.rows())
}

/// The register-tile token a compiled program actually runs under: the
/// distinct `micro` geometries of steps that carry a pre-packed weight
/// panel, sorted and `+`-joined (`"-"` = all scalar loops).  This is the
/// field the CI smoke greps to prove every JSON row records its chosen
/// tile knobs.
fn micro_summary(cg: &crate::graph::CompiledGraph) -> String {
    use crate::tune::micro_str;
    let mut ms: Vec<crate::graph::MicroKernel> = cg
        .steps
        .iter()
        .filter(|s| s.packed.is_some())
        .filter_map(|s| s.sched.micro)
        .collect();
    ms.sort();
    ms.dedup();
    if ms.is_empty() {
        "-".into()
    } else {
        ms.iter().map(|&m| micro_str(Some(m))).collect::<Vec<String>>().join("+")
    }
}

/// Analytic achieved-rate metrics for one row: (GiB/s, int8 ops/s,
/// roofline fraction).  Workload terms come from the perfmodel (same
/// flops/bytes the tuner's prior uses), so the numbers are comparable
/// across rows and across PRs, not a per-row instrumentation.
fn row_metrics(image: usize, batch: usize, int8: bool, mean_ms: f64) -> (f64, f64, f64) {
    let m = MachineModel::default();
    let flops = resnet10_flops(image) * batch as f64;
    let bytes =
        resnet10_activation_bytes(image, if int8 { 1.0 } else { 4.0 }) * batch as f64;
    let secs = mean_ms / 1e3;
    if secs <= 0.0 {
        return (0.0, 0.0, 0.0);
    }
    let gibs = bytes / secs / (1u64 << 30) as f64;
    let ops = if int8 { flops / secs } else { 0.0 };
    (gibs, ops, roofline_fraction(&m, flops, bytes, int8, mean_ms))
}

/// Time the warm-start build path for an already-compiled engine: a full
/// in-memory cache round-trip.  Serialization is excluded (that cost is
/// paid at store time, not on the hit path); parse + validation against
/// the graph + arena wrap-up are included.
fn cached_build_ms(
    exec: &crate::executor::ArenaExec,
    g: &crate::graph::Graph,
    ovr: &crate::graph::ScheduleOverrides,
    fuse: bool,
    threads: usize,
) -> Result<f64> {
    use crate::cache::store::{compiled_from_json, compiled_to_json};
    use crate::cache::CacheKey;
    use crate::util::json::Json;

    let key = CacheKey::of(g, ovr, fuse, threads);
    let text = compiled_to_json(exec.compiled(), &key).to_string_pretty();
    let t0 = std::time::Instant::now();
    let j = Json::parse(&text)?;
    let cg = compiled_from_json(&j, g, &key)?;
    let warm = crate::executor::ArenaExec::from_compiled(cg, threads)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    debug_assert_eq!(warm.compiled().steps.len(), exec.compiled().steps.len());
    Ok(ms)
}

/// Where `bench-arena --tuned` gets each cell's tuned schedule from: a
/// persisted records file (applied to every cell; classes the file
/// doesn't know fall back to the default schedule) or an inline
/// micro-tune per cell (small budget, deterministic per-cell seed).
pub enum TunedSource<'a> {
    Records(&'a crate::tune::TuneRecords),
    Inline { budget: usize, seed: u64 },
}

fn layout_label(layout: crate::graph::Layout) -> String {
    use crate::graph::Layout;
    match layout {
        Layout::Nchw => "NCHW".into(),
        Layout::Nhwc => "NHWC".into(),
        Layout::Nchwc(cb) => format!("NCHW{cb}c"),
    }
}

/// Arena-executor ablation: the full **layout × precision matrix**
/// (NCHW / NHWC / NCHW{c}, fp32 / int8, fused / unfused) of the native
/// static-plan engine, against the naive per-node-allocating interpreter
/// baseline.  Runs entirely in-process (no AOT artifacts, no PJRT) — the
/// paper's best-row contrast (packed-layout int8 vs plain fp32)
/// reproduced natively: the same seeded model function in every layout,
/// so row differences are storage and fusion, not weights.
///
/// `force_micro` pins the default-schedule rows to the register-blocked
/// int8 microkernels (`MicroKernel::default()` on every anchor; inert on
/// fp32 rows, which have no int8 panel to pre-pack) — the CI smoke runs
/// the matrix both ways so the scalar loops and the blocked tiles are
/// both exercised on every merge.  Tuned rows keep whatever geometry the
/// records/search chose.
pub fn arena_ablation(
    opts: &BenchOpts,
    batches: &[usize],
    image: usize,
    threads: usize,
    tuned: Option<&TunedSource<'_>>,
    force_micro: bool,
) -> Result<(Table, Vec<ArenaRow>)> {
    use crate::executor::factory::ARENA_PACK_BLOCK;
    use crate::executor::ArenaExec;
    use crate::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
    use crate::graph::{build_resnet_ir_in, calibrate_ir, evaluate, Layout};
    use crate::metrics::fmt_speedup;

    let layouts = [Layout::Nchw, Layout::Nhwc, Layout::Nchwc(ARENA_PACK_BLOCK)];
    let mut rows: Vec<ArenaRow> = Vec::new();
    let mut t = Table::new(
        format!(
            "Arena ablation — layout × precision matrix, arena vs interpreter \
             (resnet10 IR, image {image}, {} epochs, {} thread{})",
            opts.epochs,
            threads,
            if threads == 1 { "" } else { "s" }
        ),
        &["Batch", "Layout", "Config", "Time (ms)", "Speedup", "Steps",
          "Arena KiB", "Fused", "Micro"],
    );
    let kib = |b: usize| format!("{:.1}", b as f64 / 1024.0);
    // The schedule table the default (non-tuned) arena rows compile
    // under: hard-coded defaults, or the same with the register-blocked
    // microkernel geometry pinned on every anchor.
    let default_ovr = {
        let mut ovr = crate::graph::ScheduleOverrides::default();
        if force_micro {
            ovr.default_sched.micro = Some(crate::graph::MicroKernel::default());
        }
        ovr
    };
    for &batch in batches {
        // The NCHW fp32 interpreter is the cross-layout baseline; the
        // interp int8 row keeps the paper's unfused-q/dq contrast visible.
        let mut base_ms = f64::NAN;
        for (li, layout) in layouts.into_iter().enumerate() {
            let lname = layout_label(layout);
            let g = build_resnet_ir_in(batch, image, 7, layout)?;
            let x = calibrate_ir(&g, 42);
            let scales = calibrate_graph(&g, &x)?;
            let qg = QuantizeRealize { scales }.run(&g)?;

            if layout == Layout::Nchw {
                let base = measure(opts.epochs, opts.warmup, || evaluate(&g, &x).map(|_| ()))?;
                base_ms = base.mean_ms;
                t.row(vec![
                    batch.to_string(), lname.clone(), "interp fp32 (oracle)".into(),
                    fmt_ms(base.mean_ms), fmt_speedup(1.0), "-".into(), "-".into(),
                    "-".into(), "-".into(),
                ]);
                let (gibs, ops, rf) = row_metrics(image, batch, false, base.mean_ms);
                rows.push(ArenaRow {
                    batch, layout: lname.clone(), precision: "fp32".into(),
                    config: "interp fp32 (oracle)".into(), fused: false, threads: 1,
                    schedule: "default".into(), knobs: "-".into(),
                    mean_ms: base.mean_ms, ns_per_iter: base.mean_ms * 1e6, steps: 0,
                    fused_chains: 0, arena_bytes: 0,
                    compile_ms: 0.0, compile_cached_ms: 0.0,
                    micro: "-".into(), gibs, int8_ops_per_s: ops, roofline_frac: rf,
                    step_rows: vec![],
                });

                let qi = measure(opts.epochs, opts.warmup, || evaluate(&qg, &x).map(|_| ()))?;
                t.row(vec![
                    batch.to_string(), lname.clone(), "interp int8 (unfused q/dq)".into(),
                    fmt_ms(qi.mean_ms), fmt_speedup(base.mean_ms / qi.mean_ms),
                    "-".into(), "-".into(), "0".into(), "-".into(),
                ]);
                let (gibs, ops, rf) = row_metrics(image, batch, true, qi.mean_ms);
                rows.push(ArenaRow {
                    batch, layout: lname.clone(), precision: "int8".into(),
                    config: "interp int8 (unfused q/dq)".into(), fused: false, threads: 1,
                    schedule: "default".into(), knobs: "-".into(),
                    mean_ms: qi.mean_ms, ns_per_iter: qi.mean_ms * 1e6, steps: 0,
                    fused_chains: 0, arena_bytes: 0,
                    compile_ms: 0.0, compile_cached_ms: 0.0,
                    micro: "-".into(), gibs, int8_ops_per_s: ops, roofline_frac: rf,
                    step_rows: vec![],
                });
            }

            for (precision, graph) in [("fp32", &g), ("int8", &qg)] {
                for fuse in [false, true] {
                    let label = format!(
                        "arena {precision} ({})",
                        if fuse { "fused" } else { "unfused" }
                    );
                    let t0 = std::time::Instant::now();
                    let mut exec =
                        ArenaExec::with_schedule(graph, fuse, threads, &default_ovr)?;
                    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let compile_cached_ms =
                        cached_build_ms(&exec, graph, &default_ovr, fuse, threads)?;
                    let stats =
                        measure(opts.epochs, opts.warmup, || exec.run(&x).map(|_| ()))?;
                    let step_rows = profile_steps(&mut exec, &x)?;
                    let cg = exec.compiled();
                    let micro = micro_summary(cg);
                    t.row(vec![
                        batch.to_string(), lname.clone(), label.clone(),
                        fmt_ms(stats.mean_ms), fmt_speedup(base_ms / stats.mean_ms),
                        cg.steps.len().to_string(),
                        kib(cg.arena_bytes),
                        cg.fused_chains.to_string(),
                        micro.clone(),
                    ]);
                    let (gibs, ops, rf) =
                        row_metrics(image, batch, precision == "int8", stats.mean_ms);
                    rows.push(ArenaRow {
                        batch, layout: lname.clone(), precision: precision.into(),
                        config: label, fused: fuse, threads,
                        schedule: "default".into(), knobs: "-".into(),
                        mean_ms: stats.mean_ms, ns_per_iter: stats.mean_ms * 1e6,
                        steps: cg.steps.len(), fused_chains: cg.fused_chains,
                        arena_bytes: cg.arena_bytes,
                        compile_ms, compile_cached_ms,
                        micro, gibs, int8_ops_per_s: ops, roofline_frac: rf,
                        step_rows,
                    });
                }

                // The tuned row for this layout × precision cell: same
                // model, schedule chosen by records or an inline
                // micro-tune; oracle-exactness is guaranteed by the
                // tuner's measurer (records) or re-checked at build time
                // (inline, via the measurer again).
                if let Some(src) = tuned {
                    let (fuse, ovr, knobs) = match src {
                        TunedSource::Records(r) => {
                            (r.fuse, r.overrides(threads), r.knob_summary())
                        }
                        TunedSource::Inline { budget, seed } => {
                            // A distinct deterministic seed per cell so
                            // the cells don't all walk the same sample
                            // sequence.
                            let cell_seed = *seed
                                ^ (batch as u64).wrapping_mul(0x9E37_79B9)
                                ^ ((li as u64) << 17)
                                ^ (((precision == "int8") as u64) << 40);
                            let outcome = crate::tune::tune_graph(
                                graph,
                                x.clone(),
                                &crate::tune::TuneOptions {
                                    budget: (*budget).max(2),
                                    seed: cell_seed,
                                    threads,
                                    warmup: 1,
                                    iters: 3,
                                    use_prior: true,
                                },
                            )?;
                            let plan = outcome.best.plan;
                            (plan.fuse, plan.overrides(threads), plan.describe())
                        }
                    };
                    let t0 = std::time::Instant::now();
                    let mut exec = ArenaExec::with_schedule(graph, fuse, threads, &ovr)?;
                    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let compile_cached_ms =
                        cached_build_ms(&exec, graph, &ovr, fuse, threads)?;
                    let stats =
                        measure(opts.epochs, opts.warmup, || exec.run(&x).map(|_| ()))?;
                    let step_rows = profile_steps(&mut exec, &x)?;
                    let cg = exec.compiled();
                    let micro = micro_summary(cg);
                    let label = format!("arena {precision} (tuned)");
                    t.row(vec![
                        batch.to_string(), lname.clone(), label.clone(),
                        fmt_ms(stats.mean_ms), fmt_speedup(base_ms / stats.mean_ms),
                        cg.steps.len().to_string(),
                        kib(cg.arena_bytes),
                        cg.fused_chains.to_string(),
                        micro.clone(),
                    ]);
                    let (gibs, ops, rf) =
                        row_metrics(image, batch, precision == "int8", stats.mean_ms);
                    rows.push(ArenaRow {
                        batch, layout: lname.clone(), precision: precision.into(),
                        config: label, fused: fuse, threads,
                        schedule: "tuned".into(), knobs,
                        mean_ms: stats.mean_ms, ns_per_iter: stats.mean_ms * 1e6,
                        steps: cg.steps.len(), fused_chains: cg.fused_chains,
                        arena_bytes: cg.arena_bytes,
                        compile_ms, compile_cached_ms,
                        micro, gibs, int8_ops_per_s: ops, roofline_frac: rf,
                        step_rows,
                    });
                }
            }
        }
    }
    Ok((t, rows))
}

/// `bench-serve` — arena-bucket serving vs per-request execution, all on
/// the native engine (no artifacts): the Table-3 batching story measured
/// through the coordinator instead of a bare executor loop.
///
/// Three rows: the batching server over [`crate::executor::NativeArenaFactory`]
/// buckets (concurrent clients), a sequential per-request `run_into` loop
/// on the batch-1 engine (no batching, still allocation-free), and a
/// sequential per-request `run` loop (allocating a fresh output per
/// inference — the naive client-library pattern).
pub fn serve_bench(
    buckets: &[usize],
    image: usize,
    threads: usize,
    requests: usize,
    clients: usize,
    batch_timeout: std::time::Duration,
    workers: usize,
) -> Result<Table> {
    use crate::coordinator::{InferenceServer, ServeConfig};
    use crate::executor::{ArenaExec, EngineFactory, NativeArenaFactory};
    use std::time::Instant;

    let spec = EngineSpec::new(EngineKind::Arena);
    let factory = NativeArenaFactory::new(spec, buckets, image, threads)?;
    let buckets = factory.buckets();
    let g1 = factory.graph(1)?;

    let clients = clients.max(1);
    let per_client = (requests / clients).max(1);
    let total = per_client * clients;

    let mut t = Table::new(
        format!(
            "bench-serve — arena bucket serving vs per-request run \
             (image {image}, {total} requests, {clients} clients, \
             buckets {buckets:?}, {threads} thread(s), {workers} worker(s))"
        ),
        &["Config", "Req/s", "p50 (ms)", "p95 (ms)", "p99 (ms)",
          "Mean batch", "Padded", "Errors"],
    );

    // (a) the batching server over arena bucket engines.
    let cfg = ServeConfig {
        spec,
        max_batch: *buckets.last().expect("non-empty buckets"),
        batch_timeout,
        workers,
        ..ServeConfig::default()
    };
    let server = std::sync::Arc::new(InferenceServer::start_with(factory, cfg)?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = server.clone();
        handles.push(std::thread::spawn(move || {
            let rest = [3, image, image];
            let mut errors = 0usize;
            for i in 0..per_client {
                let img = synthetic_images(1, &rest, (c * 7919 + i) as u64);
                if server.submit_blocking(img).is_err() {
                    errors += 1;
                }
            }
            errors
        }));
    }
    let mut errors = 0usize;
    for h in handles {
        errors += h.join().unwrap_or(per_client);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = stats.latency_stats();
    let (p50, p95, p99) = match &lat.stats {
        Some(s) => (fmt_ms(s.p50_ms), fmt_ms(s.p95_ms), fmt_ms(s.p99_ms)),
        None => ("-".into(), "-".into(), "-".into()),
    };
    t.row(vec![
        "serve (arena buckets)".into(),
        format!("{:.1}", total as f64 / wall),
        p50, p95, p99,
        format!("{:.2}", stats.mean_batch()),
        stats.padded_slots.to_string(),
        errors.to_string(),
    ]);

    // (b)/(c) per-request baselines on the batch-1 engine, sequential.
    // Images are pre-generated so only executor time is on the clock.
    let exec = ArenaExec::with_options(&g1, true, threads)?;
    let images: Vec<TensorData> = (0..total.min(64))
        .map(|i| synthetic_images(1, &[3, image, image], i as u64))
        .collect();
    let (out_shape, out_dt) = Executor::output_desc(&exec);
    let mut out = TensorData::zeros(out_dt, out_shape);

    fn direct_row(
        t: &mut Table,
        total: usize,
        images: &[TensorData],
        label: &str,
        mut f: impl FnMut(&TensorData) -> Result<()>,
    ) -> Result<()> {
        let mut samples = Vec::with_capacity(total);
        for i in 0..total {
            let x = &images[i % images.len()];
            let t0 = Instant::now();
            f(x)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let st = EpochStats::from_samples(&samples, 0)
            .ok_or_else(|| anyhow::anyhow!("direct_row: no samples"))?;
        let wall_ms: f64 = samples.iter().sum();
        t.row(vec![
            label.into(),
            format!("{:.1}", total as f64 / (wall_ms / 1e3)),
            fmt_ms(st.p50_ms), fmt_ms(st.p95_ms), fmt_ms(st.p99_ms),
            "1.00".into(), "0".into(), "0".into(),
        ]);
        Ok(())
    }
    direct_row(&mut t, total, &images, "direct run_into (b1, no batching)", |x| {
        exec.run_into(x, &mut out)
    })?;
    direct_row(&mut t, total, &images, "direct run (b1, alloc per request)", |x| {
        exec.run(x).map(|_| ())
    })?;
    Ok(t)
}

/// Memory-plan ablation: arena reuse vs unshared allocation across the
/// model chain (pure analysis, no execution).
pub fn memplan_ablation(ctx: &BenchCtx) -> Result<Table> {
    let mut t = Table::new(
        "Memory planner — static arena vs dynamic (unshared) allocation",
        &["Bundle", "Boundary tensors", "Arena (KiB)", "Unshared (KiB)", "Reuse factor"],
    );
    for b in &ctx.manifest.bundles {
        if b.executor != EngineKind::Vm {
            continue;
        }
        let plan = crate::memplan::StaticPlan::for_chain(&b.modules);
        plan.verify().map_err(|e| anyhow::anyhow!(e))?;
        t.row(vec![
            b.id.clone(),
            plan.placements.len().to_string(),
            format!("{:.1}", plan.arena_bytes as f64 / 1024.0),
            format!("{:.1}", plan.unshared_bytes as f64 / 1024.0),
            format!("{:.2}x", plan.reuse_factor()),
        ]);
    }
    Ok(t)
}
