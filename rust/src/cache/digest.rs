//! Structural content digests over the graph IR and schedule tables.
//!
//! The compile cache is keyed by *what a graph means*, not by how it was
//! built: two independently constructed but identical graphs must share
//! one key, and any change that could alter the compiled step stream must
//! change the key.  The digest therefore covers topology (operator kinds,
//! attribute values, input order), tensor types (shape + dtype, so
//! re-batched bucket graphs key differently), and constant *payloads*
//! (weights hash by value, never by `Arc` pointer).  Node ids and names
//! are deliberately excluded — appending the same dataflow in a different
//! order yields the same digest.
//!
//! Digests compose recursively: each node's digest hashes its operator,
//! its attributes, its children's digests (in input order — `Add` operand
//! order is observable for NaN), and its type.  The graph digest is the
//! output node's digest, so dead branches never perturb the key, matching
//! the DCE the compiler itself performs.  A separate *constant-pool*
//! digest hashes the sorted set of live constant digests: re-batched
//! bucket graphs produce distinct graph digests that share one pool
//! digest, which is how the on-disk store validates that a cached entry's
//! `Slot::Const` indices still point at the weights the caller holds.
//!
//! The hash is an in-crate SHA-256 (FIPS 180-4; the offline build has no
//! hashing dependency).  All multi-byte values are hashed little-endian
//! with length prefixes on variable-length fields, so no two distinct
//! structures serialize to the same byte stream.

use std::fmt;

use crate::executor::{Banding, PACK_FORMAT_VERSION};
use crate::graph::compile::{ClassKey, ScheduleOverrides, ShapeKey, StepSched};
use crate::graph::ir::{ConstValue, Graph, IrDType, Layout, Op, TensorTy};

// ---------------------------------------------------------------------------
// SHA-256
// ---------------------------------------------------------------------------

/// A 256-bit content digest.  `Ord` gives constant-pool digests a
/// canonical sort; hex rendering is the on-disk / log identity.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub [u8; 32]);

impl Digest {
    pub fn hex(&self) -> String {
        let mut s = String::with_capacity(64);
        for b in &self.0 {
            s.push_str(&format!("{b:02x}"));
        }
        s
    }

    /// Leading 16 hex chars — enough to name files and log lines.
    pub fn short(&self) -> String {
        self.hex()[..16].to_string()
    }

    pub fn from_hex(s: &str) -> Option<Digest> {
        if s.len() != 64 {
            return None;
        }
        let mut out = [0u8; 32];
        for (i, chunk) in s.as_bytes().chunks(2).enumerate() {
            let hi = (chunk[0] as char).to_digit(16)?;
            let lo = (chunk[1] as char).to_digit(16)?;
            out[i] = (hi * 16 + lo) as u8;
        }
        Some(Digest(out))
    }
}

impl fmt::Display for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

impl fmt::Debug for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Digest({})", self.short())
    }
}

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Streaming SHA-256 (FIPS 180-4).
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 {
            state: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> Digest {
        let bit_len = self.total_len.wrapping_mul(8);
        // Padding: 0x80, zeros, then the 64-bit big-endian message length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Write the length directly into the buffer tail (update would
        // recount it).
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        Digest(out)
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }

    // -- typed feeders (length-prefixed / fixed-width, little-endian) ------

    fn put_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    fn put_f32(&mut self, v: f32) {
        self.update(&v.to_bits().to_le_bytes());
    }

    fn put_tag(&mut self, t: u8) {
        self.update(&[t]);
    }

    fn put_layout(&mut self, l: Layout) {
        match l {
            Layout::Nchw => {
                self.put_tag(0);
                self.put_u64(0);
            }
            Layout::Nhwc => {
                self.put_tag(1);
                self.put_u64(0);
            }
            Layout::Nchwc(cb) => {
                self.put_tag(2);
                self.put_usize(cb);
            }
        }
    }

    fn put_ty(&mut self, ty: &TensorTy) {
        self.put_usize(ty.shape.len());
        for &d in &ty.shape {
            self.put_usize(d);
        }
        self.put_tag(match ty.dtype {
            IrDType::F32 => 0,
            IrDType::S8 => 1,
            IrDType::S32 => 2,
        });
    }
}

// ---------------------------------------------------------------------------
// Structural graph digests
// ---------------------------------------------------------------------------

/// Hash an operator kind + attributes (not its operands — the node walk
/// feeds child digests separately).
fn put_op(h: &mut Sha256, op: &Op) {
    match op {
        Op::Input => h.put_tag(0),
        Op::Constant(c) => {
            h.put_tag(1);
            match c {
                ConstValue::F32(v) => {
                    h.put_tag(0);
                    h.put_usize(v.len());
                    for x in v.iter() {
                        h.put_f32(*x);
                    }
                }
                ConstValue::I8(v) => {
                    h.put_tag(1);
                    h.put_usize(v.len());
                    // i8 payloads hash byte-for-byte.
                    let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                    h.update(&bytes);
                }
            }
        }
        Op::Conv2d { stride, padding, layout } => {
            h.put_tag(2);
            h.put_usize(*stride);
            h.put_usize(*padding);
            h.put_layout(*layout);
        }
        Op::Dense => h.put_tag(3),
        Op::BiasAdd { layout } => {
            h.put_tag(4);
            h.put_layout(*layout);
        }
        Op::Relu => h.put_tag(5),
        Op::Add => h.put_tag(6),
        Op::MaxPool { window, stride, padding, layout } => {
            h.put_tag(7);
            h.put_usize(*window);
            h.put_usize(*stride);
            h.put_usize(*padding);
            h.put_layout(*layout);
        }
        Op::GlobalAvgPool { layout } => {
            h.put_tag(8);
            h.put_layout(*layout);
        }
        Op::Quantize { scale } => {
            h.put_tag(9);
            h.put_f32(*scale);
        }
        Op::Dequantize { scale } => {
            h.put_tag(10);
            h.put_f32(*scale);
        }
        Op::LayoutTransform { from, to } => {
            h.put_tag(11);
            h.put_layout(*from);
            h.put_layout(*to);
        }
    }
}

/// Per-node recursive digests, computed in id order (the graph is
/// append-only, so every input precedes its users).  A node's digest is a
/// pure function of its op, attributes, child digests (input order), and
/// type — never of its id or name.
pub fn node_digests(g: &Graph) -> Vec<Digest> {
    let mut out: Vec<Digest> = Vec::with_capacity(g.len());
    for n in &g.nodes {
        let mut h = Sha256::new();
        h.update(b"tvmq-node-v1");
        put_op(&mut h, &n.op);
        h.put_usize(n.inputs.len());
        for &i in &n.inputs {
            h.update(&out[i].0);
        }
        h.put_ty(&n.ty);
        out.push(h.finalize());
    }
    out
}

/// The two digests a graph exports: its own identity and its live
/// constant pool's.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphDigest {
    /// Identity of the computation reachable from the output (plus the
    /// declared input), invariant under node reordering and renaming.
    pub graph: Digest,
    /// Hash of the sorted live constant digests — shared by re-batched
    /// variants of the same model.
    pub const_pool: Digest,
}

pub fn graph_digest(g: &Graph) -> GraphDigest {
    let nodes = node_digests(g);
    let graph = {
        let mut h = Sha256::new();
        h.update(b"tvmq-graph-v1");
        h.update(&nodes[g.output].0);
        // The declared input participates even when (degenerately) the
        // output does not reach it — its type is part of the contract.
        h.update(&nodes[g.input].0);
        h.finalize()
    };
    let live = g.live_set();
    let mut const_digests: Vec<Digest> = g
        .nodes
        .iter()
        .filter(|n| live[n.id] && matches!(n.op, Op::Constant(_)))
        .map(|n| nodes[n.id])
        .collect();
    const_digests.sort();
    let const_pool = {
        let mut h = Sha256::new();
        h.update(b"tvmq-constpool-v1");
        h.put_usize(const_digests.len());
        for d in &const_digests {
            h.update(&d.0);
        }
        h.finalize()
    };
    GraphDigest { graph, const_pool }
}

fn put_sched(h: &mut Sha256, s: &StepSched) {
    match s.banding {
        None => {
            h.put_tag(0);
            h.put_u64(0);
        }
        Some(Banding::Contiguous) => {
            h.put_tag(1);
            h.put_u64(0);
        }
        Some(Banding::Interleaved) => {
            h.put_tag(2);
            h.put_u64(0);
        }
        Some(Banding::Dynamic { chunk }) => {
            h.put_tag(3);
            h.put_usize(chunk);
        }
    }
    h.put_usize(s.max_bands);
    match s.micro {
        None => {
            h.put_tag(0);
            h.put_u64(0);
            h.put_u64(0);
            h.put_u64(0);
        }
        Some(m) => {
            h.put_tag(1);
            h.put_usize(m.mr);
            h.put_usize(m.nr);
            h.put_usize(m.ku);
        }
    }
}

/// Feed one [`ClassKey`] (op family + optional layout) into the hash.
fn put_class_key(h: &mut Sha256, k: &ClassKey) {
    h.put_tag(match k.op {
        crate::graph::compile::AnchorOp::Conv2d => 0,
        crate::graph::compile::AnchorOp::QConv2d => 1,
        crate::graph::compile::AnchorOp::Dense => 2,
        crate::graph::compile::AnchorOp::QDense => 3,
    });
    match k.layout {
        None => {
            h.put_tag(0);
            h.put_u64(0);
        }
        Some(l) => {
            h.put_tag(1);
            h.put_layout(l);
        }
    }
}

/// Digest of a schedule-override table plus the fuse flag.  The pool
/// width (`ovr.threads`) is deliberately *excluded* — it is a separate
/// component of the cache key, because executors overwrite it with their
/// own thread count before compiling.
pub fn overrides_digest(ovr: &ScheduleOverrides, fuse: bool) -> Digest {
    let mut h = Sha256::new();
    // v2: StepSched gained the register-tile knob, the table gained the
    // per-shape tier, and the pre-packed-weight format version is folded
    // in — a microkernel layout change can never serve a stale plan.
    h.update(b"tvmq-overrides-v2");
    h.put_u64(PACK_FORMAT_VERSION);
    h.put_tag(fuse as u8);
    h.put_usize(ovr.max_stack_lanes);
    put_sched(&mut h, &ovr.default_sched);
    let mut entries: Vec<(&ClassKey, &StepSched)> = ovr.per_class.iter().collect();
    entries.sort_by_key(|(k, _)| **k);
    h.put_usize(entries.len());
    for (k, s) in entries {
        put_class_key(&mut h, k);
        put_sched(&mut h, s);
    }
    let mut shape_entries: Vec<(&ShapeKey, &StepSched)> = ovr.per_shape.iter().collect();
    shape_entries.sort_by(|a, b| a.0.cmp(b.0));
    h.put_usize(shape_entries.len());
    for (k, s) in shape_entries {
        put_class_key(&mut h, &k.class);
        h.put_usize(k.shape.len());
        for &d in &k.shape {
            h.put_usize(d);
        }
        put_sched(&mut h, s);
    }
    h.finalize()
}

/// Domain-separated content digest of a raw byte payload — the store
/// uses it to pin pre-packed weight panels without persisting them.
pub fn bytes_digest(domain: &str, b: &[u8]) -> Digest {
    let mut h = Sha256::new();
    h.update(domain.as_bytes());
    h.put_usize(b.len());
    h.update(b);
    h.finalize()
}

/// The full compile-cache key: what to build (graph), how to build it
/// (schedule table + fuse), and the pool width the spill windows were
/// sized for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub graph: Digest,
    pub const_pool: Digest,
    pub overrides: Digest,
    pub threads: usize,
}

impl CacheKey {
    pub fn of(g: &Graph, ovr: &ScheduleOverrides, fuse: bool, threads: usize) -> CacheKey {
        let gd = graph_digest(g);
        CacheKey {
            graph: gd.graph,
            const_pool: gd.const_pool,
            overrides: overrides_digest(ovr, fuse),
            threads: threads.max(1),
        }
    }

    /// Stable file stem for the on-disk store.
    pub fn file_stem(&self) -> String {
        format!(
            "cg-{}-{}-t{}",
            &self.graph.hex()[..24],
            &self.overrides.hex()[..12],
            self.threads
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vectors() {
        let empty = Sha256::new().finalize();
        assert_eq!(
            empty.hex(),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        let mut h = Sha256::new();
        h.update(b"abc");
        assert_eq!(
            h.finalize().hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // A two-block message (len 56 forces the length into a second
        // padding block).
        let mut h = Sha256::new();
        h.update(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq");
        assert_eq!(
            h.finalize().hex(),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let mut one = Sha256::new();
        one.update(&data);
        let mut chunked = Sha256::new();
        for c in data.chunks(17) {
            chunked.update(c);
        }
        assert_eq!(one.finalize(), chunked.finalize());
    }

    #[test]
    fn hex_round_trips() {
        let mut h = Sha256::new();
        h.update(b"round trip");
        let d = h.finalize();
        assert_eq!(Digest::from_hex(&d.hex()), Some(d));
        assert_eq!(Digest::from_hex("zz"), None);
    }
}
