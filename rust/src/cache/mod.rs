//! `cache` — the content-addressed compile/tune cache behind warm-start
//! serving.
//!
//! The paper's best configurations are *searched*, which makes
//! compile+tune latency the startup tax of every serving-fleet variant.
//! This subsystem removes that tax for repeat builds: compiled arena
//! programs are stored on disk keyed by **what** is being built rather
//! than by file names or process identity, so `tvmq serve --cache-dir D`
//! warm-starts in milliseconds with zero `graph::compile` calls on hits.
//!
//! # What is keyed
//!
//! A [`CacheKey`] is three digests plus the pool width:
//!
//! - **graph digest** ([`digest::graph_digest`]) — a recursive SHA-256
//!   over the dataflow reachable from the output: operator kinds and
//!   attributes, input order, tensor shapes/dtypes, and constant
//!   *payloads* (by value, never by pointer).  Node ids and names do not
//!   participate: two independently built but identical graphs share one
//!   key, and re-batched bucket graphs get distinct keys that share a
//!   constant-pool digest.
//! - **overrides digest** ([`digest::overrides_digest`]) — the schedule
//!   table (per-class and per-shape banding/band-cap/register-tile knobs,
//!   the lane-accumulator stack bound, the default schedule) plus the
//!   fuse flag and the pre-packed-weight format version
//!   ([`crate::executor::PACK_FORMAT_VERSION`]).
//! - **threads** — the pool width spill windows were sized for.
//!
//! # What invalidates
//!
//! Any change to any keyed input — topology, attributes, layouts,
//! shapes (including batch), constant values, schedule knobs, fuse,
//! threads — changes the key; stale entries are never looked up.
//! Corrupt, truncated, or future-versioned entries are logged misses
//! (the cold path recompiles and overwrites), never errors.
//!
//! # What `--verify-cache` proves
//!
//! With verification on, every hit is executed on a seeded input and
//! compared **bit-for-bit** against `graph::interp::evaluate` before the
//! engine is handed to the caller; a mismatch rejects the entry and
//! falls back to a cold compile.  A verified hit therefore carries the
//! same oracle guarantee the compile path itself is tested under.
//!
//! The sibling tune cache rides in the same directory: any tune-records
//! files found there are merged by task key (best measured config wins,
//! [`crate::tune::records::merge`]) and applied to the engines built
//! from the cache — see [`store::scan_tune_records`].

pub mod digest;
pub mod store;

pub use digest::{graph_digest, overrides_digest, CacheKey, Digest, GraphDigest, Sha256};
pub use store::{scan_tune_records, CacheStats, CompileCache, MERGED_RECORDS_FILE};
