//! The on-disk compile cache: serialized step streams + arena plans,
//! keyed by [`CacheKey`] (graph digest × schedule digest × pool width).
//!
//! # What an entry holds
//!
//! Everything `compile_graph_with` produced *except* the constant pool:
//! the step stream (ops, slots, schedules, spill windows), the verified
//! [`StaticPlan`], and the I/O types.  Constants are deliberately **not**
//! serialized — they are rebuilt from the caller's graph (the DCE'd
//! constant nodes in node order, exactly the order the compiler pools
//! them in), so cached engines keep sharing one `Arc`-backed weight set
//! with everything else built from the same template, and entries stay
//! kilobytes instead of megabytes.  The key's constant-pool digest pins
//! that the rebuilt pool is byte-identical to the one the entry was
//! compiled against.
//!
//! # What invalidates
//!
//! Any change to graph topology, op attributes, layouts, constant
//! values, tensor shapes (including batch), the schedule-override table,
//! the fuse flag, or the pool width produces a different key — the old
//! entry is simply never looked up again.  A corrupt, truncated,
//! unparsable, or future-versioned entry is a logged **miss**, never an
//! error: the caller falls back to a cold compile and overwrites it.
//!
//! # What `--verify-cache` proves
//!
//! In verify mode every hit is differentially re-checked before it is
//! trusted: the deserialized program is run through a fresh `ArenaExec`
//! on a seeded input and its output compared **bit-for-bit** against
//! `graph::interp::evaluate` on the caller's graph.  A mismatch rejects
//! the entry (logged, counted, treated as a miss) — so a verified hit
//! carries exactly the same oracle guarantee as a cold compile.
//!
//! Writes are atomic (temp file + rename), so a crashed process never
//! leaves a half-written entry that later parses.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use super::digest::{bytes_digest, graph_digest, CacheKey, Digest};
use crate::executor::microkernel::pack_weight;
use crate::executor::{ArenaExec, Banding, Executor, PACK_FORMAT_VERSION};
use crate::graph::compile::{
    CompiledGraph, Epilogue, PackedWeight, Residual, Slot, SpillSpec, Step, StepOp,
    StepSched,
};
use crate::graph::ir::{ConstValue, Graph, IrDType, Layout, Op, TensorTy};
use crate::graph::passes::{DeadCodeElim, Pass};
use crate::memplan::StaticPlan;
use crate::runtime::TensorData;
use crate::tune::knobs::{
    banding_str, layout_str, micro_str, parse_banding_str, parse_layout_str,
    parse_micro_str,
};
use crate::tune::TuneRecords;
use crate::util::json::Json;
use crate::util::rng::Rng64;

pub const STORE_KIND: &str = "tvmq-compile-cache";
/// v2: steps carry the register-tile schedule knob and an optional
/// pre-packed weight reference, and the entry records the pack format
/// version plus per-panel metadata (source const, layout, length, content
/// digest).  Packed *bytes* are never stored — a hit re-derives them from
/// the digest-verified constant pool and cross-checks the metadata.
pub const STORE_VERSION: u64 = 2;

/// File name the auto-merged tune records are written under (and skipped
/// when re-scanning, so the merge's inputs stay the primary files).
pub const MERGED_RECORDS_FILE: &str = "merged-tune-records.json";

// ---------------------------------------------------------------------------
// JSON (de)serialization of the compiled program
// ---------------------------------------------------------------------------

/// f32 values (quantization scales) serialize as their IEEE-754 bit
/// patterns: a `u32` is exact in JSON's f64 and round-trips bit-for-bit,
/// which a decimal rendering would not guarantee.
fn f32_to_json(v: f32) -> Json {
    Json::num(v.to_bits() as f64)
}

fn f32_from_json(j: &Json) -> Result<f32> {
    let bits = j.as_u64()?;
    if bits > u32::MAX as u64 {
        bail!("f32 bit pattern out of range: {bits}");
    }
    Ok(f32::from_bits(bits as u32))
}

fn layout_to_json(l: Layout) -> Json {
    Json::str(layout_str(Some(l)))
}

fn layout_from_json(j: &Json) -> Result<Layout> {
    parse_layout_str(j.as_str()?)?.ok_or_else(|| anyhow!("expected a concrete layout"))
}

fn dtype_str(d: IrDType) -> &'static str {
    match d {
        IrDType::F32 => "f32",
        IrDType::S8 => "s8",
        IrDType::S32 => "s32",
    }
}

fn dtype_from_str(s: &str) -> Result<IrDType> {
    Ok(match s {
        "f32" => IrDType::F32,
        "s8" => IrDType::S8,
        "s32" => IrDType::S32,
        other => bail!("unknown dtype {other:?}"),
    })
}

fn ty_to_json(ty: &TensorTy) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::Arr(ty.shape.iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        ("dtype", Json::str(dtype_str(ty.dtype))),
    ])
}

fn ty_from_json(j: &Json) -> Result<TensorTy> {
    Ok(TensorTy {
        shape: j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?,
        dtype: dtype_from_str(j.get("dtype")?.as_str()?)?,
    })
}

fn slot_to_json(s: &Slot) -> Json {
    match s {
        Slot::Arena { offset, bytes } => Json::obj(vec![
            ("kind", Json::str("arena")),
            ("offset", Json::num(*offset as f64)),
            ("bytes", Json::num(*bytes as f64)),
        ]),
        Slot::Const(i) => Json::obj(vec![
            ("kind", Json::str("const")),
            ("index", Json::num(*i as f64)),
        ]),
    }
}

fn slot_from_json(j: &Json) -> Result<Slot> {
    match j.get("kind")?.as_str()? {
        "arena" => Ok(Slot::Arena {
            offset: j.get("offset")?.as_usize()?,
            bytes: j.get("bytes")?.as_usize()?,
        }),
        "const" => Ok(Slot::Const(j.get("index")?.as_usize()?)),
        other => bail!("unknown slot kind {other:?}"),
    }
}

fn epi_to_json(e: &Epilogue) -> Json {
    Json::obj(vec![
        (
            "bias",
            e.bias.map(|i| Json::num(i as f64)).unwrap_or(Json::Null),
        ),
        ("relu", Json::Bool(e.relu)),
        (
            "residual",
            match e.residual {
                None => Json::Null,
                Some(r) => Json::obj(vec![
                    ("pre_relu", Json::Bool(r.pre_relu)),
                    ("chain_lhs", Json::Bool(r.chain_lhs)),
                ]),
            },
        ),
    ])
}

fn bool_from_json(j: &Json) -> Result<bool> {
    match j {
        Json::Bool(b) => Ok(*b),
        other => bail!("expected a boolean, got {other:?}"),
    }
}

fn epi_from_json(j: &Json) -> Result<Epilogue> {
    Ok(Epilogue {
        bias: match j.opt("bias") {
            None => None,
            Some(v) => Some(v.as_usize()?),
        },
        relu: bool_from_json(j.get("relu")?)?,
        residual: match j.opt("residual") {
            None => None,
            Some(r) => Some(Residual {
                pre_relu: bool_from_json(r.get("pre_relu")?)?,
                chain_lhs: bool_from_json(r.get("chain_lhs")?)?,
            }),
        },
    })
}

fn step_op_to_json(op: &StepOp) -> Json {
    match op {
        StepOp::LoadInput => Json::obj(vec![("op", Json::str("load_input"))]),
        StepOp::Conv2d { stride, padding, layout, epi } => Json::obj(vec![
            ("op", Json::str("conv2d")),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::num(*padding as f64)),
            ("layout", layout_to_json(*layout)),
            ("epi", epi_to_json(epi)),
        ]),
        StepOp::QConv2d { qscale, dqscale, stride, padding, layout, epi } => Json::obj(vec![
            ("op", Json::str("qconv2d")),
            ("qscale_bits", f32_to_json(*qscale)),
            ("dqscale_bits", f32_to_json(*dqscale)),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::num(*padding as f64)),
            ("layout", layout_to_json(*layout)),
            ("epi", epi_to_json(epi)),
        ]),
        StepOp::Dense { epi } => {
            Json::obj(vec![("op", Json::str("dense")), ("epi", epi_to_json(epi))])
        }
        StepOp::QDense { qscale, dqscale, epi } => Json::obj(vec![
            ("op", Json::str("qdense")),
            ("qscale_bits", f32_to_json(*qscale)),
            ("dqscale_bits", f32_to_json(*dqscale)),
            ("epi", epi_to_json(epi)),
        ]),
        StepOp::BiasAdd { layout } => Json::obj(vec![
            ("op", Json::str("bias_add")),
            ("layout", layout_to_json(*layout)),
        ]),
        StepOp::Relu => Json::obj(vec![("op", Json::str("relu"))]),
        StepOp::Add => Json::obj(vec![("op", Json::str("add"))]),
        StepOp::MaxPool { window, stride, padding, layout } => Json::obj(vec![
            ("op", Json::str("max_pool")),
            ("window", Json::num(*window as f64)),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::num(*padding as f64)),
            ("layout", layout_to_json(*layout)),
        ]),
        StepOp::GlobalAvgPool { layout } => Json::obj(vec![
            ("op", Json::str("global_avg_pool")),
            ("layout", layout_to_json(*layout)),
        ]),
        StepOp::Quantize { scale } => Json::obj(vec![
            ("op", Json::str("quantize")),
            ("scale_bits", f32_to_json(*scale)),
        ]),
        StepOp::Dequantize { scale } => Json::obj(vec![
            ("op", Json::str("dequantize")),
            ("scale_bits", f32_to_json(*scale)),
        ]),
        StepOp::LayoutTransform { from, to } => Json::obj(vec![
            ("op", Json::str("layout_transform")),
            ("from", layout_to_json(*from)),
            ("to", layout_to_json(*to)),
        ]),
    }
}

fn step_op_from_json(j: &Json) -> Result<StepOp> {
    Ok(match j.get("op")?.as_str()? {
        "load_input" => StepOp::LoadInput,
        "conv2d" => StepOp::Conv2d {
            stride: j.get("stride")?.as_usize()?,
            padding: j.get("padding")?.as_usize()?,
            layout: layout_from_json(j.get("layout")?)?,
            epi: epi_from_json(j.get("epi")?)?,
        },
        "qconv2d" => StepOp::QConv2d {
            qscale: f32_from_json(j.get("qscale_bits")?)?,
            dqscale: f32_from_json(j.get("dqscale_bits")?)?,
            stride: j.get("stride")?.as_usize()?,
            padding: j.get("padding")?.as_usize()?,
            layout: layout_from_json(j.get("layout")?)?,
            epi: epi_from_json(j.get("epi")?)?,
        },
        "dense" => StepOp::Dense { epi: epi_from_json(j.get("epi")?)? },
        "qdense" => StepOp::QDense {
            qscale: f32_from_json(j.get("qscale_bits")?)?,
            dqscale: f32_from_json(j.get("dqscale_bits")?)?,
            epi: epi_from_json(j.get("epi")?)?,
        },
        "bias_add" => StepOp::BiasAdd { layout: layout_from_json(j.get("layout")?)? },
        "relu" => StepOp::Relu,
        "add" => StepOp::Add,
        "max_pool" => StepOp::MaxPool {
            window: j.get("window")?.as_usize()?,
            stride: j.get("stride")?.as_usize()?,
            padding: j.get("padding")?.as_usize()?,
            layout: layout_from_json(j.get("layout")?)?,
        },
        "global_avg_pool" => {
            StepOp::GlobalAvgPool { layout: layout_from_json(j.get("layout")?)? }
        }
        "quantize" => StepOp::Quantize { scale: f32_from_json(j.get("scale_bits")?)? },
        "dequantize" => StepOp::Dequantize { scale: f32_from_json(j.get("scale_bits")?)? },
        "layout_transform" => StepOp::LayoutTransform {
            from: layout_from_json(j.get("from")?)?,
            to: layout_from_json(j.get("to")?)?,
        },
        other => bail!("unknown step op {other:?}"),
    })
}

fn sched_to_json(s: &StepSched) -> Json {
    Json::obj(vec![
        ("banding", Json::str(banding_str(s.banding))),
        ("max_bands", Json::num(s.max_bands as f64)),
        ("micro", Json::str(micro_str(s.micro))),
    ])
}

fn sched_from_json(j: &Json) -> Result<StepSched> {
    Ok(StepSched {
        banding: parse_banding_str(j.get("banding")?.as_str()?)?,
        max_bands: j.get("max_bands")?.as_usize()?,
        // Absent in v1 entries — scalar kernels.
        micro: match j.opt("micro") {
            Some(v) => parse_micro_str(v.as_str()?)?,
            None => None,
        },
    })
}

fn spill_to_json(s: &SpillSpec) -> Json {
    Json::obj(vec![
        ("offset", Json::num(s.offset as f64)),
        ("band_bytes", Json::num(s.band_bytes as f64)),
        ("bands", Json::num(s.bands as f64)),
    ])
}

fn spill_from_json(j: &Json) -> Result<SpillSpec> {
    Ok(SpillSpec {
        offset: j.get("offset")?.as_usize()?,
        band_bytes: j.get("band_bytes")?.as_usize()?,
        bands: j.get("bands")?.as_usize()?,
    })
}

fn step_to_json(s: &Step) -> Json {
    Json::obj(vec![
        ("op", step_op_to_json(&s.op)),
        (
            "srcs",
            Json::Arr(
                s.srcs
                    .iter()
                    .map(|(slot, ty)| {
                        Json::obj(vec![("slot", slot_to_json(slot)), ("ty", ty_to_json(ty))])
                    })
                    .collect(),
            ),
        ),
        ("dst", slot_to_json(&s.dst)),
        ("dst_ty", ty_to_json(&s.dst_ty)),
        (
            "scratch",
            s.scratch.as_ref().map(slot_to_json).unwrap_or(Json::Null),
        ),
        ("sched", sched_to_json(&s.sched)),
        (
            "spill",
            s.spill.as_ref().map(spill_to_json).unwrap_or(Json::Null),
        ),
        (
            "packed",
            s.packed.map(|i| Json::num(i as f64)).unwrap_or(Json::Null),
        ),
        ("name", Json::str(s.name.clone())),
    ])
}

fn step_from_json(j: &Json) -> Result<Step> {
    Ok(Step {
        op: step_op_from_json(j.get("op")?)?,
        srcs: j
            .get("srcs")?
            .as_arr()?
            .iter()
            .map(|s| Ok((slot_from_json(s.get("slot")?)?, ty_from_json(s.get("ty")?)?)))
            .collect::<Result<Vec<_>>>()?,
        dst: slot_from_json(j.get("dst")?)?,
        dst_ty: ty_from_json(j.get("dst_ty")?)?,
        scratch: match j.opt("scratch") {
            None => None,
            Some(s) => Some(slot_from_json(s)?),
        },
        sched: sched_from_json(j.get("sched")?)?,
        spill: match j.opt("spill") {
            None => None,
            Some(s) => Some(spill_from_json(s)?),
        },
        packed: match j.opt("packed") {
            None => None,
            Some(p) => Some(p.as_usize()?),
        },
        name: j.get("name")?.as_str()?.to_string(),
    })
}

/// Byte view of an int8 payload (for content digests only).
fn i8_bytes(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}

/// Domain string for the pre-packed-panel content digests.
const PACKED_DIGEST_DOMAIN: &str = "tvmq-packed-v1";

/// Serialize a compiled program under its cache key.  The constant pool
/// is represented only by per-entry metadata (dtype + element count) —
/// payloads are rebuilt from the graph on load.
pub fn compiled_to_json(cg: &CompiledGraph, key: &CacheKey) -> Json {
    Json::obj(vec![
        ("kind", Json::str(STORE_KIND)),
        ("version", Json::num(STORE_VERSION as f64)),
        ("graph_digest", Json::str(key.graph.hex())),
        ("const_pool_digest", Json::str(key.const_pool.hex())),
        ("overrides_digest", Json::str(key.overrides.hex())),
        ("threads", Json::num(key.threads as f64)),
        ("pack_format", Json::num(PACK_FORMAT_VERSION as f64)),
        (
            "packed",
            Json::Arr(
                cg.packed
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("src", Json::num(p.src as f64)),
                            ("layout", Json::str(layout_str(p.layout))),
                            ("len", Json::num(p.data.len() as f64)),
                            (
                                "digest",
                                Json::str(
                                    bytes_digest(PACKED_DIGEST_DOMAIN, i8_bytes(&p.data))
                                        .hex(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("steps", Json::Arr(cg.steps.iter().map(step_to_json).collect())),
        (
            "consts",
            Json::Arr(
                cg.consts
                    .iter()
                    .map(|(c, ty)| {
                        Json::obj(vec![
                            ("dtype", Json::str(dtype_str(c.dtype()))),
                            ("len", Json::num(c.len() as f64)),
                            ("ty", ty_to_json(ty)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("plan", cg.plan.to_json()),
        ("arena_bytes", Json::num(cg.arena_bytes as f64)),
        ("input_ty", ty_to_json(&cg.input_ty)),
        ("output_ty", ty_to_json(&cg.output_ty)),
        ("output_slot", slot_to_json(&cg.output_slot)),
        ("fused_chains", Json::num(cg.fused_chains as f64)),
    ])
}

/// Rebuild the constant pool the way `compile_graph_with` pools it: the
/// DCE'd graph's `Op::Constant` nodes in node order.
fn rebuild_consts(g: &Graph) -> Result<Vec<(ConstValue, TensorTy)>> {
    let g = DeadCodeElim.run(g)?;
    Ok(g.nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Constant(c) => Some((c.clone(), n.ty.clone())),
            _ => None,
        })
        .collect())
}

/// Deserialize an entry against the caller's graph + key, validating
/// every integrity property the executor later relies on.  Any failure
/// here is reported to the cache as corruption (a miss), never a panic.
pub fn compiled_from_json(j: &Json, g: &Graph, key: &CacheKey) -> Result<CompiledGraph> {
    if j.get("kind")?.as_str()? != STORE_KIND {
        bail!("not a compile-cache entry");
    }
    let version = j.get("version")?.as_u64()?;
    if version > STORE_VERSION {
        bail!("entry version {version} is newer than supported {STORE_VERSION}");
    }
    let stored_graph = Digest::from_hex(j.get("graph_digest")?.as_str()?)
        .ok_or_else(|| anyhow!("bad graph digest"))?;
    let stored_pool = Digest::from_hex(j.get("const_pool_digest")?.as_str()?)
        .ok_or_else(|| anyhow!("bad const-pool digest"))?;
    let stored_ovr = Digest::from_hex(j.get("overrides_digest")?.as_str()?)
        .ok_or_else(|| anyhow!("bad overrides digest"))?;
    if stored_graph != key.graph || stored_ovr != key.overrides || stored_pool != key.const_pool
    {
        bail!("entry digests do not match the requested key");
    }
    if j.get("threads")?.as_usize()? != key.threads {
        bail!("entry pool width does not match the requested key");
    }
    // The caller's graph must actually be the graph the key was computed
    // from — otherwise `Slot::Const` indices would dereference the wrong
    // weights.
    let gd = graph_digest(g);
    if gd.graph != key.graph || gd.const_pool != key.const_pool {
        bail!("caller graph does not match the requested key");
    }

    let consts = rebuild_consts(g)?;
    let const_meta = j.get("consts")?.as_arr()?;
    if const_meta.len() != consts.len() {
        bail!(
            "constant pool size mismatch: entry has {}, graph rebuilds {}",
            const_meta.len(),
            consts.len()
        );
    }
    for (i, (m, (c, _ty))) in const_meta.iter().zip(&consts).enumerate() {
        if m.get("dtype")?.as_str()? != dtype_str(c.dtype()) || m.get("len")?.as_usize()? != c.len()
        {
            bail!("constant {i} metadata mismatch");
        }
    }

    // Re-derive the pre-packed weight panels from the digest-verified
    // constant pool and cross-check them against the entry's metadata.
    // The packed bytes themselves are never persisted; any disagreement
    // (format version, source index, layout, length, content digest) is
    // corruption — a logged miss, so a microkernel-layout change can
    // never serve a stale pre-packed plan.
    let mut packed: Vec<PackedWeight> = Vec::new();
    if let Some(pf) = j.opt("pack_format") {
        if pf.as_u64()? != PACK_FORMAT_VERSION {
            bail!(
                "entry pack format {} != supported {PACK_FORMAT_VERSION}",
                pf.as_u64()?
            );
        }
        for (i, m) in j.get("packed")?.as_arr()?.iter().enumerate() {
            let src = m.get("src")?.as_usize()?;
            let layout = parse_layout_str(m.get("layout")?.as_str()?)?;
            let (c, ty) = consts
                .get(src)
                .ok_or_else(|| anyhow!("packed panel {i} sources constant {src} beyond pool"))?;
            let ConstValue::I8(w) = c else {
                bail!("packed panel {i} sources non-int8 constant {src}");
            };
            let data = pack_weight(layout, w, &ty.shape);
            if data.len() != m.get("len")?.as_usize()? {
                bail!("packed panel {i} length mismatch");
            }
            let want = Digest::from_hex(m.get("digest")?.as_str()?)
                .ok_or_else(|| anyhow!("packed panel {i} carries a bad digest"))?;
            if bytes_digest(PACKED_DIGEST_DOMAIN, i8_bytes(&data)) != want {
                bail!("packed panel {i} payload digest mismatch");
            }
            packed.push(PackedWeight { src, layout, data: std::sync::Arc::new(data) });
        }
    }

    let steps = j
        .get("steps")?
        .as_arr()?
        .iter()
        .map(step_from_json)
        .collect::<Result<Vec<_>>>()?;
    // Every const slot must point inside the rebuilt pool.
    for (si, step) in steps.iter().enumerate() {
        for (slot, _) in &step.srcs {
            if let Slot::Const(i) = slot {
                if *i >= consts.len() {
                    bail!("step {si} references constant {i} beyond pool of {}", consts.len());
                }
            }
        }
        if let Some(e) = step.op.epilogue() {
            if let Some(b) = e.bias {
                if b >= consts.len() {
                    bail!("step {si} bias constant {b} beyond pool of {}", consts.len());
                }
            }
        }
        if let Some(pi) = step.packed {
            if pi >= packed.len() {
                bail!("step {si} references packed panel {pi} beyond pool of {}", packed.len());
            }
        }
    }

    let plan = StaticPlan::from_json(j.get("plan")?)?;
    plan.verify().map_err(|e| anyhow!("arena plan failed verification: {e}"))?;
    let arena_bytes = j.get("arena_bytes")?.as_usize()?;
    if arena_bytes != plan.arena_bytes {
        bail!("arena extent {arena_bytes} != plan extent {}", plan.arena_bytes);
    }

    Ok(CompiledGraph {
        steps,
        consts,
        packed,
        plan,
        arena_bytes,
        input_ty: ty_from_json(j.get("input_ty")?)?,
        output_ty: ty_from_json(j.get("output_ty")?)?,
        output_slot: slot_from_json(j.get("output_slot")?)?,
        fused_chains: j.get("fused_chains")?.as_usize()?,
    })
}

// ---------------------------------------------------------------------------
// The cache itself
// ---------------------------------------------------------------------------

/// Hit/miss accounting, snapshotted for logs and the stats artifact.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    /// Entries discarded as corrupt, mismatched, or failing oracle
    /// re-verification (each also counts as a miss).
    pub rejected: u64,
}

impl CacheStats {
    /// Fraction of lookups that hit: `hits / (hits + misses)`, `0.0`
    /// before any lookup has happened (a cold cache is honestly 0%, not
    /// NaN).  Rejects are already counted inside `misses`.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / lookups as f64
    }
}

/// A content-addressed compile cache rooted at one directory.  Lookups
/// and stores are thread-safe; the factory shares one handle across the
/// serving tier's worker threads.
pub struct CompileCache {
    dir: PathBuf,
    verify: bool,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    rejected: AtomicU64,
}

impl CompileCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<CompileCache> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(CompileCache {
            dir,
            verify: false,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        })
    }

    /// Enable `--verify-cache`: every hit is differentially re-checked
    /// against the interpreter oracle before being trusted.
    pub fn with_verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn verifying(&self) -> bool {
        self.verify
    }

    fn entry_path(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.json", key.file_stem()))
    }

    /// Look up `key`.  `g` must be the graph the key was computed from —
    /// its constants are spliced into the deserialized program.  Every
    /// failure mode (absent, corrupt, version-mismatched, digest
    /// mismatch, failed verification) returns `None`.
    pub fn load(&self, key: &CacheKey, g: &Graph) -> Option<CompiledGraph> {
        let path = self.entry_path(key);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        let parsed = Json::parse(&text).and_then(|j| compiled_from_json(&j, g, key));
        let cg = match parsed {
            Ok(cg) => cg,
            Err(e) => {
                eprintln!(
                    "tvmq: cache: ignoring unusable entry {}: {e:#}",
                    path.display()
                );
                self.rejected.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        if self.verify {
            if let Err(e) = verify_against_oracle(&cg, g, key.threads) {
                eprintln!(
                    "tvmq: cache: entry {} failed oracle re-verification: {e:#}",
                    path.display()
                );
                self.rejected.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(cg)
    }

    /// Persist an entry atomically (temp file + rename, so readers never
    /// observe a torn write).
    pub fn store(&self, key: &CacheKey, cg: &CompiledGraph) -> Result<()> {
        let path = self.entry_path(key);
        let tmp = self.dir.join(format!(
            ".{}.tmp-{}",
            key.file_stem(),
            std::process::id()
        ));
        let text = compiled_to_json(cg, key).to_string_pretty() + "\n";
        fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {}", path.display()))?;
        self.stores.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
        }
    }

    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("kind", Json::str("tvmq-cache-stats")),
            ("dir", Json::str(self.dir.display().to_string())),
            ("verify", Json::Bool(self.verify)),
            ("hits", Json::num(s.hits as f64)),
            ("misses", Json::num(s.misses as f64)),
            ("stores", Json::num(s.stores as f64)),
            ("rejected", Json::num(s.rejected as f64)),
            ("hit_rate", Json::num(s.hit_rate())),
        ])
    }

    /// Write `cache-stats.json` into the cache dir (the CI artifact).
    pub fn write_stats(&self) -> Result<PathBuf> {
        let path = self.dir.join("cache-stats.json");
        fs::write(&path, self.stats_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// Differential oracle check for `--verify-cache`: run the deserialized
/// program on a seeded input and require bit-identical output to
/// `graph::interp::evaluate`.
fn verify_against_oracle(cg: &CompiledGraph, g: &Graph, threads: usize) -> Result<()> {
    let exec = ArenaExec::from_compiled(cg.clone(), threads)?;
    let ty = &g.node(g.input).ty;
    let mut rng = Rng64::seed_from_u64(0x5eed_cac4);
    let vals: Vec<f32> = (0..ty.element_count()).map(|_| rng.normal() * 0.5).collect();
    let x = TensorData::from_f32(ty.shape.clone(), &vals)?;
    let want = crate::graph::evaluate(g, &x)?;
    let got = exec.run(&x)?;
    let (got, want) = (got.as_f32()?, want.as_f32()?);
    if got.len() != want.len() {
        bail!("output length {} != oracle {}", got.len(), want.len());
    }
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        if a.to_bits() != b.to_bits() {
            bail!(
                "output diverges from the oracle at element {i}: {a:?} vs {b:?}"
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tune-records discovery in the cache dir
// ---------------------------------------------------------------------------

/// All loadable tune-records files in `dir` (sorted by path for
/// determinism).  Files of other kinds (cache entries, stats) are
/// silently skipped; files that *claim* to be records but fail to load
/// are logged and skipped — corruption never errors the serve path.
pub fn scan_tune_records(dir: &Path) -> Vec<(PathBuf, TuneRecords)> {
    let mut out = Vec::new();
    let Ok(entries) = fs::read_dir(dir) else {
        return out;
    };
    let mut paths: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.extension().map_or(false, |e| e == "json")
                && p.file_name().map_or(false, |n| n != MERGED_RECORDS_FILE)
        })
        .collect();
    paths.sort();
    for p in paths {
        let Ok(text) = fs::read_to_string(&p) else {
            continue;
        };
        let Ok(j) = Json::parse(&text) else {
            continue;
        };
        let kind = j.opt("kind").and_then(|k| k.as_str().ok());
        if kind != Some("tvmq-tune-records") {
            continue;
        }
        match TuneRecords::from_json(&j) {
            Ok(r) => out.push((p, r)),
            Err(e) => eprintln!(
                "tvmq: cache: ignoring unreadable tune records {}: {e:#}",
                p.display()
            ),
        }
    }
    out
}
