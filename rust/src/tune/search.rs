//! The search driver: seeded random sampling (optionally ordered by a
//! `perfmodel` cost prior) followed by greedy hill-climbing over
//! single-knob neighbours, under a fixed trial budget.
//!
//! Candidate *generation* is a pure function of the seed — same seed +
//! budget ⇒ the same candidate sequence and, under a deterministic
//! [`Measure`], the same best config (the determinism test pins this).
//! On real hardware the measured numbers decide which candidate wins;
//! every accepted trial already passed the measurer's bit-for-bit oracle
//! gate.

use std::collections::HashSet;

use anyhow::{anyhow, Result};

use super::knobs::{KnobSpace, SchedulePlan};
use super::measure::{Measure, Measurement, MeasureOpts, Measurer};
use crate::graph::Graph;
use crate::perfmodel::{tune_prior_ms, MachineModel};
use crate::runtime::TensorData;

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct TuneOptions {
    /// Total measured candidates, including the default schedule.
    pub budget: usize,
    pub seed: u64,
    /// Worker-pool width the candidates compile for.
    pub threads: usize,
    /// Measurement protocol (per candidate).
    pub warmup: usize,
    pub iters: usize,
    /// Order the random phase's candidates by the analytic cost prior, so
    /// a small budget measures the model's best guesses first.
    pub use_prior: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { budget: 24, seed: 1, threads: 1, warmup: 2, iters: 8, use_prior: true }
    }
}

/// One measured (oracle-verified) candidate.
#[derive(Debug, Clone)]
pub struct Trial {
    pub plan: SchedulePlan,
    pub ns_per_iter: f64,
}

/// The search result: the incumbent, every accepted trial in measurement
/// order, and the knob space it ran over.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub best: Trial,
    pub default_ns: f64,
    pub trials: Vec<Trial>,
    /// Candidates the measurer rejected (compile failure or oracle
    /// mismatch) — should be zero; schedule knobs are semantics-free.
    pub rejected: usize,
    pub space: KnobSpace,
    pub threads: usize,
}

impl TuneOutcome {
    /// The paper's improvement convention: default / best, as a
    /// percentage (100% = parity).
    pub fn improvement_pct(&self) -> f64 {
        100.0 * self.default_ns / self.best.ns_per_iter
    }
}

/// Tune `g`: enumerate its knob space, build an oracle-checked measurer
/// over input `x`, and search.
pub fn tune_graph(g: &Graph, x: TensorData, opts: &TuneOptions) -> Result<TuneOutcome> {
    let space = KnobSpace::for_graph(g, opts.threads)?;
    let measurer = Measurer::new(
        g,
        x,
        opts.threads,
        MeasureOpts { warmup: opts.warmup, iters: opts.iters },
    )?;
    tune_with_measurer(space, &measurer, opts)
}

/// The driver itself, over any [`Measure`] implementation.
pub fn tune_with_measurer(
    space: KnobSpace,
    measurer: &dyn Measure,
    opts: &TuneOptions,
) -> Result<TuneOutcome> {
    let mut rng = crate::util::rng::Rng64::seed_from_u64(opts.seed);
    let mut trials: Vec<Trial> = Vec::new();
    let mut rejected = 0usize;
    let mut seen: HashSet<String> = HashSet::new();

    // The default schedule is always trial 0 — it is the baseline the
    // records file reports against, and if *it* fails the oracle the
    // harness itself is broken: refuse to tune rather than search on top
    // of a lying measurer.
    let default_plan = SchedulePlan::default_for(&space.classes);
    seen.insert(default_plan.describe());
    let d: Measurement = measurer
        .measure(&default_plan)
        .map_err(|e| anyhow!("default schedule failed its oracle check — not tuning: {e}"))?;
    trials.push(Trial { plan: default_plan, ns_per_iter: d.ns_per_iter });
    let mut best = trials[0].clone();

    let budget = opts.budget.max(1);
    let measure_one =
        |plan: SchedulePlan, trials: &mut Vec<Trial>, best: &mut Trial, rejected: &mut usize| {
            match measurer.measure(&plan) {
                Ok(m) => {
                    let t = Trial { plan, ns_per_iter: m.ns_per_iter };
                    if t.ns_per_iter < best.ns_per_iter {
                        *best = t.clone();
                    }
                    trials.push(t);
                    true
                }
                Err(_) => {
                    // Oracle mismatch or compile failure: the candidate is
                    // dropped on the floor — it can never become the
                    // incumbent.
                    *rejected += 1;
                    false
                }
            }
        };

    // ---- Random phase: half the remaining budget ----
    let random_budget = budget.saturating_sub(1) / 2;
    let mut cands: Vec<SchedulePlan> = Vec::new();
    // Oversample so dedup against `seen` still fills the quota.
    for _ in 0..random_budget.saturating_mul(3) {
        if cands.len() >= random_budget.saturating_mul(2) {
            break;
        }
        let p = space.sample(&mut rng);
        if seen.insert(p.describe()) {
            cands.push(p);
        }
    }
    if opts.use_prior {
        // Stable sort by the analytic prior: deterministic tie-breaks, so
        // the measured subset is still a pure function of the seed.
        let m = MachineModel::default();
        cands.sort_by(|a, b| {
            prior_ms(&m, &space, a).total_cmp(&prior_ms(&m, &space, b))
        });
    }
    cands.truncate(random_budget);
    for p in cands {
        measure_one(p, &mut trials, &mut best, &mut rejected);
    }

    // ---- Greedy hill-climb: spend what's left on single-knob moves ----
    let mut remaining = budget.saturating_sub(trials.len() + rejected);
    'climb: loop {
        let mut improved = false;
        for n in space.neighbors(&best.plan) {
            if remaining == 0 {
                break 'climb;
            }
            if !seen.insert(n.describe()) {
                continue;
            }
            remaining -= 1;
            let before = best.ns_per_iter;
            if measure_one(n, &mut trials, &mut best, &mut rejected)
                && best.ns_per_iter < before
            {
                improved = true;
                break; // restart the neighbourhood around the new incumbent
            }
        }
        if !improved {
            break;
        }
    }

    let default_ns = trials[0].ns_per_iter;
    let threads = space.threads;
    Ok(TuneOutcome { best, default_ns, trials, rejected, space, threads })
}

/// Analytic prior for one candidate: the roofline with unfused plans
/// paying doubled activation traffic and band-capped plans losing compute
/// parallelism.  Ordering heuristic only — measurements decide.
fn prior_ms(m: &MachineModel, space: &KnobSpace, plan: &SchedulePlan) -> f64 {
    // The effective fan-out is the most restrictive band cap a class
    // imposes (0 = full width).
    let bands = plan
        .per_class
        .iter()
        .map(|(_, s)| if s.max_bands == 0 { space.threads } else { s.max_bands.min(space.threads) })
        .min()
        .unwrap_or(space.threads)
        .max(1);
    tune_prior_ms(
        m,
        space.flops,
        space.act_bytes,
        space.int8,
        plan.fuse,
        bands,
        plan.uses_micro(),
    )
}
