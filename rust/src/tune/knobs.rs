//! The typed knob space: which schedule axes exist for a given model, and
//! the deterministic seeded samplers / neighbourhood moves the search
//! driver walks them with.
//!
//! A candidate is a [`SchedulePlan`]: two global knobs (fuse-vs-split
//! epilogues, the packed lane-accumulator stack bound) plus one
//! [`StepSched`] per anchor **class** present in the compiled model
//! (conv / q-conv / dense / q-dense × layout — [`ClassKey`]).  Per-class
//! knobs are the banding mode (contiguous / interleaved / dynamic with a
//! chunk granularity) and a band cap (the thread-count axis).  Every knob
//! changes only how work is distributed or where an accumulator lives,
//! never what is computed, so any sampled plan is semantically valid —
//! the measurer's oracle check is defense in depth, not the selection
//! mechanism.

use anyhow::Result;

use crate::executor::Banding;
use crate::graph::compile::{
    AnchorOp, ClassKey, MicroKernel, ScheduleOverrides, StepSched, MAX_FUSED_QCONV_CB,
};
use crate::graph::{compile_graph, Graph, Layout};
use crate::util::rng::Rng64;

/// One candidate schedule for a whole model.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulePlan {
    /// Fuse epilogue chains (the default) or split every op 1:1.
    pub fuse: bool,
    /// Stack bound for the packed q-conv lane accumulator; blocks wider
    /// than this spill to per-band arena windows.
    pub max_stack_lanes: usize,
    /// Per-class step schedules, sorted by key (deterministic identity).
    pub per_class: Vec<(ClassKey, StepSched)>,
}

impl SchedulePlan {
    /// The historical hard-coded schedule: fused, stack accumulator,
    /// default banding everywhere.
    pub fn default_for(classes: &[ClassKey]) -> Self {
        SchedulePlan {
            fuse: true,
            max_stack_lanes: MAX_FUSED_QCONV_CB,
            per_class: classes.iter().map(|&c| (c, StepSched::default())).collect(),
        }
    }

    /// Lower the plan into the compiler's override table.
    pub fn overrides(&self, threads: usize) -> ScheduleOverrides {
        ScheduleOverrides {
            max_stack_lanes: self.max_stack_lanes,
            threads: threads.max(1),
            default_sched: StepSched::default(),
            per_class: self.per_class.iter().copied().collect(),
            per_shape: Default::default(),
        }
    }

    /// Whether any class runs the register-blocked microkernel path.
    pub fn uses_micro(&self) -> bool {
        self.per_class.iter().any(|(_, s)| s.micro.is_some())
    }

    /// Compact human/JSON-stable description — also the plan's identity
    /// for dedup during search (two plans with equal strings compile to
    /// identical programs).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "fuse={} lanes={}",
            if self.fuse { "on" } else { "off" },
            self.max_stack_lanes
        );
        for (key, sched) in &self.per_class {
            s.push_str(&format!(
                " {}[{}]={},b{},{}",
                key.op.as_str(),
                layout_str(key.layout),
                banding_str(sched.banding),
                sched.max_bands,
                micro_str(sched.micro)
            ));
        }
        s
    }
}

/// Canonical layout token used in plan descriptions and records files
/// (`"-"` for layout-less dense anchors).
pub fn layout_str(layout: Option<Layout>) -> String {
    match layout {
        None => "-".into(),
        Some(Layout::Nchw) => "NCHW".into(),
        Some(Layout::Nhwc) => "NHWC".into(),
        Some(Layout::Nchwc(cb)) => format!("NCHW{cb}c"),
    }
}

/// Inverse of [`layout_str`].
pub fn parse_layout_str(s: &str) -> Result<Option<Layout>> {
    Ok(match s {
        "-" => None,
        "NCHW" => Some(Layout::Nchw),
        "NHWC" => Some(Layout::Nhwc),
        other => {
            let inner = other
                .strip_prefix("NCHW")
                .and_then(|r| r.strip_suffix('c'))
                .ok_or_else(|| anyhow::anyhow!("bad layout token {other:?}"))?;
            Some(Layout::Nchwc(inner.parse()?))
        }
    })
}

/// Canonical banding token (`"default"` = the kernel's built-in choice).
pub fn banding_str(b: Option<Banding>) -> String {
    match b {
        None => "default".into(),
        Some(Banding::Contiguous) => "contiguous".into(),
        Some(Banding::Interleaved) => "interleaved".into(),
        Some(Banding::Dynamic { chunk }) => format!("dynamic:{chunk}"),
    }
}

/// Canonical microkernel token (`"-"` = scalar kernels, no pre-packing;
/// otherwise `m{mr}n{nr}k{ku}` — the register-tile factors).
pub fn micro_str(m: Option<MicroKernel>) -> String {
    match m {
        None => "-".into(),
        Some(mk) => format!("m{}n{}k{}", mk.mr, mk.nr, mk.ku),
    }
}

/// Inverse of [`micro_str`].
pub fn parse_micro_str(s: &str) -> Result<Option<MicroKernel>> {
    if s == "-" {
        return Ok(None);
    }
    let bad = || anyhow::anyhow!("bad micro token {s:?}");
    let rest = s.strip_prefix('m').ok_or_else(bad)?;
    let (mr, rest) = rest.split_once('n').ok_or_else(bad)?;
    let (nr, ku) = rest.split_once('k').ok_or_else(bad)?;
    Ok(Some(MicroKernel { mr: mr.parse()?, nr: nr.parse()?, ku: ku.parse()? }))
}

/// Inverse of [`banding_str`].
pub fn parse_banding_str(s: &str) -> Result<Option<Banding>> {
    Ok(match s {
        "default" => None,
        "contiguous" => Some(Banding::Contiguous),
        "interleaved" => Some(Banding::Interleaved),
        other => {
            let chunk = other
                .strip_prefix("dynamic:")
                .ok_or_else(|| anyhow::anyhow!("bad banding token {other:?}"))?;
            Some(Banding::Dynamic { chunk: chunk.parse()? })
        }
    })
}

/// The banding choices a class can take (chunk sizes are the band
/// granularity axis).
const BANDING_CHOICES: [Option<Banding>; 6] = [
    None,
    Some(Banding::Contiguous),
    Some(Banding::Interleaved),
    Some(Banding::Dynamic { chunk: 1 }),
    Some(Banding::Dynamic { chunk: 2 }),
    Some(Banding::Dynamic { chunk: 4 }),
];

/// Stack-lane bounds the lane-accumulator knob can take (only sampled
/// when a packed quantized class exists; `MAX_FUSED_QCONV_CB` = all
/// stack, smaller values force the arena-spill strategy earlier).
const LANE_CHOICES: [usize; 4] = [MAX_FUSED_QCONV_CB, 32, 8, 2];

/// Register-tile choices for int8-bearing classes (`None` = the scalar
/// kernels, no pre-packing).  Every choice is bit-exact — the tiles shape
/// loops only — so the sampler may pick freely.
const MICRO_CHOICES: [Option<MicroKernel>; 4] = [
    None,
    Some(MicroKernel { mr: 4, nr: 4, ku: 4 }),
    Some(MicroKernel { mr: 4, nr: 8, ku: 8 }),
    Some(MicroKernel { mr: 4, nr: 16, ku: 16 }),
];

/// The knob space of one model at one pool width: the anchor classes its
/// fused compile emits (with a representative output shape per class, for
/// the records file) plus rough model-level cost terms for the
/// `perfmodel` prior.
#[derive(Debug, Clone)]
pub struct KnobSpace {
    pub classes: Vec<ClassKey>,
    /// Representative destination shape per class (parallel to
    /// `classes`): the first matching step's output.
    pub shapes: Vec<Vec<usize>>,
    /// Whether each class carries an int8 weight (parallel to `classes`)
    /// — the microkernel axis only exists for those; on fp32 classes the
    /// compiler would ignore the knob, so sampling it would just create
    /// duplicate candidates.
    pub micro_live: Vec<bool>,
    pub threads: usize,
    /// Approximate anchor FLOPs of one inference (prior input).
    pub flops: f64,
    /// Approximate activation bytes moved per inference (prior input).
    pub act_bytes: f64,
    /// Whether the model runs quantized anchors.
    pub int8: bool,
}

impl KnobSpace {
    /// Enumerate the knob space of `g` by compiling it once under the
    /// default schedule.
    pub fn for_graph(g: &Graph, threads: usize) -> Result<KnobSpace> {
        let cg = compile_graph(g, true)?;
        let mut seen: Vec<(ClassKey, Vec<usize>, bool)> = Vec::new();
        for step in &cg.steps {
            if let Some(key) = step.op.class_key() {
                let s8w = step
                    .srcs
                    .get(1)
                    .is_some_and(|(_, t)| t.dtype == crate::graph::ir::IrDType::S8);
                if !seen.iter().any(|(k, _, _)| *k == key) {
                    seen.push((key, step.dst_ty.shape.clone(), s8w));
                }
            }
        }
        seen.sort_by_key(|(k, _, _)| *k);
        let int8 = seen
            .iter()
            .any(|(k, _, _)| matches!(k.op, AnchorOp::QConv2d | AnchorOp::QDense));
        let (flops, act_bytes) = graph_cost(g);
        let mut classes = Vec::with_capacity(seen.len());
        let mut shapes = Vec::with_capacity(seen.len());
        let mut micro_live = Vec::with_capacity(seen.len());
        for (k, sh, live) in seen {
            classes.push(k);
            shapes.push(sh);
            micro_live.push(live);
        }
        Ok(KnobSpace {
            classes,
            shapes,
            micro_live,
            threads: threads.max(1),
            flops,
            act_bytes,
            int8,
        })
    }

    /// Whether the lane-accumulator knob is live (a packed quantized
    /// anchor exists).
    pub fn has_packed_qconv(&self) -> bool {
        self.classes.iter().any(|k| {
            k.op == AnchorOp::QConv2d && matches!(k.layout, Some(Layout::Nchwc(_)))
        })
    }

    /// Band-cap choices at this pool width (0 = full width).
    fn band_choices(&self) -> Vec<usize> {
        let mut v = vec![0usize, 1];
        if self.threads > 2 {
            v.push(self.threads / 2);
        }
        v.dedup();
        v
    }

    /// Draw one candidate, uniformly per axis — a pure function of the
    /// rng state, so a seeded search is reproducible.
    pub fn sample(&self, rng: &mut Rng64) -> SchedulePlan {
        let bands = self.band_choices();
        SchedulePlan {
            fuse: rng.range_usize(0, 9) > 0, // split-everything is rarely right: 1-in-10
            max_stack_lanes: if self.has_packed_qconv() {
                LANE_CHOICES[rng.range_usize(0, LANE_CHOICES.len() - 1)]
            } else {
                MAX_FUSED_QCONV_CB
            },
            per_class: self
                .classes
                .iter()
                .enumerate()
                .map(|(i, &key)| {
                    let sched = StepSched {
                        banding: BANDING_CHOICES[rng.range_usize(0, BANDING_CHOICES.len() - 1)],
                        max_bands: bands[rng.range_usize(0, bands.len() - 1)],
                        micro: if self.micro_live[i] {
                            MICRO_CHOICES[rng.range_usize(0, MICRO_CHOICES.len() - 1)]
                        } else {
                            None
                        },
                    };
                    (key, sched)
                })
                .collect(),
        }
    }

    /// Single-knob mutations of `plan`, in a deterministic order — the
    /// hill-climber's neighbourhood.
    pub fn neighbors(&self, plan: &SchedulePlan) -> Vec<SchedulePlan> {
        let mut out = Vec::new();
        {
            let mut p = plan.clone();
            p.fuse = !p.fuse;
            out.push(p);
        }
        if self.has_packed_qconv() {
            for lanes in LANE_CHOICES {
                if lanes != plan.max_stack_lanes {
                    let mut p = plan.clone();
                    p.max_stack_lanes = lanes;
                    out.push(p);
                }
            }
        }
        for (i, (_, cur)) in plan.per_class.iter().enumerate() {
            for banding in BANDING_CHOICES {
                if banding != cur.banding {
                    let mut p = plan.clone();
                    p.per_class[i].1.banding = banding;
                    out.push(p);
                }
            }
            for bands in self.band_choices() {
                if bands != cur.max_bands {
                    let mut p = plan.clone();
                    p.per_class[i].1.max_bands = bands;
                    out.push(p);
                }
            }
            if self.micro_live.get(i).copied().unwrap_or(false) {
                for micro in MICRO_CHOICES {
                    if micro != cur.micro {
                        let mut p = plan.clone();
                        p.per_class[i].1.micro = micro;
                        out.push(p);
                    }
                }
            }
        }
        out
    }
}

/// Rough anchor-FLOPs + activation-traffic estimate of one inference —
/// inputs to the `perfmodel` cost prior, not a measurement.
fn graph_cost(g: &Graph) -> (f64, f64) {
    use crate::graph::ir::{dims_of, Op};
    let mut flops = 0f64;
    let mut bytes = 0f64;
    for node in &g.nodes {
        match &node.op {
            Op::Conv2d { layout, .. } => {
                let Ok((n, k, oh, ow)) = dims_of(&node.ty.shape, *layout) else {
                    continue;
                };
                let ws = &g.nodes[node.inputs[1]].ty.shape;
                let (c, r, s) = match layout {
                    Layout::Nchw => (ws[1], ws[2], ws[3]),
                    Layout::Nhwc => (ws[2], ws[0], ws[1]),
                    Layout::Nchwc(_) => (ws[1] * ws[4], ws[2], ws[3]),
                };
                flops += crate::perfmodel::conv_flops(n, c, k, oh, ow, r, s);
            }
            Op::Dense => {
                let xs = &g.nodes[node.inputs[0]].ty.shape;
                let ws = &g.nodes[node.inputs[1]].ty.shape;
                if xs.len() == 2 && ws.len() == 2 {
                    flops += 2.0 * (xs[0] * xs[1] * ws[1]) as f64;
                }
            }
            Op::Constant(_) => continue,
            _ => {}
        }
        // Every non-constant value is written once and read at least
        // once downstream.
        bytes += 2.0 * node.ty.byte_len() as f64;
    }
    (flops, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banding_and_layout_tokens_round_trip() {
        for b in BANDING_CHOICES {
            assert_eq!(parse_banding_str(&banding_str(b)).unwrap(), b);
        }
        for l in [None, Some(Layout::Nchw), Some(Layout::Nhwc), Some(Layout::Nchwc(8))] {
            assert_eq!(parse_layout_str(&layout_str(l)).unwrap(), l);
        }
        for m in MICRO_CHOICES {
            assert_eq!(parse_micro_str(&micro_str(m)).unwrap(), m);
        }
        assert!(parse_banding_str("stolen").is_err());
        assert!(parse_layout_str("NCHWxc").is_err());
        assert!(parse_micro_str("m4x8").is_err());
        assert!(parse_micro_str("tile").is_err());
    }

    #[test]
    fn sampler_is_seed_deterministic() {
        let g = crate::graph::build_resnet_ir(1, 8, 7).unwrap();
        let space = KnobSpace::for_graph(&g, 4).unwrap();
        assert!(!space.classes.is_empty());
        let mut a = Rng64::seed_from_u64(9);
        let mut b = Rng64::seed_from_u64(9);
        for _ in 0..32 {
            assert_eq!(space.sample(&mut a), space.sample(&mut b));
        }
    }

    #[test]
    fn neighbors_differ_in_exactly_one_knob_axis() {
        let g = crate::graph::build_resnet_ir(1, 8, 7).unwrap();
        let space = KnobSpace::for_graph(&g, 4).unwrap();
        let plan = SchedulePlan::default_for(&space.classes);
        let ns = space.neighbors(&plan);
        assert!(!ns.is_empty());
        for n in &ns {
            assert_ne!(n.describe(), plan.describe());
        }
    }
}
