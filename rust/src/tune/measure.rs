//! Candidate measurement: compile a [`SchedulePlan`] through
//! `graph::compile`, prove it bit-for-bit against the interpreter oracle,
//! and only then time it on the real step stream.
//!
//! The oracle gate runs **before** any timing: a candidate whose output
//! differs from [`crate::graph::interp::evaluate`] by a single bit is
//! rejected with an error and can never become the incumbent, no matter
//! how fast it ran.  (Schedule knobs cannot change results by
//! construction — every banding mode assigns each row to exactly one band
//! — so a rejection here means a compiler/executor bug; the tuner
//! refusing to reward it is exactly the behaviour we want then.)
//!
//! Timing follows the repo's bench protocol in miniature: `warmup`
//! untimed runs, then `iters` individually timed runs reduced by a
//! **trimmed mean** (drop the top and bottom ~10% of samples) to shed
//! scheduler noise without letting one lucky run win the search.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::knobs::SchedulePlan;
use crate::executor::ArenaExec;
use crate::graph::{evaluate, Graph};
use crate::runtime::TensorData;

/// Measurement protocol knobs.
#[derive(Debug, Clone, Copy)]
pub struct MeasureOpts {
    /// Untimed runs before the clock starts.
    pub warmup: usize,
    /// Timed runs per candidate (trimmed-mean reduced).
    pub iters: usize,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts { warmup: 2, iters: 10 }
    }
}

/// One accepted candidate's timing.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Trimmed-mean nanoseconds per inference.
    pub ns_per_iter: f64,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.ns_per_iter / 1e6
    }
}

/// Something that can score a candidate plan — the search driver's only
/// view of measurement.  The production implementation is [`Measurer`];
/// tests substitute deterministic cost functions to pin the driver's
/// seed-determinism without timing noise.
pub trait Measure {
    fn measure(&self, plan: &SchedulePlan) -> Result<Measurement>;
}

/// The real measurer: one model, one input, one pre-computed oracle
/// output; every candidate compiles fresh and must reproduce the oracle
/// exactly before its clock starts.
pub struct Measurer {
    g: Graph,
    x: TensorData,
    oracle: TensorData,
    threads: usize,
    opts: MeasureOpts,
}

impl Measurer {
    /// Evaluate the oracle once and build a measurer around it.
    pub fn new(g: &Graph, x: TensorData, threads: usize, opts: MeasureOpts) -> Result<Self> {
        let oracle = evaluate(g, &x)?;
        Ok(Self::with_oracle(g, x, oracle, threads, opts))
    }

    /// Build around a pre-computed expected output.  Public so tests can
    /// verify the rejection path with a deliberately wrong oracle.
    pub fn with_oracle(
        g: &Graph,
        x: TensorData,
        oracle: TensorData,
        threads: usize,
        opts: MeasureOpts,
    ) -> Self {
        Measurer { g: g.clone(), x, oracle, threads: threads.max(1), opts }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compile `plan` and prove it against the oracle (one run).  Returns
    /// the executor ready for timing; `Err` if compilation fails or any
    /// output bit differs.
    pub fn check(&self, plan: &SchedulePlan) -> Result<ArenaExec> {
        let exec = ArenaExec::with_schedule(
            &self.g,
            plan.fuse,
            self.threads,
            &plan.overrides(self.threads),
        )?;
        let mut out = TensorData::zeros(self.oracle.dtype, self.oracle.shape.clone());
        exec.run_into(&self.x, &mut out)?;
        if out != self.oracle {
            return Err(anyhow!(
                "oracle mismatch: candidate [{}] diverged from interp::evaluate — rejected",
                plan.describe()
            ));
        }
        Ok(exec)
    }
}

impl Measure for Measurer {
    fn measure(&self, plan: &SchedulePlan) -> Result<Measurement> {
        let exec = self.check(plan)?;
        let mut out = TensorData::zeros(self.oracle.dtype, self.oracle.shape.clone());
        for _ in 0..self.opts.warmup {
            exec.run_into(&self.x, &mut out)?;
        }
        let iters = self.opts.iters.max(1);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            exec.run_into(&self.x, &mut out)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e9);
        }
        Ok(Measurement { ns_per_iter: trimmed_mean(&mut samples) })
    }
}

/// Mean of the samples with ~10% shaved off each tail (at least one
/// sample survives).
pub fn trimmed_mean(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.total_cmp(b));
    let trim = samples.len() / 10;
    let kept = &samples[trim..samples.len() - trim];
    kept.iter().sum::<f64>() / kept.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_sheds_outliers() {
        let mut flat = vec![10.0; 10];
        assert!((trimmed_mean(&mut flat) - 10.0).abs() < 1e-9);
        // One wild outlier in ten samples lands in the trimmed tail.
        let mut noisy = vec![10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 1e9];
        assert!((trimmed_mean(&mut noisy) - 10.0).abs() < 1e-9);
        let mut single = vec![7.0];
        assert!((trimmed_mean(&mut single) - 7.0).abs() < 1e-9);
    }
}
