//! Persisted tuning records: the best-config cache keyed by
//! (step op, shape, layout, precision, threads), JSON on disk, loadable
//! back into a [`ScheduleOverrides`] table by the serving factory, the
//! CLI, and the benches.
//!
//! The file is self-describing: run metadata (model geometry, thread
//! width, budget), the winning global knobs, one task entry per anchor
//! class with its chosen schedule, and the tuned-vs-default ns/iter the
//! run measured.  Records survive `save → load → overrides` exactly (the
//! round-trip test pins this), and unknown classes simply fall back to
//! the default schedule, so a records file tuned on one model variant can
//! be applied to another without breaking anything.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::knobs::{
    banding_str, layout_str, parse_banding_str, parse_layout_str, SchedulePlan,
};
use super::search::TuneOutcome;
use crate::graph::compile::{AnchorOp, ClassKey, ScheduleOverrides, StepSched};
use crate::util::json::Json;

/// The cache key of one tuned task, as persisted.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskKey {
    pub op: AnchorOp,
    pub layout: Option<crate::graph::Layout>,
    /// Precision the op family implies (`int8` for q-anchors).
    pub precision: String,
    /// Representative output shape of the class in the tuned model.
    pub shape: Vec<usize>,
    /// Pool width the schedule was tuned at.
    pub threads: usize,
}

impl TaskKey {
    pub fn class(&self) -> ClassKey {
        ClassKey { op: self.op, layout: self.layout }
    }
}

/// One tuned task: key + winning step schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecord {
    pub key: TaskKey,
    pub sched: StepSched,
}

/// A whole tuning run, as persisted.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecords {
    /// Model the run tuned (informational).
    pub model: String,
    pub layout: String,
    pub precision: String,
    pub image: usize,
    pub batch: usize,
    pub threads: usize,
    /// Winning global knobs.
    pub fuse: bool,
    pub max_stack_lanes: usize,
    /// Per-class winners.
    pub records: Vec<TuneRecord>,
    /// Run accounting.
    pub trials: usize,
    pub rejected: usize,
    pub default_ns_per_iter: f64,
    pub best_ns_per_iter: f64,
}

/// Metadata the caller knows about the tuned model (the outcome itself
/// doesn't carry geometry).
#[derive(Debug, Clone)]
pub struct RunMeta {
    pub model: String,
    pub layout: String,
    pub precision: String,
    pub image: usize,
    pub batch: usize,
}

fn precision_of(op: AnchorOp) -> &'static str {
    match op {
        AnchorOp::QConv2d | AnchorOp::QDense => "int8",
        AnchorOp::Conv2d | AnchorOp::Dense => "fp32",
    }
}

impl TuneRecords {
    /// Freeze a search outcome into its persisted form.
    pub fn from_outcome(outcome: &TuneOutcome, meta: &RunMeta) -> TuneRecords {
        let best = &outcome.best.plan;
        let sched_of = |key: &ClassKey| -> StepSched {
            best.per_class
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, s)| *s)
                .unwrap_or_default()
        };
        let records = outcome
            .space
            .classes
            .iter()
            .zip(&outcome.space.shapes)
            .map(|(key, shape)| TuneRecord {
                key: TaskKey {
                    op: key.op,
                    layout: key.layout,
                    precision: precision_of(key.op).into(),
                    shape: shape.clone(),
                    threads: outcome.threads,
                },
                sched: sched_of(key),
            })
            .collect();
        TuneRecords {
            model: meta.model.clone(),
            layout: meta.layout.clone(),
            precision: meta.precision.clone(),
            image: meta.image,
            batch: meta.batch,
            threads: outcome.threads,
            fuse: best.fuse,
            max_stack_lanes: best.max_stack_lanes,
            records,
            trials: outcome.trials.len(),
            rejected: outcome.rejected,
            default_ns_per_iter: outcome.default_ns,
            best_ns_per_iter: outcome.best.ns_per_iter,
        }
    }

    /// The compiler override table this records file selects.  `threads`
    /// is the pool width of the engine being built (spill windows are
    /// re-sized for it; the per-class knobs transfer as-is).
    pub fn overrides(&self, threads: usize) -> ScheduleOverrides {
        let per_class: HashMap<ClassKey, StepSched> = self
            .records
            .iter()
            .map(|r| (r.key.class(), r.sched))
            .collect();
        ScheduleOverrides {
            max_stack_lanes: self.max_stack_lanes,
            threads: threads.max(1),
            default_sched: StepSched::default(),
            per_class,
        }
    }

    /// Compact one-line knob summary (for bench rows / logs) — exactly
    /// the recorded plan's identity string.
    pub fn knob_summary(&self) -> String {
        self.best_plan().describe()
    }

    /// The best plan restricted to the recorded classes (what `describe`
    /// strings in trials referred to).
    pub fn best_plan(&self) -> SchedulePlan {
        SchedulePlan {
            fuse: self.fuse,
            max_stack_lanes: self.max_stack_lanes,
            per_class: self.records.iter().map(|r| (r.key.class(), r.sched)).collect(),
        }
    }

    // ---- JSON ----

    pub fn to_json(&self) -> Json {
        let tasks: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::str(r.key.op.as_str())),
                    ("layout", Json::str(layout_str(r.key.layout))),
                    ("precision", Json::str(r.key.precision.clone())),
                    (
                        "shape",
                        Json::Arr(r.key.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                    ),
                    ("threads", Json::num(r.key.threads as f64)),
                    ("banding", Json::str(banding_str(r.sched.banding))),
                    ("max_bands", Json::num(r.sched.max_bands as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("kind", Json::str("tvmq-tune-records")),
            ("model", Json::str(self.model.clone())),
            ("layout", Json::str(self.layout.clone())),
            ("precision", Json::str(self.precision.clone())),
            ("image", Json::num(self.image as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("fuse", Json::Bool(self.fuse)),
            ("max_stack_lanes", Json::num(self.max_stack_lanes as f64)),
            ("tasks", Json::Arr(tasks)),
            ("trials", Json::num(self.trials as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("default_ns_per_iter", Json::num(self.default_ns_per_iter)),
            ("best_ns_per_iter", Json::num(self.best_ns_per_iter)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TuneRecords> {
        if j.get("kind")?.as_str()? != "tvmq-tune-records" {
            return Err(anyhow!("not a tune-records file"));
        }
        let records = j
            .get("tasks")?
            .as_arr()?
            .iter()
            .map(|t| {
                let sched = StepSched {
                    banding: parse_banding_str(t.get("banding")?.as_str()?)?,
                    max_bands: t.get("max_bands")?.as_usize()?,
                };
                Ok(TuneRecord {
                    key: TaskKey {
                        op: t.get("op")?.as_str()?.parse()?,
                        layout: parse_layout_str(t.get("layout")?.as_str()?)?,
                        precision: t.get("precision")?.as_str()?.to_string(),
                        shape: t
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        threads: t.get("threads")?.as_usize()?,
                    },
                    sched,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TuneRecords {
            model: j.get("model")?.as_str()?.to_string(),
            layout: j.get("layout")?.as_str()?.to_string(),
            precision: j.get("precision")?.as_str()?.to_string(),
            image: j.get("image")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            threads: j.get("threads")?.as_usize()?,
            fuse: match j.get("fuse")? {
                Json::Bool(b) => *b,
                other => return Err(anyhow!("fuse must be a boolean, got {other:?}")),
            },
            max_stack_lanes: j.get("max_stack_lanes")?.as_usize()?,
            records,
            trials: j.get("trials")?.as_usize()?,
            rejected: j.get("rejected")?.as_usize()?,
            default_ns_per_iter: j.get("default_ns_per_iter")?.as_f64()?,
            best_ns_per_iter: j.get("best_ns_per_iter")?.as_f64()?,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing tune records to {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TuneRecords> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune records from {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing tune records {}", path.display()))
    }
}
