//! Persisted tuning records: the best-config cache keyed by
//! (step op, shape, layout, precision, threads), JSON on disk, loadable
//! back into a [`ScheduleOverrides`] table by the serving factory, the
//! CLI, and the benches.
//!
//! The file is self-describing: run metadata (model geometry, thread
//! width, budget), the winning global knobs, one task entry per anchor
//! class with its chosen schedule, and the tuned-vs-default ns/iter the
//! run measured.  Records survive `save → load → overrides` exactly (the
//! round-trip test pins this), and unknown classes simply fall back to
//! the default schedule, so a records file tuned on one model variant can
//! be applied to another without breaking anything.
//!
//! Format evolution: the file carries a schema `version`
//! ([`RECORDS_VERSION`]).  Loading tolerates unknown fields (they are
//! simply ignored) and older versions (missing newer fields default), so
//! records written by past builds keep loading; files from a *future*
//! schema, or corrupt files, fail `load` — serving paths use
//! [`TuneRecords::load_lenient`], which logs and falls back to the
//! default schedule instead of erroring.
//!
//! Cross-run merging ([`merge`]): records files accumulated across runs
//! (different budgets, seeds, machines) merge by task key, keeping the
//! config with the best measured ns/iter — `tvmq tune --merge a.json
//! b.json -o out.json`, applied automatically when a `--cache-dir`
//! holds several records files.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::knobs::{
    banding_str, layout_str, micro_str, parse_banding_str, parse_layout_str,
    parse_micro_str, SchedulePlan,
};
use super::search::TuneOutcome;
use crate::graph::compile::{
    AnchorOp, ClassKey, ScheduleOverrides, ShapeKey, StepSched,
};
use crate::util::json::Json;

/// The cache key of one tuned task, as persisted.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskKey {
    pub op: AnchorOp,
    pub layout: Option<crate::graph::Layout>,
    /// Precision the op family implies (`int8` for q-anchors).
    pub precision: String,
    /// Representative output shape of the class in the tuned model.
    pub shape: Vec<usize>,
    /// Pool width the schedule was tuned at.
    pub threads: usize,
}

impl TaskKey {
    pub fn class(&self) -> ClassKey {
        ClassKey { op: self.op, layout: self.layout }
    }
}

/// One tuned task: key + winning step schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecord {
    pub key: TaskKey,
    pub sched: StepSched,
    /// Whole-plan ns/iter measured for the run this config won (schema
    /// v2; v1 files load with `None` and fall back to the run-level
    /// `best_ns_per_iter`).  The merge keeps the lowest.
    pub ns_per_iter: Option<f64>,
}

/// A whole tuning run, as persisted.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecords {
    /// Model the run tuned (informational).
    pub model: String,
    pub layout: String,
    pub precision: String,
    pub image: usize,
    pub batch: usize,
    pub threads: usize,
    /// Winning global knobs.
    pub fuse: bool,
    pub max_stack_lanes: usize,
    /// Per-class winners.
    pub records: Vec<TuneRecord>,
    /// Run accounting.
    pub trials: usize,
    pub rejected: usize,
    pub default_ns_per_iter: f64,
    pub best_ns_per_iter: f64,
}

/// Metadata the caller knows about the tuned model (the outcome itself
/// doesn't carry geometry).
#[derive(Debug, Clone)]
pub struct RunMeta {
    pub model: String,
    pub layout: String,
    pub precision: String,
    pub image: usize,
    pub batch: usize,
}

fn precision_of(op: AnchorOp) -> &'static str {
    match op {
        AnchorOp::QConv2d | AnchorOp::QDense => "int8",
        AnchorOp::Conv2d | AnchorOp::Dense => "fp32",
    }
}

/// Current schema version.  v3 adds the per-task `micro` register-tile
/// token; v2 added per-task `ns_per_iter`.  Older files still load (the
/// missing fields default to `None`); versions beyond this fail `load`
/// (and fall back to defaults via [`TuneRecords::load_lenient`]).
pub const RECORDS_VERSION: u64 = 3;

impl TuneRecords {
    /// Freeze a search outcome into its persisted form.
    pub fn from_outcome(outcome: &TuneOutcome, meta: &RunMeta) -> TuneRecords {
        let best = &outcome.best.plan;
        let sched_of = |key: &ClassKey| -> StepSched {
            best.per_class
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, s)| *s)
                .unwrap_or_default()
        };
        let records = outcome
            .space
            .classes
            .iter()
            .zip(&outcome.space.shapes)
            .map(|(key, shape)| TuneRecord {
                key: TaskKey {
                    op: key.op,
                    layout: key.layout,
                    precision: precision_of(key.op).into(),
                    shape: shape.clone(),
                    threads: outcome.threads,
                },
                sched: sched_of(key),
                ns_per_iter: Some(outcome.best.ns_per_iter),
            })
            .collect();
        TuneRecords {
            model: meta.model.clone(),
            layout: meta.layout.clone(),
            precision: meta.precision.clone(),
            image: meta.image,
            batch: meta.batch,
            threads: outcome.threads,
            fuse: best.fuse,
            max_stack_lanes: best.max_stack_lanes,
            records,
            trials: outcome.trials.len(),
            rejected: outcome.rejected,
            default_ns_per_iter: outcome.default_ns,
            best_ns_per_iter: outcome.best.ns_per_iter,
        }
    }

    /// The compiler override table this records file selects.  `threads`
    /// is the pool width of the engine being built (spill windows are
    /// re-sized for it; the per-class knobs transfer as-is).
    ///
    /// Every task also lands in the exact-shape table (`per_shape`), so
    /// merged files holding several shapes of the same class resolve
    /// per shape; the class-level entry (first task of each class, in
    /// file order) remains the fallback for shapes no run has tuned.
    pub fn overrides(&self, threads: usize) -> ScheduleOverrides {
        let mut per_class: HashMap<ClassKey, StepSched> = HashMap::new();
        let mut per_shape: HashMap<ShapeKey, StepSched> = HashMap::new();
        for r in &self.records {
            per_class.entry(r.key.class()).or_insert(r.sched);
            per_shape.insert(
                ShapeKey { class: r.key.class(), shape: r.key.shape.clone() },
                r.sched,
            );
        }
        ScheduleOverrides {
            max_stack_lanes: self.max_stack_lanes,
            threads: threads.max(1),
            default_sched: StepSched::default(),
            per_class,
            per_shape,
        }
    }

    /// Compact one-line knob summary (for bench rows / logs) — exactly
    /// the recorded plan's identity string.
    pub fn knob_summary(&self) -> String {
        self.best_plan().describe()
    }

    /// The best plan restricted to the recorded classes (what `describe`
    /// strings in trials referred to).
    pub fn best_plan(&self) -> SchedulePlan {
        SchedulePlan {
            fuse: self.fuse,
            max_stack_lanes: self.max_stack_lanes,
            per_class: self.records.iter().map(|r| (r.key.class(), r.sched)).collect(),
        }
    }

    // ---- JSON ----

    pub fn to_json(&self) -> Json {
        let tasks: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::str(r.key.op.as_str())),
                    ("layout", Json::str(layout_str(r.key.layout))),
                    ("precision", Json::str(r.key.precision.clone())),
                    (
                        "shape",
                        Json::Arr(r.key.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                    ),
                    ("threads", Json::num(r.key.threads as f64)),
                    ("banding", Json::str(banding_str(r.sched.banding))),
                    ("max_bands", Json::num(r.sched.max_bands as f64)),
                    ("micro", Json::str(micro_str(r.sched.micro))),
                    (
                        "ns_per_iter",
                        r.ns_per_iter.map(Json::num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(RECORDS_VERSION as f64)),
            ("kind", Json::str("tvmq-tune-records")),
            ("model", Json::str(self.model.clone())),
            ("layout", Json::str(self.layout.clone())),
            ("precision", Json::str(self.precision.clone())),
            ("image", Json::num(self.image as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("fuse", Json::Bool(self.fuse)),
            ("max_stack_lanes", Json::num(self.max_stack_lanes as f64)),
            ("tasks", Json::Arr(tasks)),
            ("trials", Json::num(self.trials as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("default_ns_per_iter", Json::num(self.default_ns_per_iter)),
            ("best_ns_per_iter", Json::num(self.best_ns_per_iter)),
        ])
    }

    /// Parse a records file.  Unknown fields are ignored (the parser only
    /// looks keys up, never enumerates), so files written by newer builds
    /// that merely *added* fields still load; a `version` beyond
    /// [`RECORDS_VERSION`] is refused, because its semantics are unknown.
    pub fn from_json(j: &Json) -> Result<TuneRecords> {
        if j.get("kind")?.as_str()? != "tvmq-tune-records" {
            return Err(anyhow!("not a tune-records file"));
        }
        // v0 files (pre-versioning) carry no version key; treat as 1.
        let version = match j.opt("version") {
            Some(v) => v.as_u64()?,
            None => 1,
        };
        if version > RECORDS_VERSION {
            return Err(anyhow!(
                "records schema version {version} is newer than supported {RECORDS_VERSION}"
            ));
        }
        let records = j
            .get("tasks")?
            .as_arr()?
            .iter()
            .map(|t| {
                let sched = StepSched {
                    banding: parse_banding_str(t.get("banding")?.as_str()?)?,
                    max_bands: t.get("max_bands")?.as_usize()?,
                    // Absent before schema v3 — scalar kernels.
                    micro: match t.opt("micro") {
                        Some(v) => parse_micro_str(v.as_str()?)?,
                        None => None,
                    },
                };
                Ok(TuneRecord {
                    key: TaskKey {
                        op: t.get("op")?.as_str()?.parse()?,
                        layout: parse_layout_str(t.get("layout")?.as_str()?)?,
                        precision: t.get("precision")?.as_str()?.to_string(),
                        shape: t
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        threads: t.get("threads")?.as_usize()?,
                    },
                    sched,
                    ns_per_iter: match t.opt("ns_per_iter") {
                        Some(v) => Some(v.as_f64()?),
                        None => None,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TuneRecords {
            model: j.get("model")?.as_str()?.to_string(),
            layout: j.get("layout")?.as_str()?.to_string(),
            precision: j.get("precision")?.as_str()?.to_string(),
            image: j.get("image")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            threads: j.get("threads")?.as_usize()?,
            fuse: match j.get("fuse")? {
                Json::Bool(b) => *b,
                other => return Err(anyhow!("fuse must be a boolean, got {other:?}")),
            },
            max_stack_lanes: j.get("max_stack_lanes")?.as_usize()?,
            records,
            trials: j.get("trials")?.as_usize()?,
            rejected: j.get("rejected")?.as_usize()?,
            default_ns_per_iter: j.get("default_ns_per_iter")?.as_f64()?,
            best_ns_per_iter: j.get("best_ns_per_iter")?.as_f64()?,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing tune records to {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TuneRecords> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune records from {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing tune records {}", path.display()))
    }

    /// [`TuneRecords::load`] for serving paths: a corrupt, unreadable, or
    /// future-versioned file logs a warning to stderr and yields `None`
    /// (the caller falls back to the default schedule) instead of killing
    /// the serve.
    pub fn load_lenient(path: impl AsRef<Path>) -> Option<TuneRecords> {
        let path = path.as_ref();
        match Self::load(path) {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!(
                    "tvmq: warning: ignoring tune records {} (falling back to the \
                     default schedule): {e:#}",
                    path.display()
                );
                None
            }
        }
    }

    /// Warn (once per process, to stderr) when these records were tuned
    /// at a different pool width than the engine now being built.  The
    /// per-class knobs still transfer — spill windows are re-sized — but
    /// the measured ranking may not, so the mismatch should be visible
    /// rather than silent.
    pub fn warn_if_thread_mismatch(&self, serving_threads: usize) {
        static WARNED: std::sync::Once = std::sync::Once::new();
        if self.threads != serving_threads.max(1) {
            WARNED.call_once(|| {
                eprintln!(
                    "tvmq: warning: tune records were tuned at {} thread(s) but serving \
                     uses {}; applying the schedule anyway (re-tune at the serving width \
                     for best results)",
                    self.threads,
                    serving_threads.max(1)
                );
            });
        }
    }
}

/// Canonical identity of a task entry (merge key): everything in
/// [`TaskKey`], rendered stably.
fn task_key_str(k: &TaskKey) -> String {
    format!(
        "{}|{}|{}|{:?}|t{}",
        k.op.as_str(),
        layout_str(k.layout),
        k.precision,
        k.shape,
        k.threads
    )
}

/// Merge tuning runs by task key, keeping the best-measured config.
///
/// Per-task measurement is the record's `ns_per_iter` (schema v2),
/// falling back to the run-level `best_ns_per_iter` for v1 files.  Global
/// knobs (`fuse`, `max_stack_lanes`) and run metadata come from the run
/// with the best overall ns/iter; trial/rejection counts accumulate.
pub fn merge(runs: &[TuneRecords]) -> Result<TuneRecords> {
    if runs.is_empty() {
        return Err(anyhow!("nothing to merge: no records"));
    }
    let base = runs
        .iter()
        .min_by(|a, b| a.best_ns_per_iter.total_cmp(&b.best_ns_per_iter))
        .expect("non-empty");
    // Insertion order is kept (first-seen key wins position), so merging
    // is deterministic in input order.
    let mut order: Vec<String> = Vec::new();
    let mut best: HashMap<String, (TuneRecord, f64)> = HashMap::new();
    for run in runs {
        for r in &run.records {
            let ns = r.ns_per_iter.unwrap_or(run.best_ns_per_iter);
            let key = task_key_str(&r.key);
            match best.get_mut(&key) {
                None => {
                    order.push(key.clone());
                    let mut rec = r.clone();
                    rec.ns_per_iter = Some(ns);
                    best.insert(key, (rec, ns));
                }
                Some((cur, cur_ns)) => {
                    if ns < *cur_ns {
                        *cur = r.clone();
                        cur.ns_per_iter = Some(ns);
                        *cur_ns = ns;
                    }
                }
            }
        }
    }
    let records: Vec<TuneRecord> = order
        .iter()
        .map(|k| best[k].0.clone())
        .collect();
    Ok(TuneRecords {
        model: base.model.clone(),
        layout: base.layout.clone(),
        precision: base.precision.clone(),
        image: base.image,
        batch: base.batch,
        threads: base.threads,
        fuse: base.fuse,
        max_stack_lanes: base.max_stack_lanes,
        records,
        trials: runs.iter().map(|r| r.trials).sum(),
        rejected: runs.iter().map(|r| r.rejected).sum(),
        default_ns_per_iter: base.default_ns_per_iter,
        best_ns_per_iter: base.best_ns_per_iter,
    })
}
