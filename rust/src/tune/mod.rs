//! `tune` — an AutoTVM-style schedule autotuner for the native arena tier.
//!
//! The paper's best numbers (163.88% / 194.98% improvement) come from
//! *searched* schedule configurations, not from quantization alone: TVM
//! tunes tiling, layout blocking, and thread mapping per task and the
//! tuned-vs-default contrast **is** the experiment.  The arena tier used
//! to hard-code one schedule per kernel; this subsystem searches instead
//! of guessing:
//!
//! - [`knobs`] — the typed [`KnobSpace`]: per-anchor-class banding mode
//!   (contiguous / interleaved / dynamic dequeue with chunk granularity),
//!   band caps (thread mapping), fuse-vs-split epilogues, and the packed
//!   lane-accumulator stack bound; deterministic seeded samplers and
//!   single-knob neighbourhoods.
//! - [`measure`] — the [`Measurer`]: compiles each candidate through
//!   `graph::compile`, proves it **bit-for-bit** against
//!   `graph::interp::evaluate` before any clock starts, then times it
//!   in-process on the real step stream (warmup + trimmed-mean ns/iter).
//! - [`search`] — [`tune_graph`]: seeded random sampling, optionally
//!   ordered by the `perfmodel` roofline prior, then greedy hill-climb,
//!   all under a fixed trial budget.
//! - [`records`] — [`TuneRecords`]: the persisted JSON log / best-config
//!   cache keyed by (step op, shape, layout, precision, threads), loaded
//!   back by `NativeArenaFactory::with_schedule`, `tvmq run/serve
//!   --tuned`, and `bench-arena --tuned`.
//!
//! CLI: `tvmq tune [--budget N --seed S --json PATH --quick]` runs a
//! budgeted search on the seeded resnet model and writes the records
//! file; `tvmq bench-arena --tuned [records.json]` prints tuned-vs-default
//! rows across the whole layout × precision matrix.
//!
//! The one invariant everything here leans on: **schedule knobs are
//! semantics-free**.  Banding modes each assign every output row to
//! exactly one band, the spill knob only moves an integer accumulator
//! between stack and arena, and fuse-vs-split is already pinned
//! bit-exact by the fuzz harness — so tuning can chase speed without
//! renegotiating correctness, and the measurer's oracle gate exists to
//! catch compiler bugs, not numerical drift.

pub mod knobs;
pub mod measure;
pub mod records;
pub mod search;

pub use knobs::{micro_str, parse_micro_str, KnobSpace, SchedulePlan};
pub use measure::{Measure, Measurement, MeasureOpts, Measurer};
pub use records::{merge, RunMeta, TaskKey, TuneRecord, TuneRecords, RECORDS_VERSION};
pub use search::{tune_graph, tune_with_measurer, Trial, TuneOptions, TuneOutcome};
