//! Layout machinery: Figure 1's NCHW → NCHW{c} spatial packing, in rust.
//!
//! The packed layout groups channels into blocks of `c` and makes the block
//! the innermost (unit-stride) dimension, so a conv's inner loop walks
//! contiguous memory regardless of which channel slab it is reducing —
//! oneDNN's `nChw16c`, TVM's `NCHW16c`.  These routines power the layout
//! pass of the graph IR, the Figure-1 bench (packed vs unpacked locality),
//! and the block-size ablation.

use anyhow::{anyhow, Result};

/// Dimensions of an NCHW tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Nchw {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Nchw {
    pub fn len(&self) -> usize {
        self.n * self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// NCHW -> NCHW{cb}: `(N, C, H, W)` → `(N, C/cb, H, W, cb)`.
/// C must divide by `cb`.
pub fn pack_nchwc(src: &[f32], d: Nchw, cb: usize) -> Result<Vec<f32>> {
    if d.c % cb != 0 {
        return Err(anyhow!("C={} not divisible by c_block={}", d.c, cb));
    }
    if src.len() != d.len() {
        return Err(anyhow!("src len {} != dims {:?}", src.len(), d));
    }
    let co = d.c / cb;
    let mut out = vec![0f32; src.len()];
    let hw = d.h * d.w;
    for n in 0..d.n {
        for o in 0..co {
            for ci in 0..cb {
                let c = o * cb + ci;
                let src_base = (n * d.c + c) * hw;
                for p in 0..hw {
                    // dst index: (((n*co + o)*hw + p)*cb + ci)
                    out[((n * co + o) * hw + p) * cb + ci] = src[src_base + p];
                }
            }
        }
    }
    Ok(out)
}

/// NCHW{cb} -> NCHW (inverse of [`pack_nchwc`]).
pub fn unpack_nchwc(src: &[f32], d: Nchw, cb: usize) -> Result<Vec<f32>> {
    if d.c % cb != 0 || src.len() != d.len() {
        return Err(anyhow!("bad unpack dims {:?} cb={}", d, cb));
    }
    let co = d.c / cb;
    let hw = d.h * d.w;
    let mut out = vec![0f32; src.len()];
    for n in 0..d.n {
        for o in 0..co {
            for ci in 0..cb {
                let c = o * cb + ci;
                let dst_base = (n * d.c + c) * hw;
                for p in 0..hw {
                    out[dst_base + p] = src[((n * co + o) * hw + p) * cb + ci];
                }
            }
        }
    }
    Ok(out)
}

/// NCHW -> NHWC.
pub fn nchw_to_nhwc(src: &[f32], d: Nchw) -> Result<Vec<f32>> {
    if src.len() != d.len() {
        return Err(anyhow!("src len {} != dims {:?}", src.len(), d));
    }
    let mut out = vec![0f32; src.len()];
    for n in 0..d.n {
        for c in 0..d.c {
            for h in 0..d.h {
                for w in 0..d.w {
                    out[((n * d.h + h) * d.w + w) * d.c + c] =
                        src[((n * d.c + c) * d.h + h) * d.w + w];
                }
            }
        }
    }
    Ok(out)
}

/// NHWC -> NCHW.
pub fn nhwc_to_nchw(src: &[f32], d: Nchw) -> Result<Vec<f32>> {
    if src.len() != d.len() {
        return Err(anyhow!("src len {} != dims {:?}", src.len(), d));
    }
    let mut out = vec![0f32; src.len()];
    for n in 0..d.n {
        for h in 0..d.h {
            for w in 0..d.w {
                for c in 0..d.c {
                    out[((n * d.c + c) * d.h + h) * d.w + w] =
                        src[((n * d.h + h) * d.w + w) * d.c + c];
                }
            }
        }
    }
    Ok(out)
}

/// OIHW -> OIHW{i}{o}: `(K, C, R, S)` → `(K/kb, C/cb, R, S, cb, kb)`.
pub fn pack_oihw(src: &[f32], k: usize, c: usize, r: usize, s: usize,
                 cb: usize, kb: usize) -> Result<Vec<f32>> {
    if k % kb != 0 || c % cb != 0 {
        return Err(anyhow!("K={k}/kb={kb} or C={c}/cb={cb} not divisible"));
    }
    if src.len() != k * c * r * s {
        return Err(anyhow!("weight len mismatch"));
    }
    let (ko, co) = (k / kb, c / cb);
    let mut out = vec![0f32; src.len()];
    for okk in 0..ko {
        for ki in 0..kb {
            for occ in 0..co {
                for ci in 0..cb {
                    for rr in 0..r {
                        for ss in 0..s {
                            let kk = okk * kb + ki;
                            let cc = occ * cb + ci;
                            let src_i = ((kk * c + cc) * r + rr) * s + ss;
                            let dst_i = (((((okk * co + occ) * r + rr) * s + ss) * cb + ci)
                                * kb) + ki;
                            out[dst_i] = src[src_i];
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Render the Figure-1 packing diagram for a tiny tensor (docs/bench output).
pub fn render_packing_diagram(c: usize, cb: usize) -> String {
    let mut s = String::new();
    s.push_str(&format!("NCHW   (C={c}):      ch  0 1 2 ... laid out plane-by-plane\n"));
    s.push_str(&format!("NCHW{cb}c (C/{cb}={}) : ", c / cb));
    for o in 0..(c / cb) {
        s.push_str(&format!("[c{}..c{}]", o * cb, o * cb + cb - 1));
        if o + 1 < c / cb {
            s.push_str(" -> ");
        }
    }
    s.push_str("\n                    block is innermost: conv inner loop is unit-stride\n");
    s
}
