//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 via the PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`.  The interchange format is HLO *text* — jax ≥ 0.5 serialized
//! protos use 64-bit instruction ids this XLA rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT handles are raw pointers (`!Send`): the coordinator confines a
//! [`Runtime`] to one worker thread and talks to it over channels.

mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Context, Result};

pub use tensor::{synthetic_images, DType, TensorData};

use crate::manifest::{ModuleSpec, TensorSpec};

/// Execution statistics, accumulated across a runtime's lifetime.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub compiles: AtomicU64,
    pub executions: AtomicU64,
    pub bytes_h2d: AtomicU64,
    pub bytes_d2h: AtomicU64,
}

/// A PJRT CPU client plus a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: std::cell::RefCell<HashMap<PathBuf, std::rc::Rc<LoadedModule>>>,
    pub stats: RuntimeStats,
}

/// One compiled HLO module with its I/O contract.
pub struct LoadedModule {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            cache: Default::default(),
            stats: RuntimeStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one module (cached by absolute path).
    pub fn load_module(
        &self,
        root: &Path,
        spec: &ModuleSpec,
    ) -> Result<std::rc::Rc<LoadedModule>> {
        let path = root.join(&spec.file);
        if let Some(hit) = self.cache.borrow().get(&path) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        let module = std::rc::Rc::new(LoadedModule {
            name: spec.name.clone(),
            inputs: spec.inputs.clone(),
            output: spec.output.clone(),
            exe,
        });
        self.cache.borrow_mut().insert(path, module.clone());
        Ok(module)
    }

    pub fn cached_modules(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute a module host-to-host: literal in, literal out.
    ///
    /// This is one "packed function" invocation in TVM terms: the input is
    /// staged into a fresh device buffer, the output copied back — the
    /// per-call cost the VM executor pays at every instruction.
    pub fn execute_host(
        &self,
        module: &LoadedModule,
        inputs: &[&TensorData],
    ) -> Result<TensorData> {
        let lits = inputs.iter().map(|t| to_literal(t)).collect::<Result<Vec<_>>>()?;
        for t in inputs {
            self.stats
                .bytes_h2d
                .fetch_add(t.byte_len() as u64, Ordering::Relaxed);
        }
        let result = module
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {}: {e}", module.name))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {}: {e}", module.name))?;
        let out = from_literal(&out_lit, &module.output)
            .with_context(|| format!("decoding output of {}", module.name))?;
        self.stats
            .bytes_d2h
            .fetch_add(out.byte_len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Stage a host tensor into a device buffer (graph-executor input path).
    ///
    /// Goes through a literal rather than `buffer_from_host_raw_bytes`: the
    /// crate's raw-bytes path passes the `ElementType` discriminant where a
    /// `PrimitiveType` is expected (F32 → F16), corrupting the buffer type.
    pub fn to_device(&self, t: &TensorData) -> Result<xla::PjRtBuffer> {
        self.stats
            .bytes_h2d
            .fetch_add(t.byte_len() as u64, Ordering::Relaxed);
        let lit = to_literal(t)?;
        self.client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("host->device: {e}"))
    }

    /// Execute device-to-device: buffers in, buffer out (no host staging).
    pub fn execute_buffers(
        &self,
        module: &LoadedModule,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let mut result = module
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", module.name))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        let buf = result
            .drain(..)
            .next()
            .and_then(|mut replicas| replicas.drain(..).next())
            .ok_or_else(|| anyhow!("no output buffer from {}", module.name))?;
        Ok(buf)
    }

    /// Copy a device buffer back to the host.
    pub fn to_host(&self, buf: &xla::PjRtBuffer, spec: &TensorSpec) -> Result<TensorData> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e}"))?;
        let t = from_literal(&lit, spec)?;
        self.stats
            .bytes_d2h
            .fetch_add(t.byte_len() as u64, Ordering::Relaxed);
        Ok(t)
    }
}

/// TensorData → PJRT literal.
pub fn to_literal(t: &TensorData) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        t.dtype.element_type(),
        &t.shape,
        &t.data,
    )
    .map_err(|e| anyhow!("creating literal: {e}"))
}

/// PJRT literal → TensorData.  Modules are lowered untupled, so the common
/// case copies straight out of the literal; legacy tuple outputs are still
/// handled (decompose) for robustness.
///
/// §Perf: this is the request path's D2H copy.  The original implementation
/// cloned the literal (untuple handling) and staged through a typed Vec —
/// two extra full copies per inference; both are gone (EXPERIMENTS.md §Perf).
pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<TensorData> {
    let dtype = DType::parse(&spec.dtype);
    let want_bytes = spec.byte_len();
    if lit.ty().is_err() {
        // Tuple literal: decompose (rare, legacy artifacts only).
        let mut c = lit.clone();
        let parts = c
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing tuple: {e}"))?;
        let first = parts
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty tuple literal"))?;
        return from_literal(&first, spec);
    }
    if lit.size_bytes() != want_bytes {
        return Err(anyhow!(
            "literal size {} != spec {:?}/{} ({} bytes)",
            lit.size_bytes(), spec.shape, spec.dtype, want_bytes
        ));
    }
    let mut data = vec![0u8; want_bytes];
    copy_literal_bytes(lit, dtype, &mut data)?;
    TensorData::new(dtype, spec.shape.clone(), data)
}

fn copy_literal_bytes(lit: &xla::Literal, dtype: DType, dst: &mut [u8]) -> Result<()> {
    // Copy directly into the destination byte buffer: reinterpret it as the
    // element type (safe on this little-endian target; alignment of the Vec
    // allocation is checked by align_to_mut).
    match dtype {
        DType::F32 => {
            let (pre, mid, post) = unsafe { dst.align_to_mut::<f32>() };
            if !pre.is_empty() || !post.is_empty() {
                return Err(anyhow!("unaligned f32 buffer"));
            }
            lit.copy_raw_to(mid).map_err(|e| anyhow!("copy f32: {e}"))?;
        }
        DType::S32 => {
            let (pre, mid, post) = unsafe { dst.align_to_mut::<i32>() };
            if !pre.is_empty() || !post.is_empty() {
                return Err(anyhow!("unaligned s32 buffer"));
            }
            lit.copy_raw_to(mid).map_err(|e| anyhow!("copy s32: {e}"))?;
        }
        DType::S8 => {
            let (_, mid, _) = unsafe { dst.align_to_mut::<i8>() };
            lit.copy_raw_to(mid).map_err(|e| anyhow!("copy s8: {e}"))?;
        }
    }
    Ok(())
}
