//! Host tensors: the typed byte buffers that cross the PJRT boundary.

use anyhow::{anyhow, Result};
use xla::ElementType;

/// The three dtypes the quantized pipeline moves across module boundaries:
/// fp32 activations, int8 quantized tensors, int32 accumulators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    S8,
    S32,
}

impl DType {
    /// Parse the manifest dtype tag.
    pub fn parse(tag: &str) -> Self {
        match tag {
            "f32" => DType::F32,
            "s8" => DType::S8,
            "s32" => DType::S32,
            other => panic!("unknown dtype tag {other:?}"),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S8 => "s8",
            DType::S32 => "s32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::S32 => 4,
            DType::S8 => 1,
        }
    }

    pub fn element_type(&self) -> ElementType {
        match self {
            DType::F32 => ElementType::F32,
            DType::S8 => ElementType::S8,
            DType::S32 => ElementType::S32,
        }
    }
}

/// A host-side tensor: dtype + shape + raw bytes.
///
/// This is the coordinator's working currency; conversion to/from PJRT
/// literals and buffers lives in [`crate::runtime`].
#[derive(Debug, Clone, PartialEq)]
pub struct TensorData {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl TensorData {
    pub fn new(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        let want = shape.iter().product::<usize>() * dtype.size_bytes();
        if data.len() != want {
            return Err(anyhow!(
                "tensor data length {} != shape {:?} * {} = {}",
                data.len(), shape, dtype.size_bytes(), want
            ));
        }
        Ok(Self { dtype, shape, data })
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let len = shape.iter().product::<usize>() * dtype.size_bytes();
        Self { dtype, shape, data: vec![0u8; len] }
    }

    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Result<Self> {
        // Bulk byte copy instead of a per-element push loop (§Perf: this is
        // on the interpreter's per-node output path).  The stored format is
        // little-endian (what `as_f32` decodes), which equals the native
        // bytes: big-endian targets fail to compile (see lib.rs).
        let data = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
        }
        .to_vec();
        Self::new(DType::F32, shape, data)
    }

    pub fn from_i8(shape: Vec<usize>, values: &[i8]) -> Result<Self> {
        // Endian-neutral: single-byte elements.
        let bytes = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len())
        };
        Self::new(DType::S8, shape, bytes.to_vec())
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Result<Self> {
        let data = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
        }
        .to_vec();
        Self::new(DType::S32, shape, data)
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(anyhow!("not f32: {:?}", self.dtype));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        if self.dtype != DType::S8 {
            return Err(anyhow!("not s8: {:?}", self.dtype));
        }
        Ok(self.data.iter().map(|b| *b as i8).collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::S32 {
            return Err(anyhow!("not s32: {:?}", self.dtype));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Zero-copy f32 view (no per-call Vec, unlike [`Self::as_f32`]).
    /// Errors if the dtype mismatches or the buffer is misaligned (Vec<u8>
    /// allocations are ≥8-aligned in practice; checked, never assumed).
    pub fn as_f32_slice(&self) -> Result<&[f32]> {
        if self.dtype != DType::F32 {
            return Err(anyhow!("not f32: {:?}", self.dtype));
        }
        let (pre, mid, post) = unsafe { self.data.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(anyhow!("unaligned f32 tensor buffer"));
        }
        Ok(mid)
    }

    /// Zero-copy mutable f32 view — the arena executor's output window.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        if self.dtype != DType::F32 {
            return Err(anyhow!("not f32: {:?}", self.dtype));
        }
        let (pre, mid, post) = unsafe { self.data.align_to_mut::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(anyhow!("unaligned f32 tensor buffer"));
        }
        Ok(mid)
    }

    /// Zero-copy i32 view.
    pub fn as_i32_slice(&self) -> Result<&[i32]> {
        if self.dtype != DType::S32 {
            return Err(anyhow!("not s32: {:?}", self.dtype));
        }
        let (pre, mid, post) = unsafe { self.data.align_to::<i32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(anyhow!("unaligned s32 tensor buffer"));
        }
        Ok(mid)
    }

    /// Zero-copy mutable i32 view.
    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        if self.dtype != DType::S32 {
            return Err(anyhow!("not s32: {:?}", self.dtype));
        }
        let (pre, mid, post) = unsafe { self.data.align_to_mut::<i32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(anyhow!("unaligned s32 tensor buffer"));
        }
        Ok(mid)
    }

    /// Zero-copy i8 view (always aligned).
    pub fn as_i8_slice(&self) -> Result<&[i8]> {
        if self.dtype != DType::S8 {
            return Err(anyhow!("not s8: {:?}", self.dtype));
        }
        let (_, mid, _) = unsafe { self.data.align_to::<i8>() };
        Ok(mid)
    }

    /// Zero-copy mutable i8 view.
    pub fn as_i8_mut(&mut self) -> Result<&mut [i8]> {
        if self.dtype != DType::S8 {
            return Err(anyhow!("not s8: {:?}", self.dtype));
        }
        let (_, mid, _) = unsafe { self.data.align_to_mut::<i8>() };
        Ok(mid)
    }

    /// Argmax over the last axis — logits → class ids.
    pub fn argmax_last(&self) -> Result<Vec<usize>> {
        let vals = self.as_f32()?;
        let last = *self.shape.last().ok_or_else(|| anyhow!("scalar tensor"))?;
        Ok(vals
            .chunks_exact(last)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Stack batch-1 tensors along axis 0 (the batcher's gather step).
    pub fn stack(items: &[&TensorData]) -> Result<TensorData> {
        let first = items.first().ok_or_else(|| anyhow!("empty stack"))?;
        let mut data =
            Vec::with_capacity(items.iter().map(|t| t.data.len()).sum::<usize>());
        for t in items {
            if t.shape != first.shape || t.dtype != first.dtype {
                return Err(anyhow!("stack: mismatched item specs"));
            }
            data.extend_from_slice(&t.data);
        }
        let mut shape = first.shape.clone();
        if shape.is_empty() {
            return Err(anyhow!("stack: scalar items"));
        }
        shape[0] = items.iter().map(|t| t.shape[0]).sum();
        TensorData::new(first.dtype, shape, data)
    }

    /// Split along axis 0 into per-`rows` chunks (the batcher's scatter step).
    pub fn split_rows(&self, rows: usize) -> Result<Vec<TensorData>> {
        if self.shape.is_empty() || self.shape[0] % rows != 0 {
            return Err(anyhow!("split_rows({rows}) on shape {:?}", self.shape));
        }
        let row_bytes = self.byte_len() / self.shape[0] * rows;
        let mut shape = self.shape.clone();
        shape[0] = rows;
        self.data
            .chunks_exact(row_bytes)
            .map(|c| TensorData::new(self.dtype, shape.clone(), c.to_vec()))
            .collect()
    }

    /// Take the first `rows` rows (strip batch padding).
    pub fn truncate_rows(&self, rows: usize) -> Result<TensorData> {
        if self.shape.is_empty() || rows > self.shape[0] {
            return Err(anyhow!("truncate_rows({rows}) on shape {:?}", self.shape));
        }
        let row_bytes = self.byte_len() / self.shape[0];
        let mut shape = self.shape.clone();
        shape[0] = rows;
        TensorData::new(self.dtype, shape, self.data[..row_bytes * rows].to_vec())
    }

    /// Zero-pad along axis 0 up to `rows` (bucket batching).
    pub fn pad_rows(&self, rows: usize) -> Result<TensorData> {
        if self.shape.is_empty() || rows < self.shape[0] {
            return Err(anyhow!("pad_rows({rows}) on shape {:?}", self.shape));
        }
        let row_bytes = self.byte_len() / self.shape[0];
        let mut data = self.data.clone();
        data.resize(row_bytes * rows, 0);
        let mut shape = self.shape.clone();
        shape[0] = rows;
        TensorData::new(self.dtype, shape, data)
    }
}

/// Deterministic synthetic image batches (the paper's validation data stand-in).
pub fn synthetic_images(
    batch: usize,
    shape_rest: &[usize],
    seed: u64,
) -> TensorData {
    let mut rng = crate::util::rng::Rng64::seed_from_u64(seed);
    let mut shape = vec![batch];
    shape.extend_from_slice(shape_rest);
    let n: usize = shape.iter().product();
    let values: Vec<f32> = (0..n).map(|_| rng.normal() * 0.5).collect();
    TensorData::from_f32(shape, &values).expect("synthetic shape")
}
