//! # tvmq — a quantized-inference compiler/runtime
//!
//! Reproduction of *Analyzing Quantization in TVM* (Guo, 2023) as a
//! three-layer Rust + JAX + Pallas stack.  This crate is Layer 3: the
//! compiler's graph-optimization layer and the two executors whose contrast
//! is the paper's central finding — the static **graph executor** vs the
//! dynamic **VM executor** that TVM's quantization path selects by default
//! (the "bug" of Table 1).
//!
//! Python (Layers 1–2) runs only at build time (`make artifacts`), lowering
//! the schedule kernels + model segments to HLO text; this crate loads those
//! artifacts over PJRT and serves inference without Python anywhere on the
//! request path.
//!
//! Module map (DESIGN.md §2):
//! - [`manifest`] — artifact manifest schema + loader
//! - [`runtime`]  — PJRT client wrapper, tensors, executable cache
//! - [`graph`]    — Relay-like graph IR + optimization passes
//! - [`executor`] — GraphExecutor vs VmExecutor (the paper's contrast),
//!   plus ArenaExec: the native fused, statically-planned engine over the
//!   graph IR (zero allocation per inference; see `graph::compile`); the
//!   typed `EngineSpec` variant selector and the `EngineFactory`
//!   bucket-engine builders the serving tier plugs into
//! - [`memplan`]  — static memory planner vs dynamic allocation
//! - [`layout`]   — NCHW{c} packing machinery (Figure 1)
//! - [`quant`]    — host-side quantization + memory footprint accounting
//! - [`coordinator`] — batching inference server (artifact-backed or
//!   native arena engines, via any `EngineFactory`)
//! - [`check`]    — concurrency checking: the pool's epoch protocol run
//!   under a deterministic model scheduler that enumerates interleavings
//!   exhaustively (bounded DFS), plus deterministic fault injection for
//!   the serving path (`FaultyFactory`/`FaultyEngine`)
//! - [`perfmodel`] — analytic roofline / ideal-speedup model (Table 2)
//! - [`tune`]     — AutoTVM-style schedule autotuner for the arena tier:
//!   typed knob space (banding / band caps / fuse / lane strategy),
//!   oracle-gated in-process measurer, seeded random + hill-climb search,
//!   persisted `TuneRecords` (`tvmq tune`, `bench-arena --tuned`,
//!   `run/serve --tuned records.json`)
//! - [`cache`]    — content-addressed compile/tune cache: structural
//!   graph digests, the versioned on-disk store behind
//!   `serve --cache-dir` warm starts, and cross-run tune-record merging
//! - [`metrics`]  — the paper's epoch measurement protocol + table emitters
//! - [`telem`]    — the allocation-free observability spine: pre-registered
//!   atomic counters/gauges/log2 histograms, sampled per-step profiling,
//!   the drift detector behind continuous in-situ re-tuning, serve-path
//!   shape recording, and versioned JSON metric snapshots
//! - [`bench`]    — harnesses that regenerate every paper table & figure

// TensorData stores little-endian bytes, and the zero-copy views plus the
// arena executor reinterpret those bytes as native elements; both are only
// coherent on a little-endian target (runtime::copy_literal_bytes already
// assumed this silently — make it loud).
#[cfg(target_endian = "big")]
compile_error!("tvmq assumes a little-endian target");

pub mod bench;
pub mod cache;
pub mod check;
pub mod coordinator;
pub mod executor;
pub mod graph;
pub mod layout;
pub mod manifest;
pub mod memplan;
pub mod metrics;
pub mod perfmodel;
pub mod quant;
pub mod runtime;
pub mod telem;
pub mod tune;
pub mod util;

pub use manifest::Manifest;
pub use runtime::{DType, Runtime, TensorData};

/// Default artifacts directory, relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("TVMQ_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
