//! Analytic performance model: the paper's "Ideal Speedup" column and the
//! compute-bound ↔ memory-bound analysis (§2.1, Table 2, Table 3).
//!
//! The paper derives ideal speedups from first principles on the
//! Cortex-A72: NEON `vmlal` processes 4 int8 elements in each of 4 int32
//! lanes (16 MACs/instr vs 4 fp32 MACs/instr → 16× vs the scalar baseline,
//! 4× over fp32 SIMD); schedules that only parallelize H by 4 with no
//! vectorized reduction cap at 4×.  The same arithmetic is reproduced here,
//! plus a two-term roofline used for the batch-size crossover analysis.

/// Machine parameters (Cortex-A72-like defaults; override for other
/// testbeds).  Only *ratios* matter for the ideal-speedup column.
#[derive(Debug, Clone, Copy)]
pub struct MachineModel {
    /// fp32 lanes per SIMD issue (NEON 128-bit / 32-bit).
    pub fp32_lanes: usize,
    /// int8 elements per accumulator lane in the widening MAC (vmlal).
    pub int8_dot_width: usize,
    /// int32 accumulator lanes per issue.
    pub int8_lanes: usize,
    /// Peak fp32 GFLOP/s (all cores) — roofline ceiling.
    pub peak_fp32_gflops: f64,
    /// Peak memory bandwidth GB/s — roofline slope.
    pub mem_bw_gbs: f64,
}

impl Default for MachineModel {
    fn default() -> Self {
        // 8-core A72 @ ~1.5 GHz: 8 * 1.5G * 8 flop ≈ 96 GFLOP/s, LPDDR4 ~12 GB/s.
        MachineModel {
            fp32_lanes: 4,
            int8_dot_width: 4,
            int8_lanes: 4,
            peak_fp32_gflops: 96.0,
            mem_bw_gbs: 12.0,
        }
    }
}

/// Descriptor of a schedule's parallel structure — enough to derive its
/// ideal speedup exactly as the paper does.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleDesc {
    pub name: &'static str,
    pub layout: &'static str,
    pub precision: &'static str,
    /// Elements the inner loop retires per issue (vector lanes × dot width).
    pub macs_per_issue: usize,
    /// The paper's Table-2 column: speedup over the scalar baseline.
    pub ideal_speedup: usize,
}

/// The paper's five Table-2 schedules.
pub fn schedule_table(m: &MachineModel) -> Vec<ScheduleDesc> {
    let fp32_simd = m.fp32_lanes; // 4
    let int8_simd = m.int8_lanes * m.int8_dot_width; // 16
    vec![
        ScheduleDesc {
            name: "spatial_pack",
            layout: "NCHW",
            precision: "fp32",
            macs_per_issue: fp32_simd,
            // NCHW{16}c: 4-wide fp32 SIMD × 4-way H parallelism
            ideal_speedup: fp32_simd * 4,
        },
        ScheduleDesc {
            name: "spatial_pack",
            layout: "NCHW",
            precision: "int8",
            macs_per_issue: int8_simd,
            ideal_speedup: int8_simd,
        },
        ScheduleDesc {
            name: "simd",
            layout: "NCHW",
            precision: "int8",
            // vmlal: 4 int8 in 4 int32 lanes
            macs_per_issue: int8_simd,
            ideal_speedup: int8_simd,
        },
        ScheduleDesc {
            name: "spatial_pack",
            layout: "NHWC",
            precision: "fp32",
            macs_per_issue: 1,
            // H×4 only, no vectorized reduction blocking (§3.2.1)
            ideal_speedup: 4,
        },
        ScheduleDesc {
            name: "quantized_interleaved",
            layout: "NHWC",
            precision: "int8",
            // 4×4 int8 MMLA tile
            macs_per_issue: int8_simd,
            ideal_speedup: int8_simd,
        },
    ]
}

/// The ALU-width factor the deployment substrate cannot execute: on the
/// modelled machine int8 retires `int8_lanes × dot_width` MACs per issue vs
/// `fp32_lanes` for fp32 (vmlal: 16 vs 4 → 4.0).  The measured tables run
/// int8 math through exact f32 emulation (XLA 0.5.1 CPU has no s8 GEMM),
/// so paper-shape projections divide int8 compute time by this factor —
/// the same first-principles arithmetic the paper's Ideal-Speedup column
/// uses.  See DESIGN.md §Hardware-Adaptation.
pub fn int8_alu_factor(m: &MachineModel) -> f64 {
    (m.int8_lanes * m.int8_dot_width) as f64 / m.fp32_lanes as f64
}

/// Cost prior for the arena schedule autotuner (`crate::tune`): the
/// two-term roofline with the three schedule axes the analytic model can
/// see.  Unfused plans materialize every epilogue intermediate, roughly
/// doubling activation traffic; band caps divide the compute term (a
/// capped fan-out idles cores) but not the single-stream bandwidth term;
/// and the register-tile term models the microkernel axis: int8 plans
/// only reach the wide-MAC compute rate when the register-blocked dot
/// tiles are actually selected (`micro`) — scalar int8 loops retire MACs
/// at roughly the fp32 rate, which is exactly the paper's point about
/// tensorization.  This is an *ordering heuristic* for which candidates
/// to measure first under a small budget — measurements, not the prior,
/// pick the winner.
pub fn tune_prior_ms(
    m: &MachineModel,
    flops: f64,
    act_bytes: f64,
    int8: bool,
    fused: bool,
    bands: usize,
    micro: bool,
) -> f64 {
    let traffic = if fused { act_bytes } else { act_bytes * 2.0 };
    let compute_rate = if int8 && micro {
        m.peak_fp32_gflops * int8_alu_factor(m)
    } else {
        m.peak_fp32_gflops
    } * 1e9;
    let compute_s = flops / compute_rate / bands.max(1) as f64;
    let mem_s = traffic / (m.mem_bw_gbs * 1e9);
    compute_s.max(mem_s) * 1e3
}

/// Two-term roofline: time = max(compute, traffic).
pub fn roofline_ms(m: &MachineModel, flops: f64, bytes: f64, int8: bool) -> f64 {
    // int8 compute advantage: dot_width × (lanes ratio) over fp32.
    let compute_rate = if int8 {
        m.peak_fp32_gflops * (m.int8_dot_width as f64)
    } else {
        m.peak_fp32_gflops
    } * 1e9;
    let compute_s = flops / compute_rate;
    let mem_s = bytes / (m.mem_bw_gbs * 1e9);
    compute_s.max(mem_s) * 1e3
}

/// Fraction of the two-term roofline bound a measured time achieves
/// (1.0 = running exactly at the model's bound; > 1 means the model is
/// pessimistic for this cell).  The machine-readable compute-bound vs
/// memory-bound contrast `bench-arena --json` rows carry.
pub fn roofline_fraction(
    m: &MachineModel,
    flops: f64,
    bytes: f64,
    int8: bool,
    measured_ms: f64,
) -> f64 {
    if measured_ms <= 0.0 {
        return 0.0;
    }
    roofline_ms(m, flops, bytes, int8) / measured_ms
}

/// FLOPs of a conv layer.
pub fn conv_flops(n: usize, c: usize, k: usize, oh: usize, ow: usize, r: usize, s: usize) -> f64 {
    2.0 * (n * k * oh * ow) as f64 * (c * r * s) as f64
}

/// Approximate ResNet-10 (CIFAR-scale) FLOPs per image at `image`² input.
pub fn resnet10_flops(image: usize) -> f64 {
    // stem 3→16 @ s
    let mut fl = conv_flops(1, 3, 16, image, image, 3, 3);
    let mut hw = image;
    let mut cin = 16;
    for (cout, stride) in [(16usize, 1usize), (32, 2), (64, 2), (128, 2)] {
        let oh = hw / stride;
        fl += conv_flops(1, cin, cout, oh, oh, 3, 3); // conv1
        fl += conv_flops(1, cout, cout, oh, oh, 3, 3); // conv2
        if stride != 1 || cin != cout {
            fl += conv_flops(1, cin, cout, oh, oh, 1, 1); // downsample
        }
        hw = oh;
        cin = cout;
    }
    fl
}

/// Per-image activation traffic bytes (read+write across layers).
pub fn resnet10_activation_bytes(image: usize, bytes_per_elem: f64) -> f64 {
    let mut total = (3 * image * image) as f64;
    let mut hw = image;
    for (cout, stride) in [(16usize, 1usize), (16, 1), (32, 2), (64, 2), (128, 2)] {
        let oh = hw / stride;
        total += 2.0 * (cout * oh * oh) as f64; // block intermediate + out
        hw = oh;
    }
    total * 2.0 * bytes_per_elem // read + write
}

/// The §2.1 crossover analysis: at which batch does the workload flip from
/// compute-bound to memory-bound?  Returns (batch, compute_ms, memory_ms)
/// samples.
pub fn bound_analysis(
    m: &MachineModel,
    image: usize,
    weight_bytes: f64,
    batches: &[usize],
    int8: bool,
) -> Vec<(usize, f64, f64)> {
    let flops1 = resnet10_flops(image);
    let act1 = resnet10_activation_bytes(image, 4.0); // intermediates fp32 (§3.2.2)
    batches
        .iter()
        .map(|&b| {
            let flops = flops1 * b as f64;
            let traffic = act1 * b as f64
                + if int8 { weight_bytes } else { weight_bytes * 4.0 };
            let compute_rate = if int8 {
                m.peak_fp32_gflops * m.int8_dot_width as f64
            } else {
                m.peak_fp32_gflops
            } * 1e9;
            (
                b,
                flops / compute_rate * 1e3,
                traffic / (m.mem_bw_gbs * 1e9) * 1e3,
            )
        })
        .collect()
}
