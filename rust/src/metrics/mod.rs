//! Measurement protocol + table emitters.
//!
//! The paper: "For each experiment, we average the performance over 110
//! epochs with the first 10 epochs used for warm-up." (§2.2)  [`measure`]
//! implements exactly that protocol; emitters render rows in the paper's
//! table format (Time (ms) / Improvement %).

use std::time::Instant;

/// Summary statistics over the measured (post-warmup) epochs.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub epochs: usize,
    pub warmup: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Tail of the tail — the serving tier's SLO metric (a p999 spike
    /// with a healthy p50 is exactly the head-of-line-blocking signature
    /// the sharded coordinator exists to remove).
    pub p999_ms: f64,
    pub max_ms: f64,
}

impl EpochStats {
    /// Summarize the post-warmup samples.  Returns `None` when nothing
    /// was measured (empty input, or warmup consumed every sample) — the
    /// typed empty result.  It used to return all-zero stats for that
    /// case, which read as "perfect latency" downstream; every caller
    /// now decides explicitly what an empty measurement means.
    pub fn from_samples(samples_ms: &[f64], warmup: usize) -> Option<EpochStats> {
        let measured = &samples_ms[warmup.min(samples_ms.len())..];
        if measured.is_empty() {
            return None;
        }
        let n = measured.len();
        let mean = measured.iter().sum::<f64>() / n as f64;
        let var = measured.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = measured.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        Some(EpochStats {
            epochs: samples_ms.len(),
            warmup,
            mean_ms: mean,
            std_ms: var.sqrt(),
            min_ms: sorted[0],
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            p999_ms: pct(0.999),
            max_ms: sorted[n - 1],
        })
    }
}

/// The paper's protocol: `epochs` runs, first `warmup` discarded.
pub fn measure<F: FnMut() -> anyhow::Result<()>>(
    epochs: usize,
    warmup: usize,
    mut f: F,
) -> anyhow::Result<EpochStats> {
    let mut samples = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let t0 = Instant::now();
        f()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    EpochStats::from_samples(&samples, warmup)
        .ok_or_else(|| anyhow::anyhow!("measure: no post-warmup epochs ({epochs} epochs, {warmup} warmup)"))
}

/// "Improvement" in the paper's sense: baseline_time / this_time, as a
/// percentage (100% = parity, 160.70% = 1.607× faster than baseline).
pub fn improvement_pct(baseline_ms: f64, this_ms: f64) -> f64 {
    100.0 * baseline_ms / this_ms
}

// ---------------------------------------------------------------------------
// Table rendering
// ---------------------------------------------------------------------------

/// A paper-style results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:w$} |", c, w = w));
            }
            s
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

pub fn fmt_ms(v: f64) -> String {
    format!("{v:.2}")
}

pub fn fmt_pct(v: f64) -> String {
    format!("{v:.2}%")
}

/// Speedup ratio cell ("1.00x" = parity with the baseline).
pub fn fmt_speedup(v: f64) -> String {
    format!("{v:.2}x")
}

pub fn fmt_mib(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}
