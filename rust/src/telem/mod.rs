//! `telem` — the allocation-free observability spine.
//!
//! The paper's whole method is attribution: it finds the quantization
//! slowdown by measuring *where* time goes, stage by stage.  This module
//! gives the serving stack the same lens on live traffic without
//! disturbing what it observes:
//!
//! - [`Registry`] — a fixed, pre-registered set of atomic counters,
//!   gauges, and log2-bucket histograms.  Every id is an enum variant, so
//!   the hot path is one bounds-check-free array index plus a relaxed
//!   atomic RMW: **no locks, no heap allocation, ever**.  A registry
//!   built with [`Registry::disabled`] early-returns on every write —
//!   near-zero cost when observability is off.
//! - [`StepProfiler`] / [`ProfileSink`] — sampled per-step timing for
//!   `ArenaExec`: every Nth inference the step loop is timed and the
//!   ns land in per-step cells interned at engine-build time (keyed by
//!   step op, shape, layout, precision, ISA and micro tile).  The hot
//!   path touches only pre-allocated atomics; `Instant::now()` does not
//!   allocate.
//! - [`DriftDetector`] — a deterministic windowed comparator over the
//!   latency histogram: a baseline window freezes first, then each
//!   recent window's p50 is compared against the baseline's; `sustain`
//!   consecutive breaches of `ratio` trigger a re-tune request and the
//!   detector **re-baselines**, so a planted step change fires exactly
//!   once.  Verdicts are a pure function of the observed sequence.
//! - [`ShapeRecorder`] — accumulates the bucket shapes the serve path
//!   actually sees and orders them by traffic, so the drift re-tuner can
//!   emit per-shape tuning tasks (landing in
//!   `ScheduleOverrides.per_shape`) for the shapes that matter.
//! - [`Telemetry::write_snapshot`] — versioned JSON snapshots
//!   ([`SNAPSHOT_SCHEMA_VERSION`]) written via atomic tmp+rename, with
//!   the compile-cache hit/miss counters folded in.
//!
//! ## What the registry can and cannot observe
//!
//! Histograms are **log2-bucketed** ([`HIST_BUCKETS`] buckets; bucket
//! `b` holds values whose bit length is `b`, i.e. `[2^(b-1), 2^b)`), so
//! quantiles are exact only up to a factor of two: the reported quantile
//! is the *upper bound* of the bucket the rank falls in.  That is enough
//! to see a 2× regression or a queue going deep, and it is why the
//! drift detector is robust against noise below a bucket boundary — but
//! a sub-2× drift inside one bucket is invisible by construction.  Exact
//! percentiles still come from the coordinator's `LatencyReservoir`
//! (exact below its cap, and its snapshot now says when it sampled).
//! Counters/gauges are relaxed atomics: totals are exact, but a snapshot
//! taken mid-traffic is not a consistent cut across fields.
//!
//! ## Snapshot schema (version 1)
//!
//! ```json
//! {
//!   "kind": "tvmq-metrics", "schema_version": 1,
//!   "counters": { "requests": 0, "shed": 0, "errors": 0, "batches": 0,
//!                  "drift_triggers": 0, "retune_passes": 0 },
//!   "gauges":   { "queue_depth": 0, "queue_depth_max": 0,
//!                  "engine_generation": 0, "workers": 0 },
//!   "hists":    { "<name>": { "count": 0, "sum": 0, "buckets": [/*40*/] } },
//!   "cache":    null | { "hits": 0, "misses": 0, "stores": 0,
//!                         "rejected": 0, "hit_rate": 0.0 },
//!   "shapes":   [ { "batch": 1, "shape": [1,3,16,16], "count": 0 } ],
//!   "profile":  [ { "op": "...", "layout": "...", "precision": "...",
//!                    "isa": "...", "micro": "...", "shape": [],
//!                    "hits": 0, "total_ns": 0, "mean_ns": 0.0 } ]
//! }
//! ```
//!
//! Histogram names: `queue_wait_us`, `gather_us`, `latency_us` (values
//! in microseconds), `batch_size`, `queue_depth` (raw counts).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Version stamped into every metrics snapshot; bump when the snapshot
/// layout changes shape (adding fields is allowed without a bump —
/// consumers look keys up, never enumerate).
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 1;

/// Fixed histogram width: bucket `b` holds values of bit length `b`
/// (`[2^(b-1), 2^b)`), bucket 0 holds zero, the last bucket clamps the
/// tail.  40 buckets cover u64 values up to ~5.5e11 — in microseconds,
/// nearly a week of latency.
pub const HIST_BUCKETS: usize = 40;

// ---------------------------------------------------------------------------
// Registry ids
// ---------------------------------------------------------------------------

pub const N_COUNTERS: usize = 6;

/// Pre-registered monotonic counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterId {
    /// Requests settled by a serving worker (one per reply).
    Requests,
    /// Submissions shed by admission control (`Rejected::Overloaded`).
    Shed,
    /// Requests that settled with an error.
    Errors,
    /// Batches executed by serving workers.
    Batches,
    /// Drift-detector trigger events (each requests one re-tune pass).
    DriftTriggers,
    /// Drift-driven in-situ re-tune passes actually run.
    RetunePasses,
}

impl CounterId {
    pub const ALL: [CounterId; N_COUNTERS] = [
        CounterId::Requests,
        CounterId::Shed,
        CounterId::Errors,
        CounterId::Batches,
        CounterId::DriftTriggers,
        CounterId::RetunePasses,
    ];

    pub fn name(self) -> &'static str {
        match self {
            CounterId::Requests => "requests",
            CounterId::Shed => "shed",
            CounterId::Errors => "errors",
            CounterId::Batches => "batches",
            CounterId::DriftTriggers => "drift_triggers",
            CounterId::RetunePasses => "retune_passes",
        }
    }
}

pub const N_GAUGES: usize = 4;

/// Pre-registered gauges (last-write or running-max semantics — the
/// writer picks via [`Registry::gauge_set`] / [`Registry::gauge_max`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaugeId {
    /// Admission-queue depth observed at the last batch gather.
    QueueDepth,
    /// Maximum queue depth observed since the last reset.
    QueueDepthMax,
    /// Highest engine generation any worker is serving with.
    EngineGeneration,
    /// Serving worker count.
    Workers,
}

impl GaugeId {
    pub const ALL: [GaugeId; N_GAUGES] = [
        GaugeId::QueueDepth,
        GaugeId::QueueDepthMax,
        GaugeId::EngineGeneration,
        GaugeId::Workers,
    ];

    pub fn name(self) -> &'static str {
        match self {
            GaugeId::QueueDepth => "queue_depth",
            GaugeId::QueueDepthMax => "queue_depth_max",
            GaugeId::EngineGeneration => "engine_generation",
            GaugeId::Workers => "workers",
        }
    }
}

pub const N_HISTS: usize = 5;

/// Pre-registered histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistId {
    /// Per-request time from enqueue to batch gather, microseconds.
    QueueWaitUs,
    /// Per-batch gather (stacking) time, microseconds.
    GatherUs,
    /// Per-request settle latency, microseconds.
    LatencyUs,
    /// Gathered batch sizes (raw counts).
    BatchSize,
    /// Queue depth at gather time (raw counts).
    QueueDepth,
}

impl HistId {
    pub const ALL: [HistId; N_HISTS] = [
        HistId::QueueWaitUs,
        HistId::GatherUs,
        HistId::LatencyUs,
        HistId::BatchSize,
        HistId::QueueDepth,
    ];

    pub fn name(self) -> &'static str {
        match self {
            HistId::QueueWaitUs => "queue_wait_us",
            HistId::GatherUs => "gather_us",
            HistId::LatencyUs => "latency_us",
            HistId::BatchSize => "batch_size",
            HistId::QueueDepth => "queue_depth",
        }
    }
}

// ---------------------------------------------------------------------------
// Histograms
// ---------------------------------------------------------------------------

/// Log2 bucket index of `v` (clamped to the last bucket).
pub fn bucket_of(v: u64) -> usize {
    let bits = (64 - v.leading_zeros()) as usize; // 0 for v == 0
    bits.min(HIST_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `b` — the value a quantile read from
/// the histogram reports.
pub fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 63 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

/// One fixed-width log2 histogram: `HIST_BUCKETS` relaxed atomic
/// buckets plus count and sum.  Recording is two/three relaxed
/// `fetch_add`s — no locks, no allocation.
pub struct Hist {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Hist {
    const fn new() -> Hist {
        Hist {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [const { AtomicU64::new(0) }; HIST_BUCKETS],
        }
    }

    fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (b, a) in buckets.iter_mut().zip(&self.buckets) {
            *b = a.load(Ordering::Relaxed);
        }
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// A point-in-time copy of one [`Hist`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { count: 0, sum: 0, buckets: [0; HIST_BUCKETS] }
    }

    /// What this histogram accumulated since `earlier` (same histogram,
    /// earlier snapshot) — the per-trace windows the load bench reports.
    pub fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for i in 0..HIST_BUCKETS {
            buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        HistSnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            buckets,
        }
    }

    /// Upper bound of the bucket the `q`-quantile rank falls in (`None`
    /// when empty).  Exact only to the bucket's factor-of-two width.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Some(bucket_upper(b));
            }
        }
        Some(bucket_upper(HIST_BUCKETS - 1))
    }

    /// Upper bound of the highest non-empty bucket (`None` when empty).
    pub fn max_value(&self) -> Option<u64> {
        self.buckets
            .iter()
            .enumerate()
            .rev()
            .find(|(_, &n)| n > 0)
            .map(|(b, _)| bucket_upper(b))
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            (
                "buckets",
                Json::Arr(self.buckets.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// The fixed metric set.  Construction allocates nothing after the
/// struct itself; every write is a relaxed atomic op on a pre-existing
/// cell, and a disabled registry returns before touching memory.
pub struct Registry {
    enabled: bool,
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    hists: [Hist; N_HISTS],
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            enabled: true,
            counters: [const { AtomicU64::new(0) }; N_COUNTERS],
            gauges: [const { AtomicU64::new(0) }; N_GAUGES],
            hists: [const { Hist::new() }; N_HISTS],
        }
    }

    /// A registry whose every write is a branch and a return.
    pub fn disabled() -> Registry {
        Registry { enabled: false, ..Registry::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn count(&self, id: CounterId, n: u64) {
        if self.enabled {
            self.counters[id as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn counter(&self, id: CounterId) -> u64 {
        self.counters[id as usize].load(Ordering::Relaxed)
    }

    pub fn gauge_set(&self, id: GaugeId, v: u64) {
        if self.enabled {
            self.gauges[id as usize].store(v, Ordering::Relaxed);
        }
    }

    /// Running-max write (for high-water marks like queue depth).
    pub fn gauge_max(&self, id: GaugeId, v: u64) {
        if self.enabled {
            self.gauges[id as usize].fetch_max(v, Ordering::Relaxed);
        }
    }

    pub fn gauge(&self, id: GaugeId) -> u64 {
        self.gauges[id as usize].load(Ordering::Relaxed)
    }

    /// Reset a gauge to zero (between load-bench traces).
    pub fn gauge_reset(&self, id: GaugeId) {
        self.gauges[id as usize].store(0, Ordering::Relaxed);
    }

    pub fn record(&self, id: HistId, v: u64) {
        if self.enabled {
            self.hists[id as usize].record(v);
        }
    }

    pub fn hist(&self, id: HistId) -> HistSnapshot {
        self.hists[id as usize].snapshot()
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

// ---------------------------------------------------------------------------
// Per-step profiling
// ---------------------------------------------------------------------------

/// Attribution key of one fused step — what the paper's Table 1 keys its
/// rows by, for live traffic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepKey {
    /// Step-op token (e.g. `qconv2d`, `dense`, `quantize`).
    pub op: String,
    /// Output shape of the step.
    pub shape: Vec<usize>,
    /// Conv layout token (`nchw`/`nhwc`/`nchw8c`/`-`).
    pub layout: String,
    /// `int8` or `fp32` (of the step's destination).
    pub precision: String,
    /// Dispatched ISA of the executor (`scalar`/`sse2`/`avx2`).
    pub isa: String,
    /// Register tile token (`m4n8k8`) or `-` for scalar loops.
    pub micro: String,
}

impl StepKey {
    /// Stable one-line rendering (table rows, logs).
    pub fn label(&self) -> String {
        format!(
            "{} {} {} {:?} {} {}",
            self.op, self.layout, self.precision, self.shape, self.isa, self.micro
        )
    }
}

/// One attribution cell: hit count + total ns, shared by every engine
/// step that interned the same key (across workers and generations).
pub struct ProfileCell {
    pub key: StepKey,
    pub hits: AtomicU64,
    pub total_ns: AtomicU64,
}

/// The process-wide attribution table.  Interning (engine build time)
/// takes a mutex and may allocate; the serving hot path only touches the
/// returned `Arc`'d cells.
pub struct ProfileSink {
    cells: Mutex<Vec<Arc<ProfileCell>>>,
}

impl ProfileSink {
    pub fn new() -> Arc<ProfileSink> {
        Arc::new(ProfileSink { cells: Mutex::new(Vec::new()) })
    }

    /// Find or create the cell for `key`.  Build-time only.
    pub fn intern(&self, key: StepKey) -> Arc<ProfileCell> {
        let mut cells = self.cells.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(c) = cells.iter().find(|c| c.key == key) {
            return c.clone();
        }
        let cell = Arc::new(ProfileCell {
            key,
            hits: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
        });
        cells.push(cell.clone());
        cell
    }

    /// Snapshot of every cell, heaviest total time first.
    pub fn rows(&self) -> Vec<ProfileRow> {
        let cells = self.cells.lock().unwrap_or_else(PoisonError::into_inner);
        let mut rows: Vec<ProfileRow> = cells
            .iter()
            .map(|c| ProfileRow {
                key: c.key.clone(),
                hits: c.hits.load(Ordering::Relaxed),
                total_ns: c.total_ns.load(Ordering::Relaxed),
            })
            .collect();
        rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
        rows
    }
}

/// One row of the attribution table.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub key: StepKey,
    pub hits: u64,
    pub total_ns: u64,
}

impl ProfileRow {
    pub fn mean_ns(&self) -> f64 {
        if self.hits == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.hits as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str(self.key.op.clone())),
            ("layout", Json::str(self.key.layout.clone())),
            ("precision", Json::str(self.key.precision.clone())),
            ("isa", Json::str(self.key.isa.clone())),
            ("micro", Json::str(self.key.micro.clone())),
            (
                "shape",
                Json::Arr(self.key.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("hits", Json::num(self.hits as f64)),
            ("total_ns", Json::num(self.total_ns as f64)),
            ("mean_ns", Json::num(self.mean_ns())),
        ])
    }
}

/// Sampled per-step timer held by one `ArenaExec`.  The cells were
/// interned at build time; `should_sample` is one relaxed `fetch_add`
/// per inference, and a sampled inference's records are relaxed
/// `fetch_add`s into those cells — nothing on the path allocates.
pub struct StepProfiler {
    every: u64,
    tick: AtomicU64,
    samples: AtomicU64,
    cells: Vec<Arc<ProfileCell>>,
}

impl StepProfiler {
    /// `every == 0` disables sampling entirely; `every == 1` samples
    /// every inference.  `keys` must be index-aligned with the compiled
    /// step stream.
    pub fn new(every: u64, sink: &ProfileSink, keys: Vec<StepKey>) -> StepProfiler {
        StepProfiler {
            every,
            tick: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            cells: keys.into_iter().map(|k| sink.intern(k)).collect(),
        }
    }

    /// Decide whether this inference is timed (call once per inference).
    pub fn should_sample(&self) -> bool {
        if self.every == 0 {
            return false;
        }
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        if t % self.every == 0 {
            self.samples.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    pub fn record(&self, step: usize, ns: u64) {
        let c = &self.cells[step];
        c.hits.fetch_add(1, Ordering::Relaxed);
        c.total_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Inferences sampled so far.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    pub fn steps(&self) -> usize {
        self.cells.len()
    }
}

// ---------------------------------------------------------------------------
// Drift detection
// ---------------------------------------------------------------------------

/// Windowed drift comparator configuration.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Samples frozen into the baseline histogram before comparison
    /// starts.
    pub baseline: usize,
    /// Samples per recent comparison window.
    pub window: usize,
    /// Breach when `recent_p50 > ratio * baseline_p50`.  Bucket
    /// granularity is a factor of two, so ratios below ~2 fire on a
    /// one-bucket shift and ratios ≥ 2 need a two-bucket shift.
    pub ratio: f64,
    /// Consecutive breached windows required to trigger.
    pub sustain: usize,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig { baseline: 256, window: 64, ratio: 1.5, sustain: 2 }
    }
}

/// Deterministic latency-drift detector: verdicts are a pure function
/// of the observed value sequence (the unit tests replay seeded traces
/// and pin the trigger count).  After a trigger the detector
/// re-baselines from post-trigger samples, so one sustained regression
/// triggers exactly once.
pub struct DriftDetector {
    cfg: DriftConfig,
    baseline: [u64; HIST_BUCKETS],
    baseline_n: usize,
    recent: [u64; HIST_BUCKETS],
    recent_n: usize,
    breaches: usize,
    triggers: u64,
}

fn hist_quantile(buckets: &[u64; HIST_BUCKETS], n: usize, q: f64) -> u64 {
    if n == 0 {
        return 0;
    }
    let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for (b, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return bucket_upper(b);
        }
    }
    bucket_upper(HIST_BUCKETS - 1)
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig) -> DriftDetector {
        DriftDetector {
            cfg: DriftConfig {
                baseline: cfg.baseline.max(1),
                window: cfg.window.max(1),
                ratio: if cfg.ratio > 1.0 { cfg.ratio } else { 1.5 },
                sustain: cfg.sustain.max(1),
            },
            baseline: [0; HIST_BUCKETS],
            baseline_n: 0,
            recent: [0; HIST_BUCKETS],
            recent_n: 0,
            breaches: 0,
            triggers: 0,
        }
    }

    /// Feed one latency observation (any unit; microseconds in the
    /// serve path).  Returns `true` exactly when this observation
    /// completes a sustained regression — the re-tune trigger.
    pub fn observe(&mut self, v: u64) -> bool {
        if self.baseline_n < self.cfg.baseline {
            self.baseline[bucket_of(v)] += 1;
            self.baseline_n += 1;
            return false;
        }
        self.recent[bucket_of(v)] += 1;
        self.recent_n += 1;
        if self.recent_n < self.cfg.window {
            return false;
        }
        let base_p50 = hist_quantile(&self.baseline, self.baseline_n, 0.5).max(1);
        let recent_p50 = hist_quantile(&self.recent, self.recent_n, 0.5);
        let breached = recent_p50 as f64 > self.cfg.ratio * base_p50 as f64;
        self.recent = [0; HIST_BUCKETS];
        self.recent_n = 0;
        if breached {
            self.breaches += 1;
        } else {
            self.breaches = 0;
        }
        if self.breaches >= self.cfg.sustain {
            self.breaches = 0;
            self.triggers += 1;
            // Re-baseline: the next `baseline` samples (post-regression)
            // become the new normal, so the same step change cannot
            // re-trigger.
            self.baseline = [0; HIST_BUCKETS];
            self.baseline_n = 0;
            return true;
        }
        false
    }

    pub fn triggers(&self) -> u64 {
        self.triggers
    }
}

// ---------------------------------------------------------------------------
// Shape recording
// ---------------------------------------------------------------------------

/// One observed serve-path shape with its traffic count — the raw
/// material of a per-shape tuning task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeTask {
    pub batch: usize,
    pub shape: Vec<usize>,
    pub count: u64,
}

/// Accumulates the (bucket batch, input shape) pairs the serve path
/// actually executes.  Recording locks a short uncontended mutex (the
/// per-batch coordinator path, not the executor hot path) and only
/// allocates the first time a shape is seen.
pub struct ShapeRecorder {
    cells: Mutex<Vec<ShapeTask>>,
}

impl ShapeRecorder {
    pub fn new() -> ShapeRecorder {
        ShapeRecorder { cells: Mutex::new(Vec::new()) }
    }

    pub fn record(&self, batch: usize, shape: &[usize]) {
        let mut cells = self.cells.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(c) = cells.iter_mut().find(|c| c.batch == batch && c.shape == shape) {
            c.count += 1;
            return;
        }
        cells.push(ShapeTask { batch, shape: shape.to_vec(), count: 1 });
    }

    /// Observed shapes, hottest first (ties broken by smaller batch) —
    /// the order the drift re-tuner walks buckets in, so per-shape
    /// tuning effort follows traffic.
    pub fn tasks(&self) -> Vec<ShapeTask> {
        let cells = self.cells.lock().unwrap_or_else(PoisonError::into_inner);
        let mut tasks = cells.clone();
        tasks.sort_by(|a, b| b.count.cmp(&a.count).then(a.batch.cmp(&b.batch)));
        tasks
    }
}

impl Default for ShapeRecorder {
    fn default() -> ShapeRecorder {
        ShapeRecorder::new()
    }
}

// ---------------------------------------------------------------------------
// The assembled spine
// ---------------------------------------------------------------------------

/// Everything the serving stack shares: the registry, the process-wide
/// profile sink, the drift detector, and the shape recorder.  Threaded
/// as `Option<Arc<Telemetry>>` — `None` keeps every integration point
/// on its old path.
pub struct Telemetry {
    pub registry: Registry,
    pub profile: Arc<ProfileSink>,
    drift: Mutex<DriftDetector>,
    retune_pending: AtomicU64,
    pub shapes: ShapeRecorder,
}

impl Telemetry {
    pub fn new(drift: DriftConfig) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            registry: Registry::new(),
            profile: ProfileSink::new(),
            drift: Mutex::new(DriftDetector::new(drift)),
            retune_pending: AtomicU64::new(0),
            shapes: ShapeRecorder::new(),
        })
    }

    /// Feed one settled-request latency (microseconds) into the
    /// histogram and the drift detector; a completed sustained
    /// regression arms a re-tune request.
    pub fn observe_latency_us(&self, us: u64) {
        self.registry.record(HistId::LatencyUs, us);
        let triggered = self
            .drift
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .observe(us);
        if triggered {
            self.registry.count(CounterId::DriftTriggers, 1);
            self.retune_pending.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drift triggers observed so far.
    pub fn drift_triggers(&self) -> u64 {
        self.registry.counter(CounterId::DriftTriggers)
    }

    /// Claim any pending re-tune request (idempotent: coalesces
    /// multiple triggers into one pass).
    pub fn take_retune_request(&self) -> bool {
        self.retune_pending.swap(0, Ordering::Relaxed) > 0
    }

    /// Whether a re-tune request is armed (tests / introspection).
    pub fn retune_pending(&self) -> bool {
        self.retune_pending.load(Ordering::Relaxed) > 0
    }

    /// Build the versioned snapshot.  `cache` is the live compile-cache
    /// counter block when the serve path has one.
    pub fn snapshot_json(&self, cache: Option<&crate::cache::store::CacheStats>) -> Json {
        let counters = Json::Obj(
            CounterId::ALL
                .iter()
                .map(|&c| (c.name().to_string(), Json::num(self.registry.counter(c) as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            GaugeId::ALL
                .iter()
                .map(|&g| (g.name().to_string(), Json::num(self.registry.gauge(g) as f64)))
                .collect(),
        );
        let hists = Json::Obj(
            HistId::ALL
                .iter()
                .map(|&h| (h.name().to_string(), self.registry.hist(h).to_json()))
                .collect(),
        );
        let cache = match cache {
            None => Json::Null,
            Some(s) => Json::obj(vec![
                ("hits", Json::num(s.hits as f64)),
                ("misses", Json::num(s.misses as f64)),
                ("stores", Json::num(s.stores as f64)),
                ("rejected", Json::num(s.rejected as f64)),
                ("hit_rate", Json::num(s.hit_rate())),
            ]),
        };
        let shapes = Json::Arr(
            self.shapes
                .tasks()
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("batch", Json::num(t.batch as f64)),
                        (
                            "shape",
                            Json::Arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                        ),
                        ("count", Json::num(t.count as f64)),
                    ])
                })
                .collect(),
        );
        let profile =
            Json::Arr(self.profile.rows().iter().map(|r| r.to_json()).collect());
        Json::obj(vec![
            ("kind", Json::str("tvmq-metrics")),
            ("schema_version", Json::num(SNAPSHOT_SCHEMA_VERSION as f64)),
            ("counters", counters),
            ("gauges", gauges),
            ("hists", hists),
            ("cache", cache),
            ("shapes", shapes),
            ("profile", profile),
        ])
    }

    /// Write the snapshot via tmp+rename, so readers never see a torn
    /// file (same discipline as the compile cache's stores).
    pub fn write_snapshot(
        &self,
        path: &Path,
        cache: Option<&crate::cache::store::CacheStats>,
    ) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.snapshot_json(cache).to_string_pretty() + "\n")
            .with_context(|| format!("writing metrics snapshot to {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming metrics snapshot into {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;

    #[test]
    fn bucket_of_is_log2_with_zero_bucket() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Uppers bound their buckets.
        for v in [0u64, 1, 2, 5, 100, 4096] {
            assert!(v <= bucket_upper(bucket_of(v)), "v={v}");
        }
    }

    #[test]
    fn hist_quantiles_and_deltas() {
        let r = Registry::new();
        for v in [1u64, 1, 1, 100, 100, 10_000] {
            r.record(HistId::LatencyUs, v);
        }
        let s = r.hist(HistId::LatencyUs);
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 10_203);
        assert_eq!(s.quantile(0.5), Some(bucket_upper(bucket_of(1))));
        assert_eq!(s.max_value(), Some(bucket_upper(bucket_of(10_000))));
        // Delta isolates what happened after the first snapshot.
        r.record(HistId::LatencyUs, 1_000_000);
        let d = r.hist(HistId::LatencyUs).delta(&s);
        assert_eq!(d.count, 1);
        assert_eq!(d.sum, 1_000_000);
        assert_eq!(d.max_value(), Some(bucket_upper(bucket_of(1_000_000))));
        assert_eq!(HistSnapshot::empty().quantile(0.5), None);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let r = Registry::disabled();
        r.count(CounterId::Requests, 5);
        r.gauge_set(GaugeId::QueueDepth, 9);
        r.gauge_max(GaugeId::QueueDepthMax, 9);
        r.record(HistId::BatchSize, 4);
        assert_eq!(r.counter(CounterId::Requests), 0);
        assert_eq!(r.gauge(GaugeId::QueueDepth), 0);
        assert_eq!(r.gauge(GaugeId::QueueDepthMax), 0);
        assert_eq!(r.hist(HistId::BatchSize).count, 0);
    }

    #[test]
    fn gauge_max_keeps_high_water_until_reset() {
        let r = Registry::new();
        r.gauge_max(GaugeId::QueueDepthMax, 3);
        r.gauge_max(GaugeId::QueueDepthMax, 9);
        r.gauge_max(GaugeId::QueueDepthMax, 5);
        assert_eq!(r.gauge(GaugeId::QueueDepthMax), 9);
        r.gauge_reset(GaugeId::QueueDepthMax);
        assert_eq!(r.gauge(GaugeId::QueueDepthMax), 0);
    }

    fn key(op: &str) -> StepKey {
        StepKey {
            op: op.into(),
            shape: vec![1, 8, 6, 6],
            layout: "nchw".into(),
            precision: "int8".into(),
            isa: "scalar".into(),
            micro: "-".into(),
        }
    }

    #[test]
    fn profile_sink_interns_and_aggregates_across_profilers() {
        let sink = ProfileSink::new();
        // Two engines (e.g. two workers) with the same step key share one
        // cell; a distinct key gets its own.
        let p1 = StepProfiler::new(1, &sink, vec![key("qconv2d"), key("dense")]);
        let p2 = StepProfiler::new(1, &sink, vec![key("qconv2d")]);
        assert_eq!(p1.steps(), 2);
        p1.record(0, 100);
        p2.record(0, 50);
        p1.record(1, 7);
        let rows = sink.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].key.op, "qconv2d");
        assert_eq!(rows[0].hits, 2);
        assert_eq!(rows[0].total_ns, 150);
        assert!((rows[0].mean_ns() - 75.0).abs() < 1e-9);
        assert_eq!(rows[1].total_ns, 7);
    }

    #[test]
    fn profiler_samples_every_nth_and_zero_disables() {
        let sink = ProfileSink::new();
        let p = StepProfiler::new(3, &sink, vec![key("a")]);
        let fired: Vec<bool> = (0..9).map(|_| p.should_sample()).collect();
        assert_eq!(
            fired,
            [true, false, false, true, false, false, true, false, false]
        );
        assert_eq!(p.samples(), 3);
        let off = StepProfiler::new(0, &sink, vec![key("a")]);
        assert!((0..100).all(|_| !off.should_sample()));
        assert_eq!(off.samples(), 0);
    }

    /// A stationary seeded trace must never trigger: noise within a
    /// factor of two stays inside the same log2 buckets.
    #[test]
    fn drift_detector_is_quiet_on_a_stationary_trace() {
        let cfg = DriftConfig { baseline: 64, window: 16, ratio: 1.5, sustain: 2 };
        let mut d = DriftDetector::new(cfg);
        let mut rng = Rng64::seed_from_u64(42);
        for _ in 0..2000 {
            // ~700–900us: jitter, but bucket-stable around p50.
            let v = 800i64 + (rng.normal() * 40.0) as i64;
            assert!(!d.observe(v.max(1) as u64));
        }
        assert_eq!(d.triggers(), 0);
    }

    /// A planted 8x step change triggers exactly once: the sustained
    /// windows fire, then re-baselining absorbs the new level.
    #[test]
    fn drift_detector_triggers_exactly_once_on_a_planted_step() {
        let cfg = DriftConfig { baseline: 64, window: 16, ratio: 1.5, sustain: 2 };
        let mut d = DriftDetector::new(cfg);
        let mut rng = Rng64::seed_from_u64(7);
        let mut fired = Vec::new();
        for i in 0..3000 {
            let base = if i < 500 { 800.0 } else { 6400.0 };
            let v = (base + rng.normal() * base * 0.05).max(1.0) as u64;
            if d.observe(v) {
                fired.push(i);
            }
        }
        assert_eq!(fired.len(), 1, "triggers at {fired:?}");
        assert_eq!(d.triggers(), 1);
        // The trigger lands after the planted step (windows straddling
        // the step may already breach, so only the step index bounds it).
        assert!(fired[0] > 500, "triggered before the planted step: {}", fired[0]);
    }

    /// Verdict sequences are a pure function of the trace.
    #[test]
    fn drift_detector_is_deterministic() {
        let cfg = DriftConfig { baseline: 32, window: 8, ratio: 1.5, sustain: 1 };
        let run = || {
            let mut d = DriftDetector::new(cfg);
            let mut rng = Rng64::seed_from_u64(99);
            (0..600)
                .map(|i| {
                    let base = if i < 200 { 100.0 } else { 900.0 };
                    d.observe((base + rng.normal() * 10.0).max(1.0) as u64)
                })
                .collect::<Vec<bool>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn telemetry_arms_one_retune_request_per_sustained_regression() {
        let t = Telemetry::new(DriftConfig { baseline: 32, window: 8, ratio: 1.5, sustain: 2 });
        for _ in 0..200 {
            t.observe_latency_us(100);
        }
        assert!(!t.retune_pending());
        for _ in 0..200 {
            t.observe_latency_us(1600);
        }
        assert_eq!(t.drift_triggers(), 1);
        assert!(t.retune_pending());
        assert!(t.take_retune_request());
        assert!(!t.take_retune_request(), "request is claimed once");
        // The regression already re-baselined; more of the same level
        // stays quiet.
        for _ in 0..400 {
            t.observe_latency_us(1600);
        }
        assert_eq!(t.drift_triggers(), 1);
    }

    #[test]
    fn shape_recorder_orders_by_traffic() {
        let s = ShapeRecorder::new();
        for _ in 0..3 {
            s.record(1, &[1, 3, 16, 16]);
        }
        for _ in 0..7 {
            s.record(4, &[4, 3, 16, 16]);
        }
        s.record(8, &[8, 3, 16, 16]);
        let tasks = s.tasks();
        assert_eq!(tasks.len(), 3);
        assert_eq!((tasks[0].batch, tasks[0].count), (4, 7));
        assert_eq!((tasks[1].batch, tasks[1].count), (1, 3));
        assert_eq!((tasks[2].batch, tasks[2].count), (8, 1));
    }

    #[test]
    fn snapshot_json_carries_the_documented_schema() {
        let t = Telemetry::new(DriftConfig::default());
        t.registry.count(CounterId::Requests, 12);
        t.registry.gauge_set(GaugeId::EngineGeneration, 2);
        t.registry.record(HistId::BatchSize, 4);
        t.shapes.record(4, &[4, 3, 16, 16]);
        let j = t.snapshot_json(None);
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "tvmq-metrics");
        assert_eq!(
            j.get("schema_version").unwrap().as_u64().unwrap(),
            SNAPSHOT_SCHEMA_VERSION
        );
        assert_eq!(
            j.get("counters").unwrap().get("requests").unwrap().as_u64().unwrap(),
            12
        );
        assert_eq!(
            j.get("gauges")
                .unwrap()
                .get("engine_generation")
                .unwrap()
                .as_u64()
                .unwrap(),
            2
        );
        let bs = j.get("hists").unwrap().get("batch_size").unwrap();
        assert_eq!(bs.get("count").unwrap().as_u64().unwrap(), 1);
        assert_eq!(bs.get("buckets").unwrap().as_arr().unwrap().len(), HIST_BUCKETS);
        assert!(matches!(j.get("cache").unwrap(), Json::Null));
        assert_eq!(j.get("shapes").unwrap().as_arr().unwrap().len(), 1);
        // Round-trips through the writer.
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(&back, &j);
    }
}
