//! Artifact manifest: the contract between the AOT compile path (python)
//! and the runtime (this crate).
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered bundle: its model configuration, executor kind (graph = one fused
//! module, vm = per-segment modules), batch size, module I/O specs, and
//! quantization metadata.  Parsed with the in-tree JSON parser
//! ([`crate::util::json`]) — the offline build has no serde.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::executor::{EngineKind, EngineSpec, LayoutTag, Precision, Schedule};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub arch: String,
    pub image_size: usize,
    pub in_channels: usize,
    pub num_classes: usize,
    pub param_count: u64,
    /// Calibration scales (NCHW tap names) — recorded for inspection.
    pub scales: HashMap<String, f64>,
    pub batches: Vec<usize>,
    pub bundles: Vec<Bundle>,
    /// Directory the manifest was loaded from.
    pub root: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Bundle {
    pub id: String,
    pub config: ModelConfig,
    /// `Graph` (one fused module) or `Vm` (per-segment modules); parsed at
    /// decode time, so an unknown executor tag never reaches a lookup.
    pub executor: EngineKind,
    pub batch: usize,
    pub modules: Vec<ModuleSpec>,
    pub quant: Option<QuantReport>,
    /// Parameter bytes at this bundle's precision.
    pub weight_bytes: u64,
}

impl Bundle {
    /// The typed variant selector this bundle satisfies.
    pub fn spec(&self) -> EngineSpec {
        EngineSpec {
            layout: self.config.layout,
            schedule: self.config.schedule,
            precision: self.config.precision,
            engine: self.executor,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub arch: String,
    pub image_size: usize,
    pub in_channels: usize,
    pub num_classes: usize,
    pub layout: LayoutTag,
    pub schedule: Schedule,
    pub precision: Precision,
    pub c_block: usize,
    pub k_block: usize,
    pub h_tile: usize,
}

#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub file: String,
    /// Which bundle value feeds each argument: 0 = the bundle input,
    /// i > 0 = the output of module i-1 (the VM's register wiring).
    pub args: Vec<usize>,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    /// "prefix" | "middle" | "suffix" for vm bundles; None for fused.
    pub role: Option<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * crate::runtime::DType::parse(&self.dtype).size_bytes()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone, Copy)]
pub struct QuantReport {
    pub sqnr_db: f64,
    pub cosine: f64,
    pub top1_agreement: f64,
    pub max_abs_err: f64,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let m = Self::from_json(&j, dir.to_path_buf()).context("decoding manifest.json")?;
        m.validate()?;
        Ok(m)
    }

    fn from_json(j: &Json, root: PathBuf) -> Result<Self> {
        let mut scales = HashMap::new();
        if let Some(s) = j.opt("scales") {
            for (k, v) in s.as_obj()? {
                scales.insert(k.clone(), v.as_f64()?);
            }
        }
        Ok(Manifest {
            version: j.get("version")?.as_usize()? as u32,
            arch: j.get("arch")?.as_str()?.to_string(),
            image_size: j.get("image_size")?.as_usize()?,
            in_channels: j.get("in_channels")?.as_usize()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            param_count: j.get("param_count")?.as_u64()?,
            scales,
            batches: j
                .get("batches")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            bundles: j
                .get("bundles")?
                .as_arr()?
                .iter()
                .map(Bundle::from_json)
                .collect::<Result<_>>()?,
            root,
        })
    }

    /// Structural validation: ids unique, files exist, vm chains type-check.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for b in &self.bundles {
            if !seen.insert(&b.id) {
                bail!("duplicate bundle id {:?}", b.id);
            }
            if b.executor == EngineKind::Arena {
                bail!(
                    "bundle {:?}: arena engines are compiled natively from the \
                     graph IR, never from artifacts",
                    b.id
                );
            }
            if b.executor == EngineKind::Graph && b.modules.len() != 1 {
                bail!("graph bundle {:?} must have exactly 1 module", b.id);
            }
            if b.modules.is_empty() {
                bail!("bundle {:?} has no modules", b.id);
            }
            for m in &b.modules {
                let p = self.root.join(&m.file);
                if !p.exists() {
                    bail!("bundle {:?}: missing HLO file {}", b.id, p.display());
                }
            }
            // The value DAG must type-check: every arg refers to an
            // earlier value and its declared spec matches the producer.
            let input_spec = b
                .modules
                .first()
                .and_then(|m| m.inputs.first())
                .ok_or_else(|| anyhow!("bundle {:?}: no input spec", b.id))?
                .clone();
            for (i, m) in b.modules.iter().enumerate() {
                if m.args.len() != m.inputs.len() {
                    bail!("bundle {:?}/{}: args/inputs arity mismatch", b.id, m.name);
                }
                for (arg, spec) in m.args.iter().zip(&m.inputs) {
                    let producer = if *arg == 0 {
                        &input_spec
                    } else if *arg <= i {
                        &b.modules[*arg - 1].output
                    } else {
                        bail!(
                            "bundle {:?}/{}: arg {} refers to a later value",
                            b.id, m.name, arg
                        );
                    };
                    if producer != spec {
                        bail!(
                            "bundle {:?}/{}: value {} spec mismatch",
                            b.id, m.name, arg
                        );
                    }
                }
            }
            if input_spec.shape.first() != Some(&b.batch) {
                bail!("bundle {:?}: batch dim != declared batch", b.id);
            }
        }
        Ok(())
    }

    pub fn bundle(&self, id: &str) -> Result<&Bundle> {
        self.bundles.iter().find(|b| b.id == id).ok_or_else(|| {
            anyhow!(
                "no bundle {:?} (have: {:?})",
                id,
                self.bundles.iter().map(|b| &b.id).collect::<Vec<_>>()
            )
        })
    }

    /// Find the bundle satisfying a typed variant spec at a batch size.
    pub fn find(&self, spec: EngineSpec, batch: usize) -> Result<&Bundle> {
        self.bundles
            .iter()
            .find(|b| b.spec() == spec && b.batch == batch)
            .ok_or_else(|| anyhow!("no bundle for {spec} b{batch}"))
    }

    /// Batch sizes available for a given variant — the serving bucket set.
    pub fn batch_buckets(&self, spec: EngineSpec) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .bundles
            .iter()
            .filter(|b| b.spec() == spec)
            .map(|b| b.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

impl Bundle {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Bundle {
            id: j.get("id")?.as_str()?.to_string(),
            config: ModelConfig::from_json(j.get("config")?)?,
            executor: j.get("executor")?.as_str()?.parse()?,
            batch: j.get("batch")?.as_usize()?,
            modules: j
                .get("modules")?
                .as_arr()?
                .iter()
                .map(ModuleSpec::from_json)
                .collect::<Result<_>>()?,
            quant: match j.opt("quant") {
                Some(q) => Some(QuantReport {
                    sqnr_db: q.get("sqnr_db")?.as_f64()?,
                    cosine: q.get("cosine")?.as_f64()?,
                    top1_agreement: q.get("top1_agreement")?.as_f64()?,
                    max_abs_err: q.get("max_abs_err")?.as_f64()?,
                }),
                None => None,
            },
            weight_bytes: j.get("weight_bytes")?.as_u64()?,
        })
    }
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            arch: j.get("arch")?.as_str()?.to_string(),
            image_size: j.get("image_size")?.as_usize()?,
            in_channels: j.get("in_channels")?.as_usize()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            layout: j.get("layout")?.as_str()?.parse()?,
            schedule: j.get("schedule")?.as_str()?.parse()?,
            precision: j.get("precision")?.as_str()?.parse()?,
            c_block: j.get("c_block")?.as_usize()?,
            k_block: j.get("k_block")?.as_usize()?,
            h_tile: j.get("h_tile")?.as_usize()?,
        })
    }
}

impl ModuleSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModuleSpec {
            name: j.get("name")?.as_str()?.to_string(),
            file: j.get("file")?.as_str()?.to_string(),
            args: j
                .get("args")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            inputs: j
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            output: TensorSpec::from_json(j.get("output")?)?,
            role: j.opt("role").map(|r| r.as_str().map(String::from)).transpose()?,
        })
    }
}
