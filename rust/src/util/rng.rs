//! Deterministic PRNG (splitmix64 + xoshiro256**), replacing the rand crate
//! in the offline build.  Quality is ample for synthetic workloads, seeded
//! weights, and property-test case generation.

#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion, as the xoshiro authors recommend.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng64 { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi].
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// int8 uniform in [-127, 127].
    pub fn i8(&mut self) -> i8 {
        self.range_i64(-127, 127) as i8
    }

    /// Approximate standard normal (Irwin–Hall of 4 uniforms, rescaled).
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.f32()).sum::<f32>() - 2.0;
        s * 0.866 * 2.0
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::seed_from_u64(7);
        let mut b = Rng64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng64::seed_from_u64(1);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng64::seed_from_u64(2);
        for _ in 0..1000 {
            let v = r.range_i64(-3, 5);
            assert!((-3..=5).contains(&v));
        }
    }
}
