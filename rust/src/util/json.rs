//! Minimal JSON: full parser (RFC 8259 subset sufficient for the artifact
//! manifest) + writer.  No serde in the offline build.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (want key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("not a string: {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("not a number: {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("not an array: {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("not an object: {other:?}"),
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{:indent$}", "", indent = indent + 2);
                    item.write(out, indent + 2);
                }
                let _ = write!(out, "\n{:indent$}]", "", indent = indent);
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{:indent$}", "", indent = indent + 2);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 2);
                }
                let _ = write!(out, "\n{:indent$}}}", "", indent = indent);
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: parse the low half if present.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Builder helpers for emitting JSON.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}
