//! In-tree substrates for an offline build: JSON, CLI parsing, PRNG.
//!
//! The build environment vendors only the `xla` bridge's dependency
//! closure, so the usual ecosystem crates (serde_json, clap, rand, …) are
//! implemented here at the scale this system needs.

pub mod cli;
pub mod json;
pub mod rng;
