//! Minimal CLI argument parsing (clap replacement for the offline build).
//!
//! Supports `--flag value`, `--flag=value`, bare `--flag` booleans, short
//! `-x value` flags, a positional subcommand, and trailing positional
//! operands (`rest`), with generated usage text.
//!
//! Positional operands after the subcommand are *collected*, not
//! rejected — but only subcommands that declare they take operands
//! should accept them: callers that don't, guard with
//! [`Args::reject_rest`] so a typo like `tvmq serve arena` still fails
//! loudly instead of being silently ignored.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Positional operands after the subcommand (e.g. the input record
    /// files of `tvmq tune --merge a.json b.json -o merged.json`).
    pub rest: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Result<Args> {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter(items: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            let key = if let Some(rest) = a.strip_prefix("--") {
                Some(rest.to_string())
            } else if a.len() == 2 && a.starts_with('-') && !a[1..].starts_with(|c: char| c.is_ascii_digit()) {
                // Short flag (`-o out.json`); negative numbers are operands.
                Some(a[1..].to_string())
            } else {
                None
            };
            match key {
                Some(k) => {
                    if let Some((k, v)) = k.split_once('=') {
                        out.flags.insert(k.to_string(), v.to_string());
                    } else if it
                        .peek()
                        .map(|n| !n.starts_with('-'))
                        .unwrap_or(false)
                    {
                        let v = it.next().expect("peeked");
                        out.flags.insert(k, v);
                    } else {
                        out.bools.push(k);
                    }
                }
                None if out.subcommand.is_none() => out.subcommand = Some(a),
                None => out.rest.push(a),
            }
        }
        Ok(out)
    }

    /// Fail if positional operands were given — for subcommands that
    /// take none, so stray arguments stay an error (the pre-`rest`
    /// behaviour) instead of being dropped on the floor.
    pub fn reject_rest(&self) -> Result<()> {
        if let Some(a) = self.rest.first() {
            bail!("unexpected positional argument {a:?}");
        }
        Ok(())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated usize list.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{key}: bad integer {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --max-batch 8 --precision=int8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("max-batch", 1).unwrap(), 8);
        assert_eq!(a.str("precision", "fp32"), "int8");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.reject_rest().is_ok());
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("bench --batches 1,16,64");
        assert_eq!(a.usize_list("batches", &[1]).unwrap(), vec![1, 16, 64]);
        assert_eq!(a.usize_list("other", &[2, 3]).unwrap(), vec![2, 3]);
    }

    #[test]
    fn rest_operands_and_short_flags() {
        let a = parse("tune --merge a.json b.json -o out.json");
        assert_eq!(a.subcommand.as_deref(), Some("tune"));
        // `--merge` takes the first operand as its value (flag-with-value
        // grammar); the remainder land in `rest`.
        assert_eq!(a.str("merge", ""), "a.json");
        assert_eq!(a.rest, vec!["b.json".to_string()]);
        assert_eq!(a.opt_str("o").as_deref(), Some("out.json"));
        assert!(a.reject_rest().is_err());
    }

    #[test]
    fn flag_values_never_start_with_dash() {
        // A following `-`-prefixed token is a flag, not a value …
        let a = parse("tune --merge -o out.json a.json");
        assert!(a.flag("merge"));
        assert_eq!(a.opt_str("o").as_deref(), Some("out.json"));
        assert_eq!(a.rest, vec!["a.json".to_string()]);
        // … and `-2` stays an operand (negative-number escape hatch).
        let b = parse("cmd x -2");
        assert_eq!(b.rest, vec!["x".to_string(), "-2".to_string()]);
    }
}
