//! Minimal CLI argument parsing (clap replacement for the offline build).
//!
//! Supports `--flag value`, `--flag=value`, bare `--flag` booleans, and a
//! positional subcommand, with generated usage text.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Result<Args> {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter(items: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.bools.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        Ok(out)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated usize list.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{key}: bad integer {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --max-batch 8 --precision=int8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("max-batch", 1).unwrap(), 8);
        assert_eq!(a.str("precision", "fp32"), "int8");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("bench --batches 1,16,64");
        assert_eq!(a.usize_list("batches", &[1]).unwrap(), vec![1, 16, 64]);
        assert_eq!(a.usize_list("other", &[2, 3]).unwrap(), vec![2, 3]);
    }
}
