//! `tvmq` CLI — leader entrypoint for the coordinator and the paper-table
//! bench harnesses.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use tvmq::bench::{
    ablations, arena_ablation, figure1, memplan_ablation, serve_bench, table1, table2,
    table3, BenchCtx, BenchOpts,
};
use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{EngineKind, EngineSpec, LayoutTag, NativeArenaFactory, Precision};
use tvmq::graph::passes::{
    calibrate_graph, AlterConvLayout, CancelLayoutTransforms, ConstantFold, FusionPass, Pass,
    PassManager, QuantizeRealize,
};
use tvmq::runtime::synthetic_images;
use tvmq::util::cli::Args;

const USAGE: &str = "\
tvmq — quantized-inference runtime reproducing 'Analyzing Quantization in TVM'

USAGE: tvmq <COMMAND> [--artifacts DIR] [flags]

Model variants are typed engine specs (--layout NCHW|NHWC|NCHWc
--schedule reference|spatial_pack|simd|interleaved|native
--precision fp32|int8 --executor graph|vm|arena); unknown tokens are
rejected at parse time.  The arena engine builds all three layouts
natively (NCHWc packs channels in blocks of 8; its input stays NCHW).

COMMANDS:
  inspect           List bundles in the artifact manifest
  run               One inference: --layout NCHW --schedule spatial_pack
                    --precision int8 --executor graph|vm|arena --batch 1 --seed 42
                    (--executor arena runs the in-process IR engine: no
                    artifacts needed; --image 32 --threads 1 also apply)
  serve             Batched serving: --executor graph|vm|arena --precision int8
                    --max-batch 64 --batch-timeout-ms 2 --requests 512 --clients 32
                    (--executor arena serves natively compiled bucket engines —
                    no artifacts; --buckets 1,4,8,16 --image 32 --threads N;
                    exits non-zero unless every request succeeds)
  bench-table1      Table 1 (executor comparison)      [--epochs 110 --warmup 10]
  bench-table2      Table 2 (schedule sweep)           [--epochs 110 --warmup 10]
  bench-table3      Table 3 (batch sweep)              [--batches 1,16,64]
  bench-fig1        Figure 1 (layout packing)          [--reps 5]
  bench-ablations   Executor-mechanism ablations (incl. the arena tier)
  bench-arena       Arena layout × precision matrix vs interpreter
                    [--batches 1,8 --image 32 --threads 1 --epochs 20
                    --warmup 3 | --quick] [--json PATH  machine-readable
                    per-variant ns/iter records]
  bench-serve       Arena bucket serving vs per-request run (no artifacts)
                    [--requests 256 --clients 16 --buckets 1,4,8 --image 32
                    --threads 1 --batch-timeout-ms 2]
  compile-demo      In-process graph-IR pass pipeline  [--batch 1 --c-block 16]

The arena commands default --threads to the TVMQ_THREADS env var (else 1);
threads > 1 uses the executor's persistent worker pool.
";

/// Default kernel fan-out for the arena tier: the `TVMQ_THREADS` env var
/// (what the CI pool-path job sets) falling back to single-threaded.
fn env_threads() -> usize {
    std::env::var("TVMQ_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Assemble the typed engine spec from the four CLI axis flags.  Each
/// token parses through the [`EngineSpec`] vocabulary, so a typo fails
/// here with the valid set instead of as a lookup miss later.
fn parse_spec(args: &Args) -> Result<EngineSpec> {
    let engine: EngineKind = args.str("executor", "graph").parse()?;
    let mut spec = EngineSpec::new(engine);
    spec.layout = args.str("layout", spec.layout.as_str()).parse()?;
    spec.schedule = args.str("schedule", spec.schedule.as_str()).parse()?;
    spec.precision = args.str("precision", spec.precision.as_str()).parse()?;
    Ok(spec)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    let artifacts: PathBuf = args
        .opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(tvmq::default_artifacts_dir);

    let opts = BenchOpts {
        epochs: args.usize("epochs", 110)?,
        warmup: args.usize("warmup", 10)?,
    };

    match args.subcommand.as_deref() {
        Some("inspect") => inspect(&artifacts)?,
        Some("run") => run_one(&artifacts, &args)?,
        Some("serve") => serve_demo(&artifacts, &args)?,
        Some("bench-table1") => {
            table1(&BenchCtx::new(&artifacts, opts)?)?.0.print();
        }
        Some("bench-table2") => {
            table2(&BenchCtx::new(&artifacts, opts)?)?.0.print();
        }
        Some("bench-table3") => {
            let batches = args.usize_list("batches", &[1, 16, 64])?;
            table3(&BenchCtx::new(&artifacts, opts)?, &batches)?.0.print();
        }
        Some("bench-fig1") => {
            figure1(args.usize("reps", 5)?)?.print();
        }
        Some("bench-ablations") => {
            // The arena tier runs on the in-process IR — no artifacts, so it
            // always prints; the PJRT-backed ablations need `make artifacts`.
            print_arena_ablation(&args)?;
            match BenchCtx::new(&artifacts, opts) {
                Ok(ctx) => {
                    ablations(&ctx)?.print();
                    memplan_ablation(&ctx)?.print();
                }
                Err(e) => eprintln!(
                    "skipping artifact-backed ablations ({e}); run `make artifacts`"
                ),
            }
        }
        Some("bench-arena") => {
            print_arena_ablation(&args)?;
        }
        Some("bench-serve") => {
            serve_bench(
                &args.usize_list("buckets", &[1, 4, 8])?,
                args.usize("image", 32)?,
                args.usize("threads", env_threads())?,
                args.usize("requests", 256)?,
                args.usize("clients", 16)?,
                Duration::from_millis(args.u64("batch-timeout-ms", 2)?),
            )?
            .print();
        }
        Some("compile-demo") => {
            compile_demo(args.usize("batch", 1)?, args.usize("c-block", 16)?)?;
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
        None => {
            print!("{USAGE}");
        }
    }
    Ok(())
}

fn inspect(artifacts: &PathBuf) -> Result<()> {
    let m = tvmq::Manifest::load(artifacts)?;
    println!(
        "arch={} image={} classes={} params={}",
        m.arch, m.image_size, m.num_classes, m.param_count
    );
    println!("{:62} {:6} {:6} {:8}", "bundle", "exec", "batch", "modules");
    for b in &m.bundles {
        println!(
            "{:62} {:6} {:6} {:8}{}",
            b.id,
            b.executor.as_str(),
            b.batch,
            b.modules.len(),
            b.quant
                .as_ref()
                .map(|q| format!("  sqnr={:.1}dB top1={:.2}", q.sqnr_db, q.top1_agreement))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn run_one(artifacts: &PathBuf, args: &Args) -> Result<()> {
    use tvmq::executor::{Executor, GraphExecutor, VmExecutor};
    let spec = parse_spec(args)?;
    if spec.engine == EngineKind::Arena {
        return run_arena(args, spec);
    }
    let batch = args.usize("batch", 1)?;
    let seed = args.u64("seed", 42)?;

    let m = tvmq::Manifest::load(artifacts)?;
    let rt = std::rc::Rc::new(tvmq::Runtime::new()?);
    let bundle = m.find(spec, batch)?;
    let exec: Box<dyn Executor> = match spec.engine {
        EngineKind::Graph => Box::new(GraphExecutor::new(rt, &m, bundle)?),
        _ => Box::new(VmExecutor::new(rt, &m, bundle)?),
    };
    let rest = if spec.layout == LayoutTag::Nhwc {
        vec![m.image_size, m.image_size, m.in_channels]
    } else {
        vec![m.in_channels, m.image_size, m.image_size]
    };
    let x = synthetic_images(batch, &rest, seed);
    let t0 = std::time::Instant::now();
    let logits = exec.run(&x)?;
    println!("ran {} in {:.2} ms", bundle.id, t0.elapsed().as_secs_f64() * 1e3);
    println!("classes: {:?}", logits.argmax_last()?);
    println!("logits[0]: {:?}", &logits.as_f32()?[..m.num_classes.min(10)]);
    Ok(())
}

/// The arena layout × precision matrix, shared by `bench-arena` and the
/// artifact-free half of `bench-ablations`.  `--quick` shrinks epochs,
/// batches, and image for CI smoke runs; explicit flags still win.
/// `--json <path>` additionally writes the machine-readable per-variant
/// perf records (ns/iter), the cross-PR perf trajectory.
fn print_arena_ablation(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let arena_opts = BenchOpts {
        epochs: args.usize("epochs", if quick { 5 } else { 20 })?,
        warmup: args.usize("warmup", if quick { 1 } else { 3 })?,
    };
    let threads = args.usize("threads", env_threads())?;
    let image = args.usize("image", if quick { 16 } else { 32 })?;
    let (table, rows) = arena_ablation(
        &arena_opts,
        &args.usize_list("batches", if quick { &[1, 2] } else { &[1, 8] })?,
        image,
        threads,
    )?;
    table.print();
    if let Some(path) = args.opt_str("json") {
        write_arena_json(&path, &rows, &arena_opts, image)?;
        println!("wrote {} perf records to {path}", rows.len());
    }
    Ok(())
}

/// Serialize the arena perf rows with the run protocol (epochs, warmup,
/// image size), so a stored BENCH_*.json is self-describing when diffed
/// across PRs — records from different workloads can't be confused.
fn write_arena_json(
    path: &str,
    rows: &[tvmq::bench::ArenaRow],
    opts: &BenchOpts,
    image: usize,
) -> Result<()> {
    use tvmq::util::json::Json;
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("batch", Json::num(r.batch as f64)),
                ("layout", Json::str(r.layout.clone())),
                ("precision", Json::str(r.precision.clone())),
                ("config", Json::str(r.config.clone())),
                ("fused", Json::Bool(r.fused)),
                ("threads", Json::num(r.threads as f64)),
                ("mean_ms", Json::num(r.mean_ms)),
                ("ns_per_iter", Json::num(r.ns_per_iter)),
                ("steps", Json::num(r.steps as f64)),
                ("fused_chains", Json::num(r.fused_chains as f64)),
                ("arena_bytes", Json::num(r.arena_bytes as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("arena")),
        ("epochs", Json::num(opts.epochs as f64)),
        ("warmup", Json::num(opts.warmup as f64)),
        ("image", Json::num(image as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    std::fs::write(path, doc.to_string_pretty() + "\n")
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

/// `run --executor arena`: the artifact-free tier — build the ResNet-style
/// IR in the spec's layout (NCHW, NHWC, or packed NCHWc), optionally
/// quantize-realize it, compile to the arena engine, run.
fn run_arena(args: &Args, spec: EngineSpec) -> Result<()> {
    use tvmq::executor::factory::{ir_layout, ARENA_MODEL_SEED};
    use tvmq::executor::{ArenaExec, Executor};
    use tvmq::graph::passes::QuantizeRealize;
    use tvmq::graph::{build_resnet_ir_in, calibrate_ir};

    let batch = args.usize("batch", 1)?;
    let image = args.usize("image", 32)?;
    let threads = args.usize("threads", env_threads())?;
    let seed = args.u64("seed", 42)?;

    let g = build_resnet_ir_in(batch, image, ARENA_MODEL_SEED, ir_layout(spec.layout))?;
    let g = match spec.precision {
        Precision::Fp32 => g,
        Precision::Int8 => {
            let calib = calibrate_ir(&g, 1);
            let scales = calibrate_graph(&g, &calib)?;
            QuantizeRealize { scales }.run(&g)?
        }
    };
    let exec = ArenaExec::with_options(&g, true, threads)?;
    let cg = exec.compiled();
    println!(
        "compiled {}: {} steps ({} fused chains), arena {:.1} KiB (unshared {:.1} KiB, {:.2}x reuse)",
        exec.name(),
        cg.steps.len(),
        cg.fused_chains,
        cg.arena_bytes as f64 / 1024.0,
        cg.unshared_bytes() as f64 / 1024.0,
        cg.plan.reuse_factor(),
    );
    let x = calibrate_ir(&g, seed);
    let t0 = std::time::Instant::now();
    let logits = exec.run(&x)?;
    println!(
        "ran {} ({}, {threads} thread(s)) in {:.2} ms",
        exec.name(),
        spec.precision,
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("classes: {:?}", logits.argmax_last()?);
    Ok(())
}

fn serve_demo(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let spec = parse_spec(args)?;
    let cfg = ServeConfig {
        spec,
        max_batch: args.usize("max-batch", 64)?,
        batch_timeout: Duration::from_millis(args.u64("batch-timeout-ms", 2)?),
    };
    let requests = args.usize("requests", 512)?;
    let clients = args.usize("clients", 32)?.max(1);

    // The arena engine serves natively compiled bucket engines (no
    // artifacts); the graph/vm engines serve AOT bundles from the
    // manifest.  Either way the image geometry must match the model.
    let (server, rest) = if spec.engine == EngineKind::Arena {
        let buckets = args.usize_list("buckets", &[1, 4, 8, 16])?;
        let image = args.usize("image", 32)?;
        let threads = args.usize("threads", env_threads())?;
        let factory = NativeArenaFactory::new(spec, &buckets, image, threads)?;
        let server = InferenceServer::start_with(factory, cfg)?;
        // NHWC models take channels-last images; NCHW and packed NCHWc
        // models both take plain NCHW (the packed stem is unblocked).
        let rest = if spec.layout == LayoutTag::Nhwc {
            vec![image, image, 3]
        } else {
            vec![3, image, image]
        };
        (server, rest)
    } else {
        let m = tvmq::Manifest::load(artifacts)?;
        let rest = if spec.layout == LayoutTag::Nhwc {
            vec![m.image_size, m.image_size, m.in_channels]
        } else {
            vec![m.in_channels, m.image_size, m.image_size]
        };
        (InferenceServer::start(artifacts.clone(), cfg)?, rest)
    };
    let server = std::sync::Arc::new(server);
    println!("serving {spec} with buckets {:?}", server.buckets);

    let t0 = std::time::Instant::now();
    let per_client = (requests / clients).max(1);
    let expected = (per_client * clients) as u64;
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = server.clone();
        let rest = rest.clone();
        handles.push(std::thread::spawn(move || {
            let mut errors = 0u64;
            for i in 0..per_client {
                let img = synthetic_images(1, &rest, (c * 1000 + i) as u64);
                if server.submit_blocking(img).is_err() {
                    errors += 1;
                }
            }
            errors
        }));
    }
    let mut client_errors = 0u64;
    for h in handles {
        client_errors += h.join().unwrap_or(per_client as u64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = stats.latency_stats();
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)  errors={}",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        // Server-side count; every such failure also surfaces as a client
        // Err, so adding client_errors here would double-count.
        stats.errors
    );
    println!(
        "latency ms: p50={:.2} p95={:.2} p99={:.2}  mean batch={:.1}  batches={} padded={}",
        lat.p50_ms, lat.p95_ms, lat.p99_ms, stats.mean_batch(), stats.batches, stats.padded_slots
    );
    println!("bucket histogram: {:?}", stats.batch_histogram);
    // Smoke contract (CI relies on this): every request answered, none
    // with an error.
    if stats.requests != expected || stats.errors != 0 || client_errors != 0 {
        bail!(
            "serve smoke failed: {}/{expected} requests ok, {} server errors, \
             {client_errors} client errors",
            stats.requests, stats.errors
        );
    }
    Ok(())
}

/// The graph-IR compile pipeline end to end: build → calibrate → quantize →
/// layout-alter → fold → fuse, printing per-pass statistics.
fn compile_demo(batch: usize, c_block: usize) -> Result<()> {
    use tvmq::executor::factory::ARENA_MODEL_SEED;
    use tvmq::graph::{build_resnet_ir, calibrate_ir, evaluate};
    let g = build_resnet_ir(batch, 32, ARENA_MODEL_SEED)?;
    println!("built resnet10 IR: {} nodes, {} const bytes", g.len(), g.const_bytes());

    let calib = calibrate_ir(&g, 42);
    let ref_out = evaluate(&g, &calib)?;

    // Quantize pipeline.
    let scales = calibrate_graph(&g, &calib)?;
    println!("calibrated {} conv/dense scales", scales.len());
    let q = QuantizeRealize { scales }.run(&g)?;
    println!("quantize_realize: {} -> {} nodes", g.len(), q.len());
    let q_out = evaluate(&q, &calib)?;
    let (r, qv) = (ref_out.as_f32()?, q_out.as_f32()?);
    let num: f64 = r.iter().zip(&qv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
    let den: f64 = r.iter().map(|a| (*a as f64).powi(2)).sum();
    println!("int8 IR sqnr: {:.1} dB", 10.0 * (den / num.max(1e-30)).log10());

    // Layout pipeline on the fp32 graph.
    let pm = PassManager::new()
        .add(AlterConvLayout { c_block, k_block: c_block })
        .add(CancelLayoutTransforms)
        .add(ConstantFold);
    let packed = pm.run(&g)?;
    println!("layout pipeline: {} -> {} nodes (c_block={c_block})", g.len(), packed.len());
    let p_out = evaluate(&packed, &calib)?.as_f32()?;
    let max_err = r.iter().zip(&p_out).fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("packed-vs-NCHW max |err|: {max_err:.2e}");

    // Fusion statistics.
    let plan = FusionPass { enabled: true }.plan(&g)?;
    let nofuse = FusionPass { enabled: false }.plan(&g)?;
    println!(
        "fusion: {} groups fused vs {} unfused ({} compute nodes)",
        plan.group_count(),
        nofuse.group_count(),
        g.nodes
            .iter()
            .filter(|n| !matches!(n.op, tvmq::graph::Op::Input | tvmq::graph::Op::Constant(_)))
            .count()
    );
    Ok(())
}
