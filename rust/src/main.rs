//! `tvmq` CLI — leader entrypoint for the coordinator and the paper-table
//! bench harnesses.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use tvmq::bench::{
    ablations, arena_ablation, figure1, memplan_ablation, serve_bench, table1, table2,
    table3, BenchCtx, BenchOpts,
};
use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{EngineKind, EngineSpec, LayoutTag, NativeArenaFactory, Precision};
use tvmq::graph::passes::{
    calibrate_graph, AlterConvLayout, CancelLayoutTransforms, ConstantFold, FusionPass, Pass,
    PassManager, QuantizeRealize,
};
use tvmq::runtime::synthetic_images;
use tvmq::util::cli::Args;

const USAGE: &str = "\
tvmq — quantized-inference runtime reproducing 'Analyzing Quantization in TVM'

USAGE: tvmq <COMMAND> [--artifacts DIR] [flags]

Model variants are typed engine specs (--layout NCHW|NHWC|NCHWc
--schedule reference|spatial_pack|simd|interleaved|native
--precision fp32|int8 --executor graph|vm|arena); unknown tokens are
rejected at parse time.  The arena engine builds all three layouts
natively (NCHWc packs channels in blocks of 8; its input stays NCHW).

COMMANDS:
  inspect           List bundles in the artifact manifest
  run               One inference: --layout NCHW --schedule spatial_pack
                    --precision int8 --executor graph|vm|arena --batch 1 --seed 42
                    (--executor arena runs the in-process IR engine: no
                    artifacts needed; --image 32 --threads 1 also apply;
                    --tuned records.json loads an autotuned schedule)
  tune              Autotune the arena engine's schedule knobs (banding,
                    band caps, fuse-vs-split, packed lane strategy):
                    --layout NCHW|NHWC|NCHWc --precision int8|fp32
                    --batch 1 --image 32 --threads 1 --budget 32 --seed 1
                    --warmup 2 --iters 10 [--json records.json] [--quick]
                    Every accepted candidate is verified bit-for-bit
                    against the interpreter oracle before it is timed.
                    --merge a.json b.json ... -o merged.json instead merges
                    records files by task key (best measured config wins).
  serve             Batched serving: --executor graph|vm|arena --precision int8
                    --max-batch 64 --batch-timeout-ms 2 --requests 512 --clients 32
                    --workers 1 --queue-bound 1024
                    (--executor arena serves natively compiled bucket engines —
                    no artifacts; --buckets 1,4,8,16 --image 32 --threads N;
                    --workers N shards serving across N engine sets over one
                    bounded admission queue; --tuned records.json serves under
                    the autotuned schedule; exits non-zero unless every
                    request succeeds)
                    --cache-dir D warm-starts from the content-addressed
                    compile cache (hits skip graph compilation entirely;
                    cold builds are stored for the next run; tune-records
                    files found in D are merged and auto-applied, and
                    cache stats land in D/cache-stats.json).
                    --verify-cache re-proves every hit bit-for-bit against
                    the interpreter oracle before serving it.
                    --insitu-tune tunes the live bucket graphs in the
                    background and hot-swaps strictly-better verified
                    schedules into the serving workers at batch
                    boundaries (--tune-budget N bounds the search).
                    --metrics-json PATH writes a versioned live-metrics
                    snapshot (counters, gauges, histograms, cache
                    hit-rate, per-shape traffic, per-step profile) every
                    --metrics-every SECS (default 1) and once at exit,
                    via tmp+rename so readers never see a torn file.
                    --profile-every N samples every Nth inference for
                    per-step attribution (0 = off; sampled rows land in
                    the snapshot's \"profile\" array).
                    --drift-retune watches served latency for sustained
                    regressions and re-tunes the live bucket graphs
                    in-situ when one is detected (arena only; hottest
                    recorded shapes are re-tuned first).
  profile           Per-step attribution table for the arena engine:
                    run N seeded inferences with sampled step timing and
                    print ns-per-step keyed by op/shape/layout/precision/
                    ISA/micro tile [--batch 1 --image 32 --threads 1
                    --iters 30 --profile-every 1 --layout NCHW
                    --precision int8 --tuned records.json --json PATH]
  bench-table1      Table 1 (executor comparison)      [--epochs 110 --warmup 10]
  bench-table2      Table 2 (schedule sweep)           [--epochs 110 --warmup 10]
  bench-table3      Table 3 (batch sweep)              [--batches 1,16,64]
  bench-fig1        Figure 1 (layout packing)          [--reps 5]
  bench-ablations   Executor-mechanism ablations (incl. the arena tier)
  bench-arena       Arena layout × precision matrix vs interpreter
                    [--batches 1,8 --image 32 --threads 1 --epochs 20
                    --warmup 3 | --quick] [--json PATH  machine-readable
                    per-variant ns/iter records] [--tuned [records.json]
                    adds a tuned row per cell: from the records file, or
                    an inline micro-tune (--tune-budget 6) when bare]
                    [--micro on|off  pins the register-blocked int8
                    microkernels on the default-schedule rows (off =
                    scalar loops); TVMQ_MICRO_ISA=scalar|sse2|avx2 caps
                    the dispatched instruction set]
  bench-serve       Arena bucket serving vs per-request run (no artifacts)
                    [--requests 256 --clients 16 --buckets 1,4,8 --image 32
                    --threads 1 --batch-timeout-ms 2 --workers 1]
                    --load replays seeded open-loop arrival traces (Poisson
                    + bursty) instead of closed-loop clients, reporting
                    p50/p99/p999 latency, throughput, and shed rate; every
                    reply is verified bit-for-bit against the interpreter
                    oracle [--rate 400 --requests 2000 --burst 32
                    --queue-bound 64 --seed 7 --json PATH | --quick]
  compile-demo      In-process graph-IR pass pipeline  [--batch 1 --c-block 16]

The arena commands default --threads to the TVMQ_THREADS env var (else 1);
threads > 1 uses the executor's persistent worker pool.
";

/// Default kernel fan-out for the arena tier: the `TVMQ_THREADS` env var
/// (what the CI pool-path job sets) falling back to single-threaded.
fn env_threads() -> usize {
    std::env::var("TVMQ_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Assemble the typed engine spec from the four CLI axis flags.  Each
/// token parses through the [`EngineSpec`] vocabulary, so a typo fails
/// here with the valid set instead of as a lookup miss later.
fn parse_spec(args: &Args) -> Result<EngineSpec> {
    let engine: EngineKind = args.str("executor", "graph").parse()?;
    let mut spec = EngineSpec::new(engine);
    spec.layout = args.str("layout", spec.layout.as_str()).parse()?;
    spec.schedule = args.str("schedule", spec.schedule.as_str()).parse()?;
    spec.precision = args.str("precision", spec.precision.as_str()).parse()?;
    Ok(spec)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    // Only `tune` takes positional operands (the `--merge` input files);
    // everywhere else a stray positional is still a hard parse error.
    if args.subcommand.as_deref() != Some("tune") {
        args.reject_rest()?;
    }
    let artifacts: PathBuf = args
        .opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(tvmq::default_artifacts_dir);

    let opts = BenchOpts {
        epochs: args.usize("epochs", 110)?,
        warmup: args.usize("warmup", 10)?,
    };

    match args.subcommand.as_deref() {
        Some("inspect") => inspect(&artifacts)?,
        Some("run") => run_one(&artifacts, &args)?,
        Some("tune") => tune_cmd(&args)?,
        Some("profile") => profile_cmd(&args)?,
        Some("serve") => serve_demo(&artifacts, &args)?,
        Some("bench-table1") => {
            table1(&BenchCtx::new(&artifacts, opts)?)?.0.print();
        }
        Some("bench-table2") => {
            table2(&BenchCtx::new(&artifacts, opts)?)?.0.print();
        }
        Some("bench-table3") => {
            let batches = args.usize_list("batches", &[1, 16, 64])?;
            table3(&BenchCtx::new(&artifacts, opts)?, &batches)?.0.print();
        }
        Some("bench-fig1") => {
            figure1(args.usize("reps", 5)?)?.print();
        }
        Some("bench-ablations") => {
            // The arena tier runs on the in-process IR — no artifacts, so it
            // always prints; the PJRT-backed ablations need `make artifacts`.
            print_arena_ablation(&args)?;
            match BenchCtx::new(&artifacts, opts) {
                Ok(ctx) => {
                    ablations(&ctx)?.print();
                    memplan_ablation(&ctx)?.print();
                }
                Err(e) => eprintln!(
                    "skipping artifact-backed ablations ({e}); run `make artifacts`"
                ),
            }
        }
        Some("bench-arena") => {
            print_arena_ablation(&args)?;
        }
        Some("bench-serve") => {
            if args.flag("load") {
                bench_serve_load(&args)?;
            } else {
                serve_bench(
                    &args.usize_list("buckets", &[1, 4, 8])?,
                    args.usize("image", 32)?,
                    args.usize("threads", env_threads())?,
                    args.usize("requests", 256)?,
                    args.usize("clients", 16)?,
                    Duration::from_millis(args.u64("batch-timeout-ms", 2)?),
                    args.usize("workers", 1)?,
                )?
                .print();
            }
        }
        Some("compile-demo") => {
            compile_demo(args.usize("batch", 1)?, args.usize("c-block", 16)?)?;
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
        None => {
            print!("{USAGE}");
        }
    }
    Ok(())
}

fn inspect(artifacts: &PathBuf) -> Result<()> {
    let m = tvmq::Manifest::load(artifacts)?;
    println!(
        "arch={} image={} classes={} params={}",
        m.arch, m.image_size, m.num_classes, m.param_count
    );
    println!("{:62} {:6} {:6} {:8}", "bundle", "exec", "batch", "modules");
    for b in &m.bundles {
        println!(
            "{:62} {:6} {:6} {:8}{}",
            b.id,
            b.executor.as_str(),
            b.batch,
            b.modules.len(),
            b.quant
                .as_ref()
                .map(|q| format!("  sqnr={:.1}dB top1={:.2}", q.sqnr_db, q.top1_agreement))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn run_one(artifacts: &PathBuf, args: &Args) -> Result<()> {
    use tvmq::executor::{Executor, GraphExecutor, VmExecutor};
    let spec = parse_spec(args)?;
    if spec.engine == EngineKind::Arena {
        return run_arena(args, spec);
    }
    let batch = args.usize("batch", 1)?;
    let seed = args.u64("seed", 42)?;

    let m = tvmq::Manifest::load(artifacts)?;
    let rt = std::rc::Rc::new(tvmq::Runtime::new()?);
    let bundle = m.find(spec, batch)?;
    let exec: Box<dyn Executor> = match spec.engine {
        EngineKind::Graph => Box::new(GraphExecutor::new(rt, &m, bundle)?),
        _ => Box::new(VmExecutor::new(rt, &m, bundle)?),
    };
    let rest = if spec.layout == LayoutTag::Nhwc {
        vec![m.image_size, m.image_size, m.in_channels]
    } else {
        vec![m.in_channels, m.image_size, m.image_size]
    };
    let x = synthetic_images(batch, &rest, seed);
    let t0 = std::time::Instant::now();
    let logits = exec.run(&x)?;
    println!("ran {} in {:.2} ms", bundle.id, t0.elapsed().as_secs_f64() * 1e3);
    println!("classes: {:?}", logits.argmax_last()?);
    println!("logits[0]: {:?}", &logits.as_f32()?[..m.num_classes.min(10)]);
    Ok(())
}

/// The arena layout × precision matrix, shared by `bench-arena` and the
/// artifact-free half of `bench-ablations`.  `--quick` shrinks epochs,
/// batches, and image for CI smoke runs; explicit flags still win.
/// `--json <path>` additionally writes the machine-readable per-variant
/// perf records (ns/iter), the cross-PR perf trajectory.  `--tuned
/// [records.json]` adds a tuned row to every layout × precision cell —
/// loaded from the records file, or found by an inline micro-tune
/// (`--tune-budget`, deterministic per-cell seeds) when the flag is bare.
fn print_arena_ablation(args: &Args) -> Result<()> {
    use tvmq::bench::TunedSource;
    use tvmq::tune::TuneRecords;

    let quick = args.flag("quick");
    let arena_opts = BenchOpts {
        epochs: args.usize("epochs", if quick { 5 } else { 20 })?,
        warmup: args.usize("warmup", if quick { 1 } else { 3 })?,
    };
    let threads = args.usize("threads", env_threads())?;
    let image = args.usize("image", if quick { 16 } else { 32 })?;
    let loaded: Option<TuneRecords> = match args.opt_str("tuned") {
        Some(path) => Some(TuneRecords::load(&path)?),
        None => None,
    };
    let tuned = match &loaded {
        Some(r) => Some(TunedSource::Records(r)),
        None if args.flag("tuned") => Some(TunedSource::Inline {
            budget: args.usize("tune-budget", 6)?,
            seed: args.u64("seed", 1)?,
        }),
        None => None,
    };
    let force_micro = match args.str("micro", "off").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("--micro takes on|off, got {other:?}"),
    };
    let (table, rows) = arena_ablation(
        &arena_opts,
        &args.usize_list("batches", if quick { &[1, 2] } else { &[1, 8] })?,
        image,
        threads,
        tuned.as_ref(),
        force_micro,
    )?;
    table.print();
    if let Some(path) = args.opt_str("json") {
        write_arena_json(&path, &rows, &arena_opts, image)?;
        println!("wrote {} perf records to {path}", rows.len());
    }
    Ok(())
}

/// `bench-serve --load` — open-loop load generation against the sharded
/// serving tier.  `--quick` is the CI smoke shape (2 workers, short
/// bounded trace, tight queue bound); explicit flags win either way.
/// `--json PATH` writes the per-trace records (p50/p99/p999, throughput,
/// shed rate) next to the other perf artifacts.
fn bench_serve_load(args: &Args) -> Result<()> {
    use tvmq::bench::{load_bench, LoadOpts};

    let mut opts = if args.flag("quick") {
        LoadOpts::quick()
    } else {
        LoadOpts {
            buckets: vec![1, 4, 8],
            image: 32,
            threads: env_threads(),
            workers: 1,
            queue_bound: 64,
            batch_timeout: Duration::from_millis(2),
            rate_rps: 400.0,
            requests: 2000,
            burst: 32,
            seed: 7,
        }
    };
    opts.buckets = args.usize_list("buckets", &opts.buckets)?;
    opts.image = args.usize("image", opts.image)?;
    opts.threads = args.usize("threads", opts.threads)?;
    opts.workers = args.usize("workers", opts.workers)?;
    opts.queue_bound = args.usize("queue-bound", opts.queue_bound)?;
    opts.batch_timeout =
        Duration::from_millis(args.u64("batch-timeout-ms", opts.batch_timeout.as_millis() as u64)?);
    opts.rate_rps = args.usize("rate", opts.rate_rps as usize)? as f64;
    opts.requests = args.usize("requests", opts.requests)?;
    opts.burst = args.usize("burst", opts.burst)?;
    opts.seed = args.u64("seed", opts.seed)?;

    let (table, rows) = load_bench(&opts)?;
    table.print();
    if let Some(path) = args.opt_str("json") {
        write_load_json(&path, &rows, &opts)?;
        println!("wrote {} load records to {path}", rows.len());
    }
    Ok(())
}

/// Serialize the load rows with the offered-trace parameters, so a stored
/// record is self-describing when diffed across PRs.
fn write_load_json(
    path: &str,
    rows: &[tvmq::bench::LoadRow],
    opts: &tvmq::bench::LoadOpts,
) -> Result<()> {
    use tvmq::util::json::Json;
    // Latency and queue-wait percentiles are typed-optional: a trace that
    // served nothing records null, never a silent 0.
    let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("trace", Json::str(r.trace.clone())),
                ("offered", Json::num(r.offered as f64)),
                ("served", Json::num(r.served as f64)),
                ("shed", Json::num(r.shed as f64)),
                ("worker_died", Json::num(r.worker_died as f64)),
                ("timeouts", Json::num(r.timeouts as f64)),
                ("other_errors", Json::num(r.other_errors as f64)),
                ("wall_s", Json::num(r.wall_s)),
                ("throughput_rps", Json::num(r.throughput_rps)),
                ("p50_ms", opt(r.p50_ms)),
                ("p99_ms", opt(r.p99_ms)),
                ("p999_ms", opt(r.p999_ms)),
                ("shed_rate", Json::num(r.shed_rate)),
                ("mean_batch", Json::num(r.mean_batch)),
                ("queue_depth_max", Json::num(r.queue_depth_max as f64)),
                ("queue_wait_p50_ms", opt(r.queue_wait_p50_ms)),
                ("queue_wait_p99_ms", opt(r.queue_wait_p99_ms)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("serve-load")),
        ("workers", Json::num(opts.workers as f64)),
        ("queue_bound", Json::num(opts.queue_bound as f64)),
        ("rate_rps", Json::num(opts.rate_rps)),
        ("requests", Json::num(opts.requests as f64)),
        ("burst", Json::num(opts.burst as f64)),
        ("image", Json::num(opts.image as f64)),
        ("threads", Json::num(opts.threads as f64)),
        ("seed", Json::num(opts.seed as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    std::fs::write(path, doc.to_string_pretty() + "\n")
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

/// Serialize the arena perf rows with the run protocol (epochs, warmup,
/// image size), so a stored BENCH_*.json is self-describing when diffed
/// across PRs — records from different workloads can't be confused.
fn write_arena_json(
    path: &str,
    rows: &[tvmq::bench::ArenaRow],
    opts: &BenchOpts,
    image: usize,
) -> Result<()> {
    use tvmq::util::json::Json;
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("batch", Json::num(r.batch as f64)),
                ("layout", Json::str(r.layout.clone())),
                ("precision", Json::str(r.precision.clone())),
                ("config", Json::str(r.config.clone())),
                ("fused", Json::Bool(r.fused)),
                ("schedule", Json::str(r.schedule.clone())),
                ("knobs", Json::str(r.knobs.clone())),
                ("threads", Json::num(r.threads as f64)),
                ("mean_ms", Json::num(r.mean_ms)),
                ("ns_per_iter", Json::num(r.ns_per_iter)),
                ("steps", Json::num(r.steps as f64)),
                ("fused_chains", Json::num(r.fused_chains as f64)),
                ("arena_bytes", Json::num(r.arena_bytes as f64)),
                ("compile_ms", Json::num(r.compile_ms)),
                ("compile_cached_ms", Json::num(r.compile_cached_ms)),
                ("micro", Json::str(r.micro.clone())),
                ("gibs", Json::num(r.gibs)),
                ("int8_ops_per_s", Json::num(r.int8_ops_per_s)),
                ("roofline_frac", Json::num(r.roofline_frac)),
                (
                    "step_rows",
                    Json::Arr(r.step_rows.iter().map(|s| s.to_json()).collect()),
                ),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("arena")),
        ("epochs", Json::num(opts.epochs as f64)),
        ("warmup", Json::num(opts.warmup as f64)),
        ("image", Json::num(image as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    std::fs::write(path, doc.to_string_pretty() + "\n")
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

/// Build the seeded ResNet-style IR the arena commands share, in the
/// spec's layout, quantize-realized for int8.
fn build_arena_model(spec: EngineSpec, batch: usize, image: usize) -> Result<tvmq::graph::Graph> {
    use tvmq::executor::factory::{ir_layout, ARENA_MODEL_SEED};
    use tvmq::graph::passes::QuantizeRealize;
    use tvmq::graph::{build_resnet_ir_in, calibrate_ir};

    let g = build_resnet_ir_in(batch, image, ARENA_MODEL_SEED, ir_layout(spec.layout))?;
    Ok(match spec.precision {
        Precision::Fp32 => g,
        Precision::Int8 => {
            let calib = calibrate_ir(&g, 1);
            let scales = calibrate_graph(&g, &calib)?;
            QuantizeRealize { scales }.run(&g)?
        }
    })
}

/// `run --executor arena`: the artifact-free tier — build the ResNet-style
/// IR in the spec's layout (NCHW, NHWC, or packed NCHWc), optionally
/// quantize-realize it, compile to the arena engine (under a tuned
/// schedule if `--tuned records.json` is given), run.
fn run_arena(args: &Args, spec: EngineSpec) -> Result<()> {
    use tvmq::executor::{ArenaExec, Executor};
    use tvmq::graph::calibrate_ir;
    use tvmq::tune::TuneRecords;

    let batch = args.usize("batch", 1)?;
    let image = args.usize("image", 32)?;
    let threads = args.usize("threads", env_threads())?;
    let seed = args.u64("seed", 42)?;

    let g = build_arena_model(spec, batch, image)?;
    let exec = match args.opt_str("tuned") {
        Some(path) => {
            let records = TuneRecords::load(&path)?;
            println!("loaded tuned schedule from {path}: {}", records.knob_summary());
            ArenaExec::with_schedule(&g, records.fuse, threads, &records.overrides(threads))?
        }
        None => ArenaExec::with_options(&g, true, threads)?,
    };
    let cg = exec.compiled();
    println!(
        "compiled {}: {} steps ({} fused chains), arena {:.1} KiB (unshared {:.1} KiB, {:.2}x reuse)",
        exec.name(),
        cg.steps.len(),
        cg.fused_chains,
        cg.arena_bytes as f64 / 1024.0,
        cg.unshared_bytes() as f64 / 1024.0,
        cg.plan.reuse_factor(),
    );
    let x = calibrate_ir(&g, seed);
    let t0 = std::time::Instant::now();
    let logits = exec.run(&x)?;
    println!(
        "ran {} ({}, {threads} thread(s)) in {:.2} ms",
        exec.name(),
        spec.precision,
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("classes: {:?}", logits.argmax_last()?);
    Ok(())
}

/// `tvmq tune` — budgeted schedule search over the arena engine's knob
/// space on the seeded model.  Prints the trial log and the winner;
/// `--json PATH` persists the records file the other commands load.
/// Every accepted candidate was verified bit-for-bit against
/// `graph::interp::evaluate` before it was timed, so a records file is
/// oracle-exact by construction.
fn tune_cmd(args: &Args) -> Result<()> {
    use tvmq::graph::calibrate_ir;
    use tvmq::metrics::Table;
    use tvmq::tune::{tune_graph, RunMeta, TuneOptions, TuneRecords};

    if args.flag("merge") || args.opt_str("merge").is_some() {
        return merge_records_cmd(args);
    }
    // Plain tuning takes no positional operands — those belong to --merge.
    args.reject_rest()?;

    let quick = args.flag("quick");
    let spec = {
        let mut spec = EngineSpec::new(EngineKind::Arena);
        spec.layout = args.str("layout", spec.layout.as_str()).parse()?;
        spec.precision = args.str("precision", spec.precision.as_str()).parse()?;
        spec
    };
    let batch = args.usize("batch", 1)?;
    let image = args.usize("image", if quick { 12 } else { 32 })?;
    let threads = args.usize("threads", env_threads())?;
    let opts = TuneOptions {
        budget: args.usize("budget", if quick { 8 } else { 32 })?,
        seed: args.u64("seed", 1)?,
        threads,
        warmup: args.usize("warmup", if quick { 1 } else { 2 })?,
        iters: args.usize("iters", if quick { 3 } else { 10 })?,
        use_prior: !args.flag("no-prior"),
    };

    let g = build_arena_model(spec, batch, image)?;
    let x = calibrate_ir(&g, 42);
    println!(
        "tuning {} {} (batch {batch}, image {image}, {threads} thread(s)): \
         budget {} trials, seed {}",
        spec.layout, spec.precision, opts.budget, opts.seed
    );
    let outcome = tune_graph(&g, x, &opts)?;

    let mut t = Table::new(
        "tune — measured candidates (oracle-verified; best first)",
        &["#", "ns/iter", "vs default", "Knobs"],
    );
    let mut order: Vec<usize> = (0..outcome.trials.len()).collect();
    order.sort_by(|&a, &b| {
        outcome.trials[a].ns_per_iter.total_cmp(&outcome.trials[b].ns_per_iter)
    });
    for (rank, &i) in order.iter().take(8).enumerate() {
        let tr = &outcome.trials[i];
        t.row(vec![
            format!("{}", rank + 1),
            format!("{:.0}", tr.ns_per_iter),
            format!("{:.2}%", 100.0 * outcome.default_ns / tr.ns_per_iter),
            tr.plan.describe(),
        ]);
    }
    t.print();
    println!(
        "best [{}]: {:.0} ns/iter vs default {:.0} ({:.2}% improvement), \
         {} trials measured, {} rejected",
        outcome.best.plan.describe(),
        outcome.best.ns_per_iter,
        outcome.default_ns,
        outcome.improvement_pct(),
        outcome.trials.len(),
        outcome.rejected,
    );

    if let Some(path) = args.opt_str("json") {
        let records = TuneRecords::from_outcome(
            &outcome,
            &RunMeta {
                model: "resnet10".into(),
                layout: spec.layout.as_str().into(),
                precision: spec.precision.as_str().into(),
                image,
                batch,
            },
        );
        records.save(&path)?;
        println!(
            "wrote {} task records to {path} (load with --tuned {path})",
            records.records.len()
        );
    }
    Ok(())
}

/// `tvmq tune --merge a.json b.json ... -o merged.json` — merge tune
/// records files by task key, keeping the best measured config for each
/// task (see [`tvmq::tune::records::merge`]).  Inputs are loaded
/// *strictly*: a corrupt file named on the command line is an error, not
/// a silent skip (the lenient path is for the serve-time scan, where the
/// user never named the file).
fn merge_records_cmd(args: &Args) -> Result<()> {
    use tvmq::tune::{merge, TuneRecords};

    // The flag grammar makes `--merge a.json` put the first operand in
    // the flag's value slot and the rest in `args.rest`.
    let mut inputs: Vec<String> = Vec::new();
    if let Some(first) = args.opt_str("merge") {
        inputs.push(first);
    }
    inputs.extend(args.rest.iter().cloned());
    if inputs.is_empty() {
        bail!("tune --merge needs at least one records file");
    }
    let out = args
        .opt_str("o")
        .or_else(|| args.opt_str("out"))
        .ok_or_else(|| anyhow::anyhow!("tune --merge needs an output path: -o merged.json"))?;
    let mut runs = Vec::with_capacity(inputs.len());
    for p in &inputs {
        runs.push(TuneRecords::load(p)?);
    }
    let merged = merge(&runs)?;
    merged.save(&out)?;
    println!(
        "merged {} records file(s) ({} task records) -> {out}: {}",
        runs.len(),
        merged.records.len(),
        merged.knob_summary()
    );
    Ok(())
}

/// `tvmq profile` — per-step attribution on the arena engine.  Builds
/// the seeded model, attaches a fresh profile sink with `--profile-every`
/// sampling (default: every inference), runs `--iters` seeded
/// inferences, and prints ns-per-step keyed by (op, shape, layout,
/// precision, ISA, micro tile) — heaviest steps first, with each step's
/// share of the sampled total.  `--json PATH` writes the same rows
/// machine-readably.
fn profile_cmd(args: &Args) -> Result<()> {
    use tvmq::executor::{ArenaExec, Executor};
    use tvmq::graph::calibrate_ir;
    use tvmq::metrics::Table;
    use tvmq::telem::ProfileSink;
    use tvmq::tune::TuneRecords;
    use tvmq::util::json::Json;

    let spec = {
        let mut spec = EngineSpec::new(EngineKind::Arena);
        spec.layout = args.str("layout", spec.layout.as_str()).parse()?;
        spec.precision = args.str("precision", spec.precision.as_str()).parse()?;
        spec
    };
    let batch = args.usize("batch", 1)?;
    let image = args.usize("image", 32)?;
    let threads = args.usize("threads", env_threads())?;
    let iters = args.usize("iters", 30)?.max(1);
    let every = args.u64("profile-every", 1)?.max(1);
    let seed = args.u64("seed", 42)?;

    let g = build_arena_model(spec, batch, image)?;
    let mut exec = match args.opt_str("tuned") {
        Some(path) => {
            let records = TuneRecords::load(&path)?;
            println!("profiling tuned schedule from {path}: {}", records.knob_summary());
            ArenaExec::with_schedule(&g, records.fuse, threads, &records.overrides(threads))?
        }
        None => ArenaExec::with_options(&g, true, threads)?,
    };
    let sink = ProfileSink::new();
    exec.set_profiling(every, &sink);
    let x = calibrate_ir(&g, seed);
    for _ in 0..iters {
        exec.run(&x)?;
    }

    let rows = sink.rows();
    let total_ns: u64 = rows.iter().map(|r| r.total_ns).sum();
    let mut t = Table::new(
        format!(
            "tvmq profile — per-step attribution ({} {} batch {batch}, image {image}, \
             {threads} thread(s), {iters} inference(s), sampled every {every})",
            spec.layout, spec.precision
        ),
        &["Step op", "Shape", "Layout", "Prec", "ISA", "Micro", "Hits",
          "Mean (µs)", "Total (ms)", "Share"],
    );
    for r in &rows {
        t.row(vec![
            r.key.op.clone(),
            format!("{:?}", r.key.shape),
            r.key.layout.clone(),
            r.key.precision.clone(),
            r.key.isa.clone(),
            r.key.micro.clone(),
            r.hits.to_string(),
            format!("{:.1}", r.mean_ns() / 1e3),
            format!("{:.3}", r.total_ns as f64 / 1e6),
            format!("{:.1}%", 100.0 * r.total_ns as f64 / total_ns.max(1) as f64),
        ]);
    }
    t.print();

    if let Some(path) = args.opt_str("json") {
        let doc = Json::obj(vec![
            ("bench", Json::str("profile")),
            ("layout", Json::str(spec.layout.as_str())),
            ("precision", Json::str(spec.precision.as_str())),
            ("batch", Json::num(batch as f64)),
            ("image", Json::num(image as f64)),
            ("threads", Json::num(threads as f64)),
            ("iters", Json::num(iters as f64)),
            ("profile_every", Json::num(every as f64)),
            ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
        ]);
        std::fs::write(&path, doc.to_string_pretty() + "\n")
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("wrote {} profile rows to {path}", rows.len());
    }
    Ok(())
}

fn serve_demo(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let spec = parse_spec(args)?;
    let cfg = ServeConfig {
        spec,
        max_batch: args.usize("max-batch", 64)?,
        batch_timeout: Duration::from_millis(args.u64("batch-timeout-ms", 2)?),
        workers: args.usize("workers", 1)?,
        queue_bound: args.usize("queue-bound", 1024)?,
    };
    let requests = args.usize("requests", 512)?;
    let clients = args.usize("clients", 32)?.max(1);

    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use tvmq::cache::{scan_tune_records, CompileCache, MERGED_RECORDS_FILE};
    use tvmq::coordinator::insitu::{spawn_drift_retuner, spawn_insitu_tuner, UpgradeSlot};
    use tvmq::telem::{CounterId, DriftConfig, Telemetry};
    use tvmq::tune::{TuneOptions, TuneRecords};

    // The telemetry spine every worker publishes into: counters, gauges,
    // histograms, the drift detector, and the per-step profile sink.
    let telem = Telemetry::new(DriftConfig::default());
    let metrics_json: Option<PathBuf> = args.opt_str("metrics-json").map(PathBuf::from);
    let metrics_every = args.u64("metrics-every", 1)?.max(1);
    let stop = Arc::new(AtomicBool::new(false));

    // Arena-only extras, reported on after the load finishes.
    let mut cache: Option<Arc<CompileCache>> = None;
    let mut tuner: Option<(std::thread::JoinHandle<()>, Arc<UpgradeSlot>)> = None;
    let mut retuner: Option<std::thread::JoinHandle<()>> = None;

    // The arena engine serves natively compiled bucket engines (no
    // artifacts); the graph/vm engines serve AOT bundles from the
    // manifest.  Either way the image geometry must match the model.
    let (server, rest) = if spec.engine == EngineKind::Arena {
        let buckets = args.usize_list("buckets", &[1, 4, 8, 16])?;
        let image = args.usize("image", 32)?;
        let threads = args.usize("threads", env_threads())?;
        let mut factory = NativeArenaFactory::new(spec, &buckets, image, threads)?;

        // Warm-start: hits skip graph compilation entirely; cold builds
        // are stored for the next run.
        if let Some(dir) = args.opt_str("cache-dir") {
            let c = Arc::new(
                CompileCache::open(&dir)?.with_verify(args.flag("verify-cache")),
            );
            println!(
                "compile cache at {dir}{}",
                if c.verifying() { " (verifying hits against the oracle)" } else { "" }
            );
            factory = factory.with_cache(c.clone());
            cache = Some(c);
        }

        if let Some(path) = args.opt_str("tuned") {
            // Lenient on the serve path: a corrupt or future-versioned
            // records file logs a warning and serves the default
            // schedule instead of refusing to start.
            if let Some(records) = TuneRecords::load_lenient(&path) {
                records.warn_if_thread_mismatch(threads);
                println!("serving tuned schedule from {path}: {}", records.knob_summary());
                factory = factory.with_schedule(records.overrides(threads), records.fuse);
            }
        } else if let Some(c) = &cache {
            // No explicit records file: merge whatever tune records live
            // in the cache dir (best measured config per task wins) and
            // serve under the merged schedule.
            let runs: Vec<TuneRecords> =
                scan_tune_records(c.dir()).into_iter().map(|(_, r)| r).collect();
            if !runs.is_empty() {
                let merged = tvmq::tune::merge(&runs)?;
                merged.warn_if_thread_mismatch(threads);
                let mpath = c.dir().join(MERGED_RECORDS_FILE);
                if let Err(e) = merged.save(&mpath) {
                    eprintln!("tvmq: warning: could not write {}: {e:#}", mpath.display());
                }
                println!(
                    "serving merged tuned schedule ({} records file(s) in cache dir): {}",
                    runs.len(),
                    merged.knob_summary()
                );
                factory = factory.with_schedule(merged.overrides(threads), merged.fuse);
            }
        }

        // Sampled per-step attribution: every Nth inference on every
        // worker engine records ns-per-step into the shared sink, which
        // the metrics snapshot exports as the "profile" array.
        factory = factory.with_profiling(args.u64("profile-every", 0)?, telem.profile.clone());

        // In-situ tuning and drift-driven re-tuning share the upgrade
        // slot: a background thread tunes the live bucket graphs and
        // publishes strictly-better verified configs; workers hot-swap
        // them at batch boundaries while serving continues.
        let drift_retune = args.flag("drift-retune");
        if args.flag("insitu-tune") || drift_retune {
            let slot = UpgradeSlot::new();
            factory = factory.with_upgrade_slot(slot.clone());
            let opts = TuneOptions {
                budget: args.usize("tune-budget", 8)?,
                seed: args.u64("seed", 1)?,
                threads,
                warmup: 1,
                iters: 3,
                use_prior: true,
            };
            if args.flag("insitu-tune") {
                let handle = spawn_insitu_tuner(
                    Arc::new(factory.clone()),
                    slot.clone(),
                    opts,
                    cache.clone(),
                );
                tuner = Some((handle, slot.clone()));
            }
            if drift_retune {
                retuner = Some(spawn_drift_retuner(
                    Arc::new(factory.clone()),
                    slot,
                    opts,
                    cache.clone(),
                    Arc::clone(&telem),
                    Arc::clone(&stop),
                ));
            }
        }

        let server =
            InferenceServer::start_with_telemetry(factory, cfg, Some(Arc::clone(&telem)))?;
        // NHWC models take channels-last images; NCHW and packed NCHWc
        // models both take plain NCHW (the packed stem is unblocked).
        let rest = if spec.layout == LayoutTag::Nhwc {
            vec![image, image, 3]
        } else {
            vec![3, image, image]
        };
        (server, rest)
    } else {
        let m = tvmq::Manifest::load(artifacts)?;
        let rest = if spec.layout == LayoutTag::Nhwc {
            vec![m.image_size, m.image_size, m.in_channels]
        } else {
            vec![m.in_channels, m.image_size, m.image_size]
        };
        (InferenceServer::start(artifacts.clone(), cfg)?, rest)
    };
    let server = std::sync::Arc::new(server);
    println!(
        "serving {spec} with buckets {:?} across {} worker(s)",
        server.buckets,
        server.workers()
    );

    // Periodic metrics snapshots (tmp+rename, so a reader never sees a
    // torn file); one final snapshot is written after serving finishes.
    let writer: Option<std::thread::JoinHandle<()>> = metrics_json.as_ref().map(|path| {
        let telem = Arc::clone(&telem);
        let cache = cache.clone();
        let path = path.clone();
        let stop = Arc::clone(&stop);
        let every = Duration::from_secs(metrics_every);
        std::thread::spawn(move || {
            loop {
                let stats = cache.as_ref().map(|c| c.stats());
                if let Err(e) = telem.write_snapshot(&path, stats.as_ref()) {
                    eprintln!("tvmq: warning: metrics snapshot: {e:#}");
                }
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let t0 = std::time::Instant::now();
                while t0.elapsed() < every && !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        })
    });

    let t0 = std::time::Instant::now();
    let per_client = (requests / clients).max(1);
    let expected = (per_client * clients) as u64;
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = server.clone();
        let rest = rest.clone();
        handles.push(std::thread::spawn(move || {
            let mut errors = 0u64;
            for i in 0..per_client {
                let img = synthetic_images(1, &rest, (c * 1000 + i) as u64);
                if server.submit_blocking(img).is_err() {
                    errors += 1;
                }
            }
            errors
        }));
    }
    let mut client_errors = 0u64;
    for h in handles {
        client_errors += h.join().unwrap_or(per_client as u64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = stats.latency_stats();
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)  errors={}",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        // Server-side count; every such failure also surfaces as a client
        // Err, so adding client_errors here would double-count.
        stats.errors
    );
    match &lat.stats {
        Some(s) => println!(
            "latency ms: p50={:.2} p95={:.2} p99={:.2} p999={:.2} \
             ({} sample(s){})  mean batch={:.1}  batches={} padded={} shed={}",
            s.p50_ms, s.p95_ms, s.p99_ms, s.p999_ms,
            lat.samples_seen,
            if lat.sampled { ", reservoir-sampled" } else { "" },
            stats.mean_batch(), stats.batches, stats.padded_slots, stats.shed
        ),
        None => println!(
            "latency ms: no settled requests  mean batch={:.1}  \
             batches={} padded={} shed={}",
            stats.mean_batch(), stats.batches, stats.padded_slots, stats.shed
        ),
    }
    println!(
        "bucket histogram: {:?}  gathered histogram: {:?}",
        stats.batch_histogram, stats.gathered_histogram
    );
    // Serving is done: stop the drift retuner and the metrics writer
    // (the writer emits one final snapshot reflecting the finished run).
    stop.store(true, Ordering::Relaxed);
    if let Some((handle, slot)) = tuner {
        // The tuner owns its own factory clone, so joining here only
        // waits on the search — serving already finished above.
        let _ = handle.join();
        let ups = slot.snapshot();
        println!("in-situ tuner finished: {} upgrade(s) published", ups.len());
        for u in ups {
            println!("  gen {}: {}", u.generation, u.describe);
        }
    }
    if let Some(handle) = retuner {
        let _ = handle.join();
        println!(
            "drift retuner: {} trigger(s), {} re-tune pass(es)",
            telem.registry.counter(CounterId::DriftTriggers),
            telem.registry.counter(CounterId::RetunePasses),
        );
    }
    if let Some(handle) = writer {
        let _ = handle.join();
        if let Some(path) = &metrics_json {
            println!("metrics snapshot -> {}", path.display());
        }
    }
    if let Some(c) = &cache {
        let s = c.stats();
        let path = c.write_stats()?;
        println!(
            "cache: {} hit(s), {} miss(es), {} store(s), {} rejected -> {}",
            s.hits,
            s.misses,
            s.stores,
            s.rejected,
            path.display()
        );
    }
    // Smoke contract (CI relies on this): every request answered, none
    // with an error.
    if stats.requests != expected || stats.errors != 0 || client_errors != 0 {
        bail!(
            "serve smoke failed: {}/{expected} requests ok, {} server errors, \
             {client_errors} client errors",
            stats.requests, stats.errors
        );
    }
    Ok(())
}

/// The graph-IR compile pipeline end to end: build → calibrate → quantize →
/// layout-alter → fold → fuse, printing per-pass statistics.
fn compile_demo(batch: usize, c_block: usize) -> Result<()> {
    use tvmq::executor::factory::ARENA_MODEL_SEED;
    use tvmq::graph::{build_resnet_ir, calibrate_ir, evaluate};
    let g = build_resnet_ir(batch, 32, ARENA_MODEL_SEED)?;
    println!("built resnet10 IR: {} nodes, {} const bytes", g.len(), g.const_bytes());

    let calib = calibrate_ir(&g, 42);
    let ref_out = evaluate(&g, &calib)?;

    // Quantize pipeline.
    let scales = calibrate_graph(&g, &calib)?;
    println!("calibrated {} conv/dense scales", scales.len());
    let q = QuantizeRealize { scales }.run(&g)?;
    println!("quantize_realize: {} -> {} nodes", g.len(), q.len());
    let q_out = evaluate(&q, &calib)?;
    let (r, qv) = (ref_out.as_f32()?, q_out.as_f32()?);
    let num: f64 = r.iter().zip(&qv).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
    let den: f64 = r.iter().map(|a| (*a as f64).powi(2)).sum();
    println!("int8 IR sqnr: {:.1} dB", 10.0 * (den / num.max(1e-30)).log10());

    // Layout pipeline on the fp32 graph.
    let pm = PassManager::new()
        .add(AlterConvLayout { c_block, k_block: c_block })
        .add(CancelLayoutTransforms)
        .add(ConstantFold);
    let packed = pm.run(&g)?;
    println!("layout pipeline: {} -> {} nodes (c_block={c_block})", g.len(), packed.len());
    let p_out = evaluate(&packed, &calib)?.as_f32()?;
    let max_err = r.iter().zip(&p_out).fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("packed-vs-NCHW max |err|: {max_err:.2e}");

    // Fusion statistics.
    let plan = FusionPass { enabled: true }.plan(&g)?;
    let nofuse = FusionPass { enabled: false }.plan(&g)?;
    println!(
        "fusion: {} groups fused vs {} unfused ({} compute nodes)",
        plan.group_count(),
        nofuse.group_count(),
        g.nodes
            .iter()
            .filter(|n| !matches!(n.op, tvmq::graph::Op::Input | tvmq::graph::Op::Constant(_)))
            .count()
    );
    Ok(())
}
