//! Memory planning: the resource-management half of the executor contrast.
//!
//! TVM's graph executor runs a **static memory planner** at build time:
//! liveness analysis over the (topologically ordered) graph, then first-fit
//! placement into a shared arena so non-overlapping intermediates reuse the
//! same storage.  The relay VM instead allocates storage dynamically per
//! instruction.  Both are implemented here; the planner also powers the
//! Table 3 memory accounting and the `memplan` ablation bench.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::manifest::ModuleSpec;

/// Round `n` up to the next multiple of `align` (`align` must be nonzero).
pub fn round_up(n: usize, align: usize) -> usize {
    (n + align - 1) / align * align
}

/// One value to place: alive from `def_step` through `last_use_step`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValueLife {
    pub name: String,
    pub bytes: usize,
    pub def_step: usize,
    pub last_use_step: usize,
}

impl ValueLife {
    /// Extend this value's liveness through `step` (no-op if it already
    /// reaches that far).  The graph compiler calls this for every step
    /// source — crucially including the residual operand of a two-input
    /// epilogue step, which is read elementwise while the step's
    /// destination is written and therefore must overlap the destination's
    /// lifetime so the planner keeps the two space-disjoint.
    pub fn extend_through(&mut self, step: usize) {
        self.last_use_step = self.last_use_step.max(step);
    }
}

/// A placed value: offset into the arena.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub name: String,
    pub offset: usize,
    pub bytes: usize,
    pub def_step: usize,
    pub last_use_step: usize,
}

/// A static memory plan: arena size + per-value offsets.
#[derive(Debug, Clone, Default)]
pub struct StaticPlan {
    pub placements: Vec<Placement>,
    pub arena_bytes: usize,
    /// What the same values would cost without reuse (the VM's way).
    pub unshared_bytes: usize,
}

impl StaticPlan {
    /// Plan a module DAG: value i (module i's output) is live from its
    /// definition until its last consumer (or the end, for the result).
    pub fn for_chain(modules: &[ModuleSpec]) -> StaticPlan {
        let n = modules.len();
        let mut last_use: Vec<usize> = (0..n).map(|i| i + 1).collect();
        for (i, m) in modules.iter().enumerate() {
            for &a in &m.args {
                if a > 0 {
                    last_use[a - 1] = last_use[a - 1].max(i);
                }
            }
        }
        if n > 0 {
            last_use[n - 1] = n; // the returned value survives to the end
        }
        let lives: Vec<ValueLife> = modules
            .iter()
            .enumerate()
            .map(|(i, m)| ValueLife {
                name: m.name.clone(),
                bytes: m.output.byte_len(),
                def_step: i,
                last_use_step: last_use[i],
            })
            .collect();
        Self::first_fit(&lives)
    }

    /// First-fit arena placement with liveness-based reuse — TVM's
    /// `GraphPlanMemory`, distilled.
    ///
    /// Values are placed in def order; a value may share arena space with
    /// any value whose lifetime `[def, last_use]` does not overlap.
    pub fn first_fit(lives: &[ValueLife]) -> StaticPlan {
        let mut placements: Vec<Placement> = Vec::with_capacity(lives.len());
        let mut arena = 0usize;
        let mut order: Vec<&ValueLife> = lives.iter().collect();
        order.sort_by_key(|v| (v.def_step, std::cmp::Reverse(v.bytes)));

        for v in order {
            // Candidate offsets: 0 plus the end of every placed interval.
            let mut candidates: Vec<usize> = std::iter::once(0)
                .chain(placements.iter().map(|p| p.offset + p.bytes))
                .collect();
            candidates.sort_unstable();
            candidates.dedup();
            let off = candidates
                .into_iter()
                .find(|&off| {
                    placements.iter().all(|p| {
                        let space_disjoint = off + v.bytes <= p.offset || off >= p.offset + p.bytes;
                        let time_disjoint =
                            v.last_use_step < p.def_step || p.last_use_step < v.def_step;
                        space_disjoint || time_disjoint
                    })
                })
                .expect("offset past all placements always fits");
            arena = arena.max(off + v.bytes);
            placements.push(Placement {
                name: v.name.clone(),
                offset: off,
                bytes: v.bytes,
                def_step: v.def_step,
                last_use_step: v.last_use_step,
            });
        }
        StaticPlan {
            arena_bytes: arena,
            unshared_bytes: lives.iter().map(|v| v.bytes).sum(),
            placements,
        }
    }

    /// First-fit with every size rounded up to `align` bytes, so all
    /// placements (and therefore every offset candidate, by induction from
    /// offset 0) are `align`-aligned.  This is what the arena executor
    /// plans with: its arena is backed by an 8-byte-aligned allocation and
    /// kernels reinterpret `[u8]` ranges as typed slices, so offsets must
    /// be at least element-aligned; we use a cache-line alignment to keep
    /// parallel writers off each other's lines too.
    pub fn first_fit_aligned(lives: &[ValueLife], align: usize) -> StaticPlan {
        let rounded: Vec<ValueLife> = lives
            .iter()
            .map(|v| ValueLife { bytes: round_up(v.bytes.max(1), align), ..v.clone() })
            .collect();
        let mut plan = Self::first_fit(&rounded);
        // The no-reuse baseline is what a dynamic allocator would request:
        // the exact byte sizes, not the alignment-rounded extents (rounding
        // them too would overstate the reuse factor for small values).
        plan.unshared_bytes = lives.iter().map(|v| v.bytes).sum();
        plan
    }

    /// Offset+size lookup by value name (the compile step resolves node
    /// ids through this after planning).
    pub fn offset_index(&self) -> HashMap<String, (usize, usize)> {
        self.placements
            .iter()
            .map(|p| (p.name.clone(), (p.offset, p.bytes)))
            .collect()
    }

    /// Invariant check: no two *simultaneously live* values overlap in space.
    pub fn verify(&self) -> Result<(), String> {
        for (i, a) in self.placements.iter().enumerate() {
            if a.last_use_step < a.def_step {
                return Err(format!("{}: negative lifetime", a.name));
            }
            for b in &self.placements[i + 1..] {
                let time_overlap =
                    a.def_step <= b.last_use_step && b.def_step <= a.last_use_step;
                let space_overlap =
                    a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                if time_overlap && space_overlap {
                    return Err(format!(
                        "overlap: {} [{}+{}] and {} [{}+{}]",
                        a.name, a.offset, a.bytes, b.name, b.offset, b.bytes
                    ));
                }
            }
        }
        Ok(())
    }

    /// Whether the placements named `a` and `b` occupy disjoint byte
    /// ranges (ignoring lifetimes).  `None` if either name is absent.
    /// Used to assert that a two-input epilogue step's residual operand
    /// cannot alias the step's destination.
    pub fn space_disjoint(&self, a: &str, b: &str) -> Option<bool> {
        let pa = self.placements.iter().find(|p| p.name == a)?;
        let pb = self.placements.iter().find(|p| p.name == b)?;
        Some(pa.offset + pa.bytes <= pb.offset || pb.offset + pb.bytes <= pa.offset)
    }

    /// Reuse ratio achieved by the planner (1.0 = no reuse).
    pub fn reuse_factor(&self) -> f64 {
        if self.arena_bytes == 0 {
            return 1.0;
        }
        self.unshared_bytes as f64 / self.arena_bytes as f64
    }

    // ---- JSON (the compile cache persists plans verbatim) ----

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "placements",
                Json::Arr(
                    self.placements
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("name", Json::str(p.name.clone())),
                                ("offset", Json::num(p.offset as f64)),
                                ("bytes", Json::num(p.bytes as f64)),
                                ("def_step", Json::num(p.def_step as f64)),
                                ("last_use_step", Json::num(p.last_use_step as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("arena_bytes", Json::num(self.arena_bytes as f64)),
            ("unshared_bytes", Json::num(self.unshared_bytes as f64)),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<StaticPlan> {
        let placements = j
            .get("placements")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(Placement {
                    name: p.get("name")?.as_str()?.to_string(),
                    offset: p.get("offset")?.as_usize()?,
                    bytes: p.get("bytes")?.as_usize()?,
                    def_step: p.get("def_step")?.as_usize()?,
                    last_use_step: p.get("last_use_step")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(StaticPlan {
            placements,
            arena_bytes: j.get("arena_bytes")?.as_usize()?,
            unshared_bytes: j.get("unshared_bytes")?.as_usize()?,
        })
    }
}

/// The VM's allocator: no plan, just counted mallocs.
#[derive(Debug, Default)]
pub struct DynamicAllocator {
    allocs: AtomicU64,
    bytes: AtomicU64,
}

impl DynamicAllocator {
    pub fn record_alloc(&self, bytes: usize) {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// (total allocations, total bytes)
    pub fn stats(&self) -> (u64, u64) {
        (self.allocs.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}
