//! The paper's central contrast: **graph executor** vs **VM executor**.
//!
//! TVM's quantization path defaults to the VM (relay virtual machine), which
//! partitions the model into prefix/middle/suffix functions and dispatches
//! them as bytecode instructions with dynamic allocation — making int8
//! *slower* than fp32 (Table 1, 29.19 ms vs 13.29 ms).  Resetting to the
//! graph executor (one static, memory-planned module) recovers the expected
//! speedup (8.27 ms).  Both executors are implemented here over the same
//! AOT artifacts so the contrast is mechanistic, not simulated.
//!
//! A third tier, [`ArenaExec`], executes the in-process graph IR over a
//! statically planned arena with fused q/dq boundaries — the mechanism the
//! graph executor's win is made of, implemented natively (no PJRT
//! artifacts needed) and checked bit-for-bit against the interpreter.

mod arena_exec;
mod graph_exec;
mod pool;
mod vm;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

pub use arena_exec::ArenaExec;
pub use graph_exec::GraphExecutor;
pub use pool::WorkerPool;
pub use vm::{VmExecutor, VmInstr};

use crate::runtime::TensorData;

/// Counters that expose *why* the two executors differ.
#[derive(Debug, Default)]
pub struct ExecCounters {
    /// End-to-end inferences served.
    pub invocations: AtomicU64,
    /// PJRT executable dispatches (1 per inference for graph, N for vm).
    pub dispatches: AtomicU64,
    /// Dynamically allocated intermediate tensors (vm only).
    pub dynamic_allocs: AtomicU64,
    /// Bytes staged host<->device for intermediates (vm host-chaining only).
    pub boundary_bytes: AtomicU64,
    /// Bytecode instructions interpreted (vm only).
    pub instructions: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecSnapshot {
    pub invocations: u64,
    pub dispatches: u64,
    pub dynamic_allocs: u64,
    pub boundary_bytes: u64,
    pub instructions: u64,
}

impl ExecCounters {
    pub fn snapshot(&self) -> ExecSnapshot {
        ExecSnapshot {
            invocations: self.invocations.load(Ordering::Relaxed),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            dynamic_allocs: self.dynamic_allocs.load(Ordering::Relaxed),
            boundary_bytes: self.boundary_bytes.load(Ordering::Relaxed),
            instructions: self.instructions.load(Ordering::Relaxed),
        }
    }
}

/// A model executor: fp32 images in, logits out.
pub trait Executor {
    fn run(&self, input: &TensorData) -> Result<TensorData>;
    fn name(&self) -> &str;
    /// The static batch size this executor was compiled for.
    fn batch(&self) -> usize;
    fn counters(&self) -> ExecSnapshot;
}
