//! The paper's central contrast: **graph executor** vs **VM executor**.
//!
//! TVM's quantization path defaults to the VM (relay virtual machine), which
//! partitions the model into prefix/middle/suffix functions and dispatches
//! them as bytecode instructions with dynamic allocation — making int8
//! *slower* than fp32 (Table 1, 29.19 ms vs 13.29 ms).  Resetting to the
//! graph executor (one static, memory-planned module) recovers the expected
//! speedup (8.27 ms).  Both executors are implemented here over the same
//! AOT artifacts so the contrast is mechanistic, not simulated.
//!
//! A third tier, [`ArenaExec`], executes the in-process graph IR over a
//! statically planned arena with fused q/dq boundaries — the mechanism the
//! graph executor's win is made of, implemented natively (no PJRT
//! artifacts needed) and checked bit-for-bit against the interpreter.
//!
//! Two abstractions make the tiers interchangeable to the serving layer:
//!
//! - [`EngineSpec`] ([`spec`]) — the typed (layout, schedule, precision,
//!   engine) quadruple every lookup is keyed by.  No free-form strings
//!   cross the executor/coordinator boundary.
//! - [`EngineFactory`] ([`factory`]) — "give me the bucket sizes, then
//!   build me one engine per bucket".  [`ArtifactFactory`] wraps the AOT
//!   manifest + PJRT path; [`NativeArenaFactory`] compiles [`ArenaExec`]
//!   engines straight from the graph IR, so the coordinator serves real
//!   traffic on the offline build with no artifacts at all.
//!
//! Serving goes through [`Executor::run_into`]: the caller owns the
//! batched input/output tensors (the coordinator pre-allocates one pair
//! per bucket at startup), and `ArenaExec` overrides the default with its
//! zero-heap-allocation path.

mod arena_exec;
pub mod factory;
mod graph_exec;
pub mod microkernel;
// Crate-visible (not `pub`): `crate::check` runs the pool's generic epoch
// protocol under its model scheduler, but the SyncOps surface stays out of
// the public API.
pub(crate) mod pool;
pub mod spec;
mod vm;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

pub use arena_exec::ArenaExec;
pub use factory::{ArtifactFactory, EngineFactory, NativeArenaFactory};
pub use graph_exec::GraphExecutor;
pub use microkernel::{Isa, PACK_FORMAT_VERSION};
pub use pool::{Banding, WorkerPool};
pub use spec::{EngineKind, EngineSpec, LayoutTag, Precision, Schedule};
pub use vm::{VmExecutor, VmInstr};

use crate::runtime::{DType, TensorData};

/// Counters that expose *why* the two executors differ.
#[derive(Debug, Default)]
pub struct ExecCounters {
    /// End-to-end inferences served.
    pub invocations: AtomicU64,
    /// PJRT executable dispatches (1 per inference for graph, N for vm).
    pub dispatches: AtomicU64,
    /// Dynamically allocated intermediate tensors (vm only).
    pub dynamic_allocs: AtomicU64,
    /// Bytes staged host<->device for intermediates (vm host-chaining only).
    pub boundary_bytes: AtomicU64,
    /// Bytecode instructions interpreted (vm only).
    pub instructions: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecSnapshot {
    pub invocations: u64,
    pub dispatches: u64,
    pub dynamic_allocs: u64,
    pub boundary_bytes: u64,
    pub instructions: u64,
}

impl ExecCounters {
    pub fn snapshot(&self) -> ExecSnapshot {
        ExecSnapshot {
            invocations: self.invocations.load(Ordering::Relaxed),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            dynamic_allocs: self.dynamic_allocs.load(Ordering::Relaxed),
            boundary_bytes: self.boundary_bytes.load(Ordering::Relaxed),
            instructions: self.instructions.load(Ordering::Relaxed),
        }
    }
}

/// A model executor: fp32 images in, logits out.
pub trait Executor {
    fn run(&self, input: &TensorData) -> Result<TensorData>;

    /// Execute into a caller-provided output tensor — the batched serving
    /// entry point.  The default allocates via [`Executor::run`] and
    /// copies; engines with a true in-place path (ArenaExec) override it,
    /// which is what makes arena-bucket serving allocation-free in the
    /// executor.
    fn run_into(&self, input: &TensorData, out: &mut TensorData) -> Result<()> {
        let r = self.run(input)?;
        if r.shape != out.shape || r.dtype != out.dtype {
            return Err(anyhow!(
                "{}: output buffer {:?}/{:?} != produced {:?}/{:?}",
                self.name(), out.shape, out.dtype, r.shape, r.dtype
            ));
        }
        out.data.copy_from_slice(&r.data);
        Ok(())
    }

    fn name(&self) -> &str;
    /// The static batch size this executor was compiled for.
    fn batch(&self) -> usize;
    /// Shape/dtype of the (batched) input tensor this engine accepts —
    /// what the coordinator pre-allocates its stacked input from.
    fn input_desc(&self) -> (Vec<usize>, DType);
    /// Shape/dtype of the output tensor this engine produces.
    fn output_desc(&self) -> (Vec<usize>, DType);
    fn counters(&self) -> ExecSnapshot;
}
