//! Pluggable engine factories: how the coordinator obtains one executor
//! per batch bucket without knowing where engines come from.
//!
//! XLA modules and arena plans are both static-shaped, so vLLM-style
//! bucket batching needs one compiled engine per batch size.  A factory
//! answers exactly the two questions the batcher has: *which bucket sizes
//! exist* ([`EngineFactory::buckets`]) and *build me the engine for one of
//! them* ([`EngineFactory::build`]).
//!
//! Two implementations:
//!
//! - [`ArtifactFactory`] — the AOT path: looks bundles up in the artifact
//!   [`Manifest`] by [`EngineSpec`] and constructs [`GraphExecutor`] /
//!   [`VmExecutor`] over PJRT.  Requires `make artifacts` + the real xla
//!   bridge.
//! - [`NativeArenaFactory`] — the offline path: builds ONE ResNet-style
//!   template graph in the spec's layout (NCHW, NHWC, or packed NCHW{c}),
//!   runs the quantize pipeline on it once, and compiles an [`ArenaExec`]
//!   engine per bucket by re-batching the template — every bucket shares
//!   the same `Arc`'d weight constants.  No artifacts, no PJRT — this is
//!   what makes `tvmq serve` fully functional on the stub build.
//!
//! Factories are moved onto the coordinator's worker thread and `build`
//! runs there (PJRT handles are `!Send`, so engines must be born on the
//! thread that drives them).

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{
    ArenaExec, EngineKind, EngineSpec, Executor, GraphExecutor, LayoutTag, Precision,
    VmExecutor,
};
use crate::cache::{CacheKey, CompileCache};
use crate::coordinator::insitu::UpgradeSlot;
use crate::graph::compile::ScheduleOverrides;
use crate::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use crate::graph::{build_resnet_ir_in, calibrate_ir, rebatch_graph, Graph, Layout};
use crate::manifest::Manifest;
use crate::runtime::Runtime;

/// Builds one executor per serving bucket.  `build` is always called on
/// the thread that will run the engine (the coordinator worker).
pub trait EngineFactory {
    /// The batch sizes this factory can compile engines for (need not be
    /// sorted or deduplicated; the coordinator normalizes).
    fn buckets(&self) -> Vec<usize>;

    /// Compile the engine for one bucket.  The returned executor's
    /// `batch()` must equal `batch`.
    fn build(&self, batch: usize) -> Result<Box<dyn Executor>>;

    /// Human-readable description of what this factory serves, for
    /// startup errors and logs.
    fn describe(&self) -> String {
        "engine factory".into()
    }

    /// The in-situ upgrade mailbox, if this factory participates in live
    /// engine hot-swap.  Coordinator workers poll the slot's generation
    /// at batch boundaries and rebuild affected bucket engines on their
    /// own thread (see [`crate::coordinator::insitu`]).  Default: none —
    /// factories opt in.
    fn upgrade_slot(&self) -> Option<Arc<UpgradeSlot>> {
        None
    }
}

/// Boxed factories are factories, so callers can assemble decorator
/// stacks (e.g. `crate::check::fault::FaultyFactory` around a native
/// factory) behind `Box<dyn EngineFactory + Send>` and still hand them to
/// `InferenceServer::start_with`.
impl<F: EngineFactory + ?Sized> EngineFactory for Box<F> {
    fn buckets(&self) -> Vec<usize> {
        (**self).buckets()
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        (**self).build(batch)
    }

    fn describe(&self) -> String {
        (**self).describe()
    }

    fn upgrade_slot(&self) -> Option<Arc<UpgradeSlot>> {
        (**self).upgrade_slot()
    }
}

// ---------------------------------------------------------------------------
// Artifact-backed factory
// ---------------------------------------------------------------------------

thread_local! {
    /// One PJRT runtime per engine-building thread: `Rc<Runtime>` is
    /// `!Send`, so a factory that cached it could not be moved onto the
    /// worker thread — the cache lives with the thread instead, and every
    /// bucket built there shares the client and its executable cache.
    static THREAD_RUNTIME: std::cell::RefCell<Option<Rc<Runtime>>> =
        const { std::cell::RefCell::new(None) };
}

fn thread_runtime() -> Result<Rc<Runtime>> {
    THREAD_RUNTIME.with(|cell| {
        let mut cell = cell.borrow_mut();
        if let Some(rt) = cell.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Rc::new(Runtime::new()?);
        *cell = Some(rt.clone());
        Ok(rt)
    })
}

/// The AOT path: engines are built from manifest bundles over PJRT.
pub struct ArtifactFactory {
    manifest: Manifest,
    spec: EngineSpec,
}

impl ArtifactFactory {
    pub fn new(manifest: Manifest, spec: EngineSpec) -> Result<Self> {
        if !spec.engine.needs_artifacts() {
            return Err(anyhow!(
                "{spec}: the {} engine is compiled natively — use NativeArenaFactory",
                spec.engine
            ));
        }
        Ok(Self { manifest, spec })
    }

    pub fn spec(&self) -> EngineSpec {
        self.spec
    }
}

impl EngineFactory for ArtifactFactory {
    fn buckets(&self) -> Vec<usize> {
        self.manifest.batch_buckets(self.spec)
    }

    fn describe(&self) -> String {
        format!("{} (artifact bundles)", self.spec)
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        let bundle = self.manifest.find(self.spec, batch)?;
        let rt = thread_runtime()?;
        Ok(match self.spec.engine {
            EngineKind::Graph => Box::new(GraphExecutor::new(rt, &self.manifest, bundle)?),
            EngineKind::Vm => Box::new(VmExecutor::new(rt, &self.manifest, bundle)?),
            EngineKind::Arena => unreachable!("rejected in ArtifactFactory::new"),
        })
    }
}

// ---------------------------------------------------------------------------
// Native arena factory
// ---------------------------------------------------------------------------

/// Model seed shared with `tvmq run --executor arena`, so served logits
/// match the CLI's single-shot path.
pub const ARENA_MODEL_SEED: u64 = 7;

/// Channel/filter block of the packed models the native factory builds
/// for [`LayoutTag::Nchwc`]: divides every residual-stage width of the
/// resnet builder (16/32/64/128) and sits well inside the fused packed
/// kernel's stack-resident accumulator bound
/// ([`crate::graph::compile::MAX_FUSED_QCONV_CB`]).
pub const ARENA_PACK_BLOCK: usize = 8;

/// The graph-IR layout a typed layout tag selects for natively built
/// models ([`LayoutTag::Nchwc`] carries no block width — the engine picks
/// [`ARENA_PACK_BLOCK`]).
pub fn ir_layout(tag: LayoutTag) -> Layout {
    match tag {
        LayoutTag::Nchw => Layout::Nchw,
        LayoutTag::Nhwc => Layout::Nhwc,
        LayoutTag::Nchwc => Layout::Nchwc(ARENA_PACK_BLOCK),
    }
}

/// The offline path: one [`ArenaExec`] per bucket, compiled from the
/// in-process ResNet-style IR in the spec's layout (all three layouts,
/// fp32 and int8).
///
/// The model is built — and for int8, calibrated and quantize-realized —
/// **once**, at batch 1; every bucket engine is then
/// [`rebatch_graph`]-derived from that single template, so all buckets
/// share one `Arc`'d weight set (no per-bucket weight rebuild or
/// re-quantization; wide `--buckets` lists cost one model's worth of
/// constants).  Because every kernel is per-sample-independent, a
/// request's logits are bit-identical no matter which bucket served it
/// (the serving differential test pins this).
#[derive(Clone)]
pub struct NativeArenaFactory {
    buckets: Vec<usize>,
    image: usize,
    precision: Precision,
    layout: LayoutTag,
    threads: usize,
    fuse: bool,
    /// Tuned schedule overrides (`Schedule::Tuned` path) applied to every
    /// bucket engine; `None` = the default hard-coded schedule.
    overrides: Option<ScheduleOverrides>,
    /// Batch-1 template (quantize-realized for int8); buckets re-batch it.
    template: Graph,
    /// Content-addressed compile cache (`serve --cache-dir`): hits skip
    /// `graph::compile` entirely via [`ArenaExec::from_compiled`]; cold
    /// builds are stored for the next run.  `None` = always compile.
    cache: Option<Arc<CompileCache>>,
    /// In-situ hot-swap mailbox handed to coordinator workers via
    /// [`EngineFactory::upgrade_slot`].
    upgrade_slot: Option<Arc<UpgradeSlot>>,
    /// Per-step profiling: (sampling period, shared attribution sink).
    /// Attached to every built engine; `None` = profiling off.
    profiling: Option<(u64, Arc<crate::telem::ProfileSink>)>,
}

impl NativeArenaFactory {
    /// `spec` must name the arena engine; every layout tag builds natively
    /// (`NCHWc` packs with [`ARENA_PACK_BLOCK`]).  `image` is the square
    /// input size; `threads` the per-engine worker-pool width.
    pub fn new(
        spec: EngineSpec,
        buckets: &[usize],
        image: usize,
        threads: usize,
    ) -> Result<Self> {
        if spec.engine != EngineKind::Arena {
            return Err(anyhow!("{spec}: NativeArenaFactory builds arena engines only"));
        }
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() || buckets[0] == 0 {
            return Err(anyhow!("arena factory needs a non-empty set of non-zero buckets"));
        }
        let g1 = build_resnet_ir_in(1, image, ARENA_MODEL_SEED, ir_layout(spec.layout))?;
        let template = match spec.precision {
            Precision::Fp32 => g1,
            Precision::Int8 => {
                let calib = calibrate_ir(&g1, 1);
                let scales = calibrate_graph(&g1, &calib)?;
                QuantizeRealize { scales }.run(&g1)?
            }
        };
        Ok(Self {
            buckets,
            image,
            precision: spec.precision,
            layout: spec.layout,
            threads: threads.max(1),
            fuse: true,
            overrides: None,
            template,
            cache: None,
            upgrade_slot: None,
            profiling: None,
        })
    }

    /// Disable epilogue fusion (the ablation configuration).
    pub fn unfused(mut self) -> Self {
        self.fuse = false;
        self
    }

    /// Serve every bucket under tuned schedule overrides — the
    /// [`crate::executor::Schedule::Tuned`] path.  Callers typically
    /// derive both arguments from a persisted records file:
    /// `factory.with_schedule(records.overrides(threads), records.fuse)`.
    pub fn with_schedule(mut self, overrides: ScheduleOverrides, fuse: bool) -> Self {
        self.overrides = Some(overrides);
        self.fuse = fuse;
        self
    }

    /// Attach a content-addressed compile cache: `build` consults it
    /// before compiling and stores what it compiles.  A hit constructs
    /// the engine with **zero** `graph::compile` calls
    /// (`tests/warm_start.rs` counter-asserts this).
    pub fn with_cache(mut self, cache: Arc<CompileCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach an in-situ upgrade mailbox; coordinator workers will poll
    /// it at batch boundaries and hot-swap published engines.
    pub fn with_upgrade_slot(mut self, slot: Arc<UpgradeSlot>) -> Self {
        self.upgrade_slot = Some(slot);
        self
    }

    /// Enable sampled per-step profiling on every engine this factory
    /// builds: each built [`ArenaExec`] times every `every`-th inference
    /// step-by-step into the shared `sink` (see
    /// [`ArenaExec::set_profiling`]).  `every == 0` leaves profiling off.
    pub fn with_profiling(mut self, every: u64, sink: Arc<crate::telem::ProfileSink>) -> Self {
        self.profiling = if every == 0 { None } else { Some((every, sink)) };
        self
    }

    /// Per-engine worker-pool width (also the cache-key thread component).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The schedule overrides `build` actually compiles under — the tuned
    /// set when one was attached, otherwise the defaults — with `threads`
    /// pinned to this factory's pool width.  Exposed so cache keys and
    /// in-situ tuners derive from the identical configuration.
    pub fn effective_overrides(&self) -> ScheduleOverrides {
        let mut ovr = self.overrides.clone().unwrap_or_default();
        ovr.threads = self.threads;
        ovr
    }

    pub fn fuse(&self) -> bool {
        self.fuse
    }

    /// The exact graph the bucket engine for `batch` compiles — exposed so
    /// differential tests can evaluate the same model through the
    /// interpreter oracle.  Constants are shared with the template (and
    /// therefore with every other bucket) by `Arc`.
    pub fn graph(&self, batch: usize) -> Result<Graph> {
        rebatch_graph(&self.template, batch)
    }

    pub fn image(&self) -> usize {
        self.image
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn layout(&self) -> LayoutTag {
        self.layout
    }
}

impl EngineFactory for NativeArenaFactory {
    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn describe(&self) -> String {
        format!(
            "native arena engines ({}, {}, image {}, {} thread(s){})",
            self.layout,
            self.precision,
            self.image,
            self.threads,
            if self.overrides.is_some() { ", tuned schedule" } else { "" }
        )
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        let g = self.graph(batch)?;
        let mut exec = match &self.cache {
            None => match &self.overrides {
                Some(ovr) => ArenaExec::with_schedule(&g, self.fuse, self.threads, ovr)?,
                None => ArenaExec::with_options(&g, self.fuse, self.threads)?,
            },
            Some(cache) => {
                // Warm-start path: key the exact (graph, schedule,
                // threads) configuration this build would compile, and
                // skip the compiler entirely on a verified hit.
                let ovr = self.effective_overrides();
                let key = CacheKey::of(&g, &ovr, self.fuse, self.threads);
                match cache.load(&key, &g) {
                    Some(cg) => {
                        println!(
                            "tvmq: cache hit: bucket {batch} ({}) — compile skipped",
                            key.file_stem()
                        );
                        ArenaExec::from_compiled(cg, self.threads)?
                    }
                    None => {
                        println!(
                            "tvmq: cache miss: bucket {batch} ({}) — compiling",
                            key.file_stem()
                        );
                        let exec = ArenaExec::with_schedule(&g, self.fuse, self.threads, &ovr)?;
                        if let Err(e) = cache.store(&key, exec.compiled()) {
                            eprintln!(
                                "tvmq: cache: failed to store bucket {batch} entry: {e:#}"
                            );
                        }
                        exec
                    }
                }
            }
        };
        if let Some((every, sink)) = &self.profiling {
            exec.set_profiling(*every, sink);
        }
        Ok(Box::new(exec))
    }

    fn upgrade_slot(&self) -> Option<Arc<UpgradeSlot>> {
        self.upgrade_slot.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_factory_rejects_non_arena_specs() {
        let spec = EngineSpec::new(EngineKind::Graph);
        assert!(NativeArenaFactory::new(spec, &[1], 16, 1).is_err());
        assert!(
            NativeArenaFactory::new(EngineSpec::new(EngineKind::Arena), &[], 16, 1).is_err()
        );
    }

    #[test]
    fn arena_factory_builds_every_layout() {
        // The layout guard is gone: NHWC and packed NCHW{c} specs build
        // native int8 bucket engines end-to-end.
        for tag in [LayoutTag::Nchw, LayoutTag::Nhwc, LayoutTag::Nchwc] {
            let spec = EngineSpec::new(EngineKind::Arena).layout(tag);
            let f = NativeArenaFactory::new(spec, &[1, 2], 16, 1)
                .unwrap_or_else(|e| panic!("{tag}: factory failed: {e}"));
            for b in f.buckets() {
                let e = f.build(b).unwrap_or_else(|e| panic!("{tag} b{b}: {e}"));
                assert_eq!(e.batch(), b);
            }
        }
    }

    #[test]
    fn artifact_factory_rejects_arena_spec() {
        // An empty manifest is enough to exercise the constructor check.
        let spec = EngineSpec::new(EngineKind::Arena);
        let manifest = Manifest {
            version: 1,
            arch: "resnet10".into(),
            image_size: 32,
            in_channels: 3,
            num_classes: 10,
            param_count: 0,
            scales: Default::default(),
            batches: vec![],
            bundles: vec![],
            root: std::path::PathBuf::new(),
        };
        assert!(ArtifactFactory::new(manifest, spec).is_err());
    }

    #[test]
    fn arena_factory_normalizes_buckets_and_builds_matching_engines() {
        let spec = EngineSpec::new(EngineKind::Arena).precision(Precision::Fp32);
        let f = NativeArenaFactory::new(spec, &[4, 1, 4, 2], 16, 1).unwrap();
        assert_eq!(f.buckets(), vec![1, 2, 4]);
        for b in f.buckets() {
            let e = f.build(b).unwrap();
            assert_eq!(e.batch(), b);
            let (shape, _) = e.input_desc();
            assert_eq!(shape[0], b);
        }
    }

    #[test]
    fn buckets_share_one_arc_backed_weight_set() {
        use crate::graph::ir::{ConstValue, Op};

        let spec = EngineSpec::new(EngineKind::Arena);
        let f = NativeArenaFactory::new(spec, &[1, 4], 16, 1).unwrap();
        let (g1, g4) = (f.graph(1).unwrap(), f.graph(4).unwrap());
        // Re-batching preserves node ids (scale maps and diagnostics
        // transfer) …
        assert_eq!(g1.len(), g4.len());
        // … and every constant payload is the SAME allocation in both
        // bucket graphs — weights are Arc-shared, not rebuilt per bucket.
        let payload_ptrs = |g: &crate::graph::Graph| -> Vec<usize> {
            g.nodes
                .iter()
                .filter_map(|n| match &n.op {
                    Op::Constant(ConstValue::F32(v)) => Some(v.as_ptr() as usize),
                    Op::Constant(ConstValue::I8(v)) => Some(v.as_ptr() as usize),
                    _ => None,
                })
                .collect()
        };
        let (p1, p4) = (payload_ptrs(&g1), payload_ptrs(&g4));
        assert!(!p1.is_empty(), "quantized resnet must carry constants");
        assert_eq!(p1, p4, "bucket graphs must share one Arc'd constant pool");
    }
}
