//! Pluggable engine factories: how the coordinator obtains one executor
//! per batch bucket without knowing where engines come from.
//!
//! XLA modules and arena plans are both static-shaped, so vLLM-style
//! bucket batching needs one compiled engine per batch size.  A factory
//! answers exactly the two questions the batcher has: *which bucket sizes
//! exist* ([`EngineFactory::buckets`]) and *build me the engine for one of
//! them* ([`EngineFactory::build`]).
//!
//! Two implementations:
//!
//! - [`ArtifactFactory`] — the AOT path: looks bundles up in the artifact
//!   [`Manifest`] by [`EngineSpec`] and constructs [`GraphExecutor`] /
//!   [`VmExecutor`] over PJRT.  Requires `make artifacts` + the real xla
//!   bridge.
//! - [`NativeArenaFactory`] — the offline path: builds the ResNet-style
//!   graph IR *per bucket batch size*, runs the quantize pipeline with
//!   **shared calibration scales**, and compiles [`ArenaExec`] engines.
//!   No artifacts, no PJRT — this is what makes `tvmq serve` fully
//!   functional on the stub build.
//!
//! Factories are moved onto the coordinator's worker thread and `build`
//! runs there (PJRT handles are `!Send`, so engines must be born on the
//! thread that drives them).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::{
    ArenaExec, EngineKind, EngineSpec, Executor, GraphExecutor, LayoutTag, Precision,
    VmExecutor,
};
use crate::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use crate::graph::{build_resnet_ir, calibrate_ir, Graph, NodeId};
use crate::manifest::Manifest;
use crate::runtime::Runtime;

/// Builds one executor per serving bucket.  `build` is always called on
/// the thread that will run the engine (the coordinator worker).
pub trait EngineFactory {
    /// The batch sizes this factory can compile engines for (need not be
    /// sorted or deduplicated; the coordinator normalizes).
    fn buckets(&self) -> Vec<usize>;

    /// Compile the engine for one bucket.  The returned executor's
    /// `batch()` must equal `batch`.
    fn build(&self, batch: usize) -> Result<Box<dyn Executor>>;

    /// Human-readable description of what this factory serves, for
    /// startup errors and logs.
    fn describe(&self) -> String {
        "engine factory".into()
    }
}

// ---------------------------------------------------------------------------
// Artifact-backed factory
// ---------------------------------------------------------------------------

thread_local! {
    /// One PJRT runtime per engine-building thread: `Rc<Runtime>` is
    /// `!Send`, so a factory that cached it could not be moved onto the
    /// worker thread — the cache lives with the thread instead, and every
    /// bucket built there shares the client and its executable cache.
    static THREAD_RUNTIME: std::cell::RefCell<Option<Rc<Runtime>>> =
        const { std::cell::RefCell::new(None) };
}

fn thread_runtime() -> Result<Rc<Runtime>> {
    THREAD_RUNTIME.with(|cell| {
        let mut cell = cell.borrow_mut();
        if let Some(rt) = cell.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Rc::new(Runtime::new()?);
        *cell = Some(rt.clone());
        Ok(rt)
    })
}

/// The AOT path: engines are built from manifest bundles over PJRT.
pub struct ArtifactFactory {
    manifest: Manifest,
    spec: EngineSpec,
}

impl ArtifactFactory {
    pub fn new(manifest: Manifest, spec: EngineSpec) -> Result<Self> {
        if !spec.engine.needs_artifacts() {
            return Err(anyhow!(
                "{spec}: the {} engine is compiled natively — use NativeArenaFactory",
                spec.engine
            ));
        }
        Ok(Self { manifest, spec })
    }

    pub fn spec(&self) -> EngineSpec {
        self.spec
    }
}

impl EngineFactory for ArtifactFactory {
    fn buckets(&self) -> Vec<usize> {
        self.manifest.batch_buckets(self.spec)
    }

    fn describe(&self) -> String {
        format!("{} (artifact bundles)", self.spec)
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        let bundle = self.manifest.find(self.spec, batch)?;
        let rt = thread_runtime()?;
        Ok(match self.spec.engine {
            EngineKind::Graph => Box::new(GraphExecutor::new(rt, &self.manifest, bundle)?),
            EngineKind::Vm => Box::new(VmExecutor::new(rt, &self.manifest, bundle)?),
            EngineKind::Arena => unreachable!("rejected in ArtifactFactory::new"),
        })
    }
}

// ---------------------------------------------------------------------------
// Native arena factory
// ---------------------------------------------------------------------------

/// Model seed shared with `tvmq run --executor arena`, so served logits
/// match the CLI's single-shot path.
pub const ARENA_MODEL_SEED: u64 = 7;

/// The offline path: one [`ArenaExec`] per bucket, compiled from the
/// in-process ResNet-style IR.
///
/// For int8, calibration runs **once** on the batch-1 graph and the
/// resulting scales are reused for every bucket.  The builder lays nodes
/// out in a batch-independent order, so the node-id-keyed scale map
/// transfers across batch sizes — and because every kernel is
/// per-sample-independent, a request's logits are bit-identical no matter
/// which bucket served it (the serving differential test pins this).
pub struct NativeArenaFactory {
    buckets: Vec<usize>,
    image: usize,
    precision: Precision,
    threads: usize,
    fuse: bool,
    /// Shared calibration scales (int8 only).
    scales: Option<HashMap<NodeId, f32>>,
}

impl NativeArenaFactory {
    /// `spec` must name the arena engine in NCHW (the native int8 kernels
    /// are NCHW-only today — see ROADMAP).  `image` is the square input
    /// size; `threads` the per-engine worker-pool width.
    pub fn new(
        spec: EngineSpec,
        buckets: &[usize],
        image: usize,
        threads: usize,
    ) -> Result<Self> {
        if spec.engine != EngineKind::Arena {
            return Err(anyhow!("{spec}: NativeArenaFactory builds arena engines only"));
        }
        if spec.layout != LayoutTag::Nchw {
            return Err(anyhow!(
                "{spec}: the native arena engine builds NCHW models only"
            ));
        }
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() || buckets[0] == 0 {
            return Err(anyhow!("arena factory needs a non-empty set of non-zero buckets"));
        }
        let scales = match spec.precision {
            Precision::Fp32 => None,
            Precision::Int8 => {
                let g1 = build_resnet_ir(1, image, ARENA_MODEL_SEED)?;
                let calib = calibrate_ir(&g1, 1);
                Some(calibrate_graph(&g1, &calib)?)
            }
        };
        Ok(Self {
            buckets,
            image,
            precision: spec.precision,
            threads: threads.max(1),
            fuse: true,
            scales,
        })
    }

    /// Disable epilogue fusion (the ablation configuration).
    pub fn unfused(mut self) -> Self {
        self.fuse = false;
        self
    }

    /// The exact graph the bucket engine for `batch` compiles — exposed so
    /// differential tests can evaluate the same model through the
    /// interpreter oracle.
    pub fn graph(&self, batch: usize) -> Result<Graph> {
        let g = build_resnet_ir(batch, self.image, ARENA_MODEL_SEED)?;
        match &self.scales {
            None => Ok(g),
            Some(scales) => QuantizeRealize { scales: scales.clone() }.run(&g),
        }
    }

    pub fn image(&self) -> usize {
        self.image
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }
}

impl EngineFactory for NativeArenaFactory {
    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn describe(&self) -> String {
        format!(
            "native arena engines ({}, image {}, {} thread(s))",
            self.precision, self.image, self.threads
        )
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        let g = self.graph(batch)?;
        Ok(Box::new(ArenaExec::with_options(&g, self.fuse, self.threads)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_factory_rejects_non_arena_specs() {
        let spec = EngineSpec::new(EngineKind::Graph);
        assert!(NativeArenaFactory::new(spec, &[1], 16, 1).is_err());
        let nhwc = EngineSpec::new(EngineKind::Arena).layout(LayoutTag::Nhwc);
        assert!(NativeArenaFactory::new(nhwc, &[1], 16, 1).is_err());
        assert!(
            NativeArenaFactory::new(EngineSpec::new(EngineKind::Arena), &[], 16, 1).is_err()
        );
    }

    #[test]
    fn artifact_factory_rejects_arena_spec() {
        // An empty manifest is enough to exercise the constructor check.
        let spec = EngineSpec::new(EngineKind::Arena);
        let manifest = Manifest {
            version: 1,
            arch: "resnet10".into(),
            image_size: 32,
            in_channels: 3,
            num_classes: 10,
            param_count: 0,
            scales: Default::default(),
            batches: vec![],
            bundles: vec![],
            root: std::path::PathBuf::new(),
        };
        assert!(ArtifactFactory::new(manifest, spec).is_err());
    }

    #[test]
    fn arena_factory_normalizes_buckets_and_builds_matching_engines() {
        let spec = EngineSpec::new(EngineKind::Arena).precision(Precision::Fp32);
        let f = NativeArenaFactory::new(spec, &[4, 1, 4, 2], 16, 1).unwrap();
        assert_eq!(f.buckets(), vec![1, 2, 4]);
        for b in f.buckets() {
            let e = f.build(b).unwrap();
            assert_eq!(e.batch(), b);
            let (shape, _) = e.input_desc();
            assert_eq!(shape[0], b);
        }
    }

    #[test]
    fn int8_scales_are_shared_across_buckets() {
        let spec = EngineSpec::new(EngineKind::Arena);
        let f = NativeArenaFactory::new(spec, &[1, 4], 16, 1).unwrap();
        // Same node count (builder order is batch-independent) and the
        // factory quantizes both buckets from one scale map.
        assert_eq!(f.graph(1).unwrap().len(), f.graph(4).unwrap().len());
        assert!(f.scales.is_some());
    }
}
