//! VM executor: the paper's bug (Table 1, `TVM-Quant`).
//!
//! "The VM Executor is a lower-level executor that allows dynamic
//! operations, enabling runtime code generation" (§3.1) — and it is what
//! TVM's quantization path selects by default, partitioning the model into
//! prefix (quantize inputs) / middle (quantized core) / suffix (dequantize
//! outputs) functions.
//!
//! This is a faithful relay-VM-style implementation: the model arrives as
//! per-primitive modules wired into a value DAG (one module per relay
//! primitive — TVM's `InvokePacked` granularity); they are compiled to a
//! linear **bytecode** program and a fetch-decode-execute loop walks it
//! with a register file, *dynamically allocating* every intermediate and
//! invoking each primitive as a separate packed call.  The costs the graph
//! executor avoids are all here, individually countable:
//!
//! - per-instruction interpretation (`instructions`),
//! - per-primitive executable dispatch (`dispatches`),
//! - per-intermediate allocation (`dynamic_allocs`),
//! - host staging at every boundary (`boundary_bytes`) — TVM packed
//!   functions exchange DLTensors in host memory.
//!
//! `device_chaining` keeps intermediates as PJRT device buffers instead
//! (the §Perf ablation isolating the staging component of the overhead).

use std::rc::Rc;
use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use super::{EngineKind, ExecCounters, ExecSnapshot, Executor};
use crate::manifest::{Bundle, Manifest, TensorSpec};
use crate::memplan::DynamicAllocator;
use crate::runtime::{DType, LoadedModule, Runtime, TensorData};

/// Register index in the VM register file.  Register 0 holds the input;
/// register i+1 holds module i's output.
pub type Reg = usize;

/// The relay-VM-like instruction set (the subset a static DAG needs; the
/// real VM adds control flow for dynamic models — RNNs — which is exactly
/// why TVM routes quantized models through it).
#[derive(Debug, Clone)]
pub enum VmInstr {
    /// Dynamically allocate storage for register `dst` (spec `spec_idx`).
    AllocStorage { dst: Reg, spec_idx: usize },
    /// Invoke compiled primitive `module_idx`: reads `srcs`, writes `dst`.
    InvokePacked { module_idx: usize, srcs: Vec<Reg>, dst: Reg },
    /// Return the contents of `src`.
    Ret { src: Reg },
}

enum Slot {
    Empty,
    Host(TensorData),
    Device(xla::PjRtBuffer, TensorSpec),
}

pub struct VmExecutor {
    rt: Rc<Runtime>,
    modules: Vec<Rc<LoadedModule>>,
    specs: Vec<TensorSpec>,
    program: Vec<VmInstr>,
    num_regs: usize,
    allocator: DynamicAllocator,
    device_chaining: bool,
    name: String,
    batch: usize,
    counters: ExecCounters,
}

impl VmExecutor {
    pub fn new(rt: Rc<Runtime>, manifest: &Manifest, bundle: &Bundle) -> Result<Self> {
        Self::with_options(rt, manifest, bundle, false)
    }

    pub fn with_options(
        rt: Rc<Runtime>,
        manifest: &Manifest,
        bundle: &Bundle,
        device_chaining: bool,
    ) -> Result<Self> {
        if bundle.executor != EngineKind::Vm {
            return Err(anyhow!(
                "bundle {:?} is a {} bundle, not vm",
                bundle.id, bundle.executor
            ));
        }
        let mut modules = Vec::new();
        let mut specs = Vec::new();
        for m in &bundle.modules {
            modules.push(rt.load_module(&manifest.root, m)?);
            specs.push(m.output.clone());
        }
        let program = Self::compile_bytecode(bundle);
        Ok(Self {
            rt,
            modules,
            specs,
            num_regs: bundle.modules.len() + 1,
            program,
            allocator: DynamicAllocator::default(),
            device_chaining,
            name: format!(
                "{}{}", bundle.id,
                if device_chaining { "+devchain" } else { "" }
            ),
            batch: bundle.batch,
            counters: ExecCounters::default(),
        })
    }

    /// Lower the module DAG to bytecode: reg 0 holds the input; module i
    /// allocates register i+1 then invokes with its wired source registers.
    fn compile_bytecode(bundle: &Bundle) -> Vec<VmInstr> {
        let n = bundle.modules.len();
        let mut prog = Vec::with_capacity(2 * n + 1);
        for (i, m) in bundle.modules.iter().enumerate() {
            prog.push(VmInstr::AllocStorage { dst: i + 1, spec_idx: i });
            prog.push(VmInstr::InvokePacked {
                module_idx: i,
                srcs: m.args.clone(),
                dst: i + 1,
            });
        }
        prog.push(VmInstr::Ret { src: n });
        prog
    }

    pub fn program(&self) -> &[VmInstr] {
        &self.program
    }

    pub fn alloc_stats(&self) -> (u64, u64) {
        self.allocator.stats()
    }

    fn invoke(&self, module_idx: usize, regs: &mut [Slot], srcs: &[Reg], dst: Reg) -> Result<()> {
        let module = &self.modules[module_idx];
        if self.device_chaining {
            // Ablation path: intermediates stay on device.  Host sources
            // (the input register) are staged on first use.
            for &s in srcs {
                if let Slot::Host(t) = &regs[s] {
                    let buf = self.rt.to_device(t)?;
                    let spec = TensorSpec { shape: t.shape.clone(), dtype: t.dtype.tag().into() };
                    regs[s] = Slot::Device(buf, spec);
                }
            }
            let bufs: Vec<&xla::PjRtBuffer> = srcs
                .iter()
                .map(|&s| match &regs[s] {
                    Slot::Device(buf, _) => Ok(buf),
                    _ => Err(anyhow!("vm: register {s} not materialized")),
                })
                .collect::<Result<_>>()?;
            let out = self.rt.execute_buffers(module, &bufs)?;
            regs[dst] = Slot::Device(out, module.output.clone());
        } else {
            // Faithful path: DLTensor-style host exchange at every boundary.
            let inputs: Vec<&TensorData> = srcs
                .iter()
                .map(|&s| match &regs[s] {
                    Slot::Host(t) => Ok(t),
                    _ => Err(anyhow!("vm: register {s} not on host")),
                })
                .collect::<Result<_>>()?;
            let moved: usize = inputs.iter().map(|t| t.byte_len()).sum::<usize>()
                + module.output.byte_len();
            self.counters
                .boundary_bytes
                .fetch_add(moved as u64, Ordering::Relaxed);
            let out = self.rt.execute_host(module, &inputs)?;
            regs[dst] = Slot::Host(out);
        }
        Ok(())
    }
}

impl Executor for VmExecutor {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        self.counters.invocations.fetch_add(1, Ordering::Relaxed);
        let mut regs: Vec<Slot> = (0..self.num_regs).map(|_| Slot::Empty).collect();
        regs[0] = Slot::Host(input.clone());

        // Fetch-decode-execute.
        let mut pc = 0usize;
        loop {
            let instr = self
                .program
                .get(pc)
                .ok_or_else(|| anyhow!("vm: pc {pc} out of program"))?;
            self.counters.instructions.fetch_add(1, Ordering::Relaxed);
            match instr {
                VmInstr::AllocStorage { dst, spec_idx } => {
                    // Dynamic allocation: fresh storage every inference, no
                    // reuse across instructions — the graph executor's
                    // static plan is exactly what this lacks.
                    let spec = &self.specs[*spec_idx];
                    self.allocator.record_alloc(spec.byte_len());
                    self.counters.dynamic_allocs.fetch_add(1, Ordering::Relaxed);
                    regs[*dst] = Slot::Empty; // storage bound at invoke
                }
                VmInstr::InvokePacked { module_idx, srcs, dst } => {
                    self.counters.dispatches.fetch_add(1, Ordering::Relaxed);
                    self.invoke(*module_idx, &mut regs, srcs, *dst)?;
                }
                VmInstr::Ret { src } => {
                    return match std::mem::replace(&mut regs[*src], Slot::Empty) {
                        Slot::Host(t) => Ok(t),
                        Slot::Device(buf, spec) => self.rt.to_host(&buf, &spec),
                        Slot::Empty => Err(anyhow!("vm: ret of empty register")),
                    };
                }
            }
            pc += 1;
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        let spec = &self.modules[0].inputs[0];
        (spec.shape.clone(), DType::parse(&spec.dtype))
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        let spec = &self.modules[self.modules.len() - 1].output;
        (spec.shape.clone(), DType::parse(&spec.dtype))
    }

    fn counters(&self) -> ExecSnapshot {
        self.counters.snapshot()
    }
}
