//! Register-blocked int8 microkernel primitives: the SIMD dot-product
//! tiles and the ahead-of-time weight pre-packing the arena executor's
//! int8 kernels dispatch into when a step carries a
//! [`MicroKernel`](crate::graph::compile::MicroKernel) schedule knob.
//!
//! # Primitive
//!
//! One primitive does all the arithmetic: [`dot_i8`], an int8×int8 →
//! i32 dot product over two equal-length contiguous spans.  Every layout's
//! microkernel decomposes its reduction into such spans (NHWC: the
//! channel axis per filter tap; NCHW: the `s`-wide filter row where the
//! window is interior; NCHW{c}: the `cb` input lanes per tap; dense: the
//! whole `K` axis), so the same three implementations back every kernel:
//!
//! - **AVX2**: 16 bytes per step — `_mm256_cvtepi8_epi16` sign-extension
//!   into one 256-bit register, then `_mm256_madd_epi16` (the `pmaddwd`
//!   family the paper's tensorized schedules build on) accumulating
//!   pairwise i32 lanes.
//! - **SSE2** (always present on x86_64): the classic
//!   unpack + `_mm_srai_epi16` sign-extension, then `_mm_madd_epi16`.
//!   `pmaddubsw` is deliberately *not* used: it multiplies u8×i8 and
//!   saturates, which is not bit-exact for signed×signed inputs.
//! - **Scalar tile** (always available, the only path off x86_64): the
//!   same reduction chunked by the `ku` knob.
//!
//! Integer addition is associative and commutative, so all three produce
//! identical i32 results for identical spans — the interpreter-oracle
//! differential gate holds for every ISA without a per-ISA tolerance.
//! (i32 accumulation can wrap only where the scalar oracle would wrap
//! too; the domains are identical.)
//!
//! # Feature-dispatch contract
//!
//! [`Isa::detect`] picks the widest ISA the *running* CPU supports
//! (`is_x86_feature_detected!`), clamped by the `TVMQ_MICRO_ISA`
//! environment variable (`avx2` / `sse2` / `scalar`) so CI can exercise
//! the scalar tile on AVX2 hosts.  Detection runs once per executor
//! construction; the chosen [`Isa`] is a plain enum copied into every
//! kernel dispatch (no function pointers, no per-call feature probing,
//! no allocation).  The `unsafe` SIMD entry points are only reachable
//! after the matching feature was detected.
//!
//! # Pre-pack layout
//!
//! [`pack_weight`] rewrites an int8 weight constant into **per-output-lane
//! contiguous panels** so every microkernel span read is unit-stride:
//!
//! | anchor layout | source weight | packed panels |
//! |---|---|---|
//! | NCHW  | `[K][C][R][S]` (OIHW) | identical — OIHW already stores each output channel's `[C][R][S]` taps contiguously |
//! | NHWC  | `[R][S][C][K]` (HWIO) | `[K][R][S][C]`: per output channel, taps in row-major tap order, channel innermost |
//! | NCHW{c} | `[K/b][C/b][R][S][cb][kb]` (OIHW{i}{o}) | `[K/b][C/b][R][S][kb][cb]`: the trailing `[cb][kb]` block transposed so each output lane's `cb` inputs are contiguous |
//! | dense | `[K][N]` | `[N][K]`: one `K`-long panel per output feature |
//!
//! The packed form is a pure permutation of the source payload (same
//! length, no padding — span lengths handle all tails), a deterministic
//! function of `(payload, shape, layout)` alone.  The compile cache
//! therefore never stores packed bytes: a warm start re-derives them from
//! the digest-verified constant pool and cross-checks length + content
//! digest against the entry's metadata ([`PACK_FORMAT_VERSION`] is folded
//! into the cache key, so a layout change here can never resurrect a
//! stale plan).
//!
//! The `mr`/`nr` knobs shape the *loop order* of the kernels in
//! `arena_exec` (output-position and output-lane tiling), not the packed
//! bytes; `ku` shapes the scalar tile's unroll chunk.  All three are
//! searched by `crate::tune` like any other schedule knob — none can
//! change a result bit.

/// Version of the pre-packed weight layout described in the module docs.
/// Folded into the schedule-table digest (`cache::digest`) and checked
/// against every store entry, so changing the panel layout invalidates
/// every cached plan that embedded the old one.
pub const PACK_FORMAT_VERSION: u64 = 1;

use crate::graph::ir::Layout;

/// The instruction set the dot-product tile runs on.  Ordered narrow →
/// wide; `detect` returns the widest supported (and permitted) one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable chunked-scalar tile (the only variant off x86_64).
    Scalar,
    /// `_mm_madd_epi16` over 16-byte steps (baseline x86_64).
    Sse2,
    /// `_mm256_madd_epi16` over 16-byte loads widened to 256-bit lanes.
    Avx2,
}

impl Isa {
    /// Widest ISA the running CPU supports, clamped by `TVMQ_MICRO_ISA`
    /// (`avx2`/`sse2`/`scalar`, case-insensitive; unknown values are
    /// ignored).  Called once per executor construction.
    pub fn detect() -> Isa {
        let cap = Self::hw_widest();
        match std::env::var("TVMQ_MICRO_ISA") {
            Ok(v) => {
                let want = match v.to_ascii_lowercase().as_str() {
                    "scalar" => Isa::Scalar,
                    "sse2" => Isa::Sse2,
                    "avx2" => Isa::Avx2,
                    _ => cap,
                };
                // The env var can only narrow: requesting avx2 on a
                // non-avx2 host stays at the hardware's widest.
                if (want as u8) <= (cap as u8) { want } else { cap }
            }
            Err(_) => cap,
        }
    }

    #[cfg(target_arch = "x86_64")]
    fn hw_widest() -> Isa {
        if std::arch::is_x86_feature_detected!("avx2") {
            Isa::Avx2
        } else {
            // SSE2 is part of the x86_64 baseline.
            Isa::Sse2
        }
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn hw_widest() -> Isa {
        Isa::Scalar
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
        }
    }
}

/// int8×int8 → i32 dot product over two equal-length spans, on the given
/// ISA.  `ku` is the scalar tile's unroll chunk (ignored by the SIMD
/// paths, whose step is their register width).  Allocation-free.
#[inline]
pub fn dot_i8(isa: Isa, ku: usize, a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot_i8_scalar(ku, a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::detect` only yields these variants when the
        // feature was detected on the running CPU.
        Isa::Sse2 => unsafe { x86::dot_i8_sse2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_i8_avx2(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot_i8_scalar(ku, a, b),
    }
}

/// The portable tile: the reduction chunked by `ku` so the compiler can
/// keep a `ku`-wide partial sum in registers.  Bit-identical to the naive
/// loop (integer addition reassociates freely).
fn dot_i8_scalar(ku: usize, a: &[i8], b: &[i8]) -> i32 {
    let ku = ku.max(1);
    let n = a.len();
    let mut sum = 0i32;
    let mut i = 0;
    while i + ku <= n {
        let mut t = 0i32;
        for j in 0..ku {
            t += a[i + j] as i32 * b[i + j] as i32;
        }
        sum += t;
        i += ku;
    }
    while i < n {
        sum += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    sum
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Horizontal sum of the four i32 lanes of `v`.
    #[inline]
    unsafe fn hsum_epi32(v: __m128i) -> i32 {
        // [2,3,0,1] then [1,0,3,2]: after both adds every lane holds the
        // total; extract lane 0.
        let s = _mm_add_epi32(v, _mm_shuffle_epi32::<0x4E>(v));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s));
        _mm_cvtsi128_si32(s)
    }

    /// # Safety
    /// Requires SSE2 (the x86_64 baseline) and `a.len() == b.len()`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_i8_sse2(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let mut acc = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            // Sign-extend i8 → i16: duplicate each byte into a 16-bit
            // slot, then arithmetic-shift the copy down.
            let a_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(va, va));
            let a_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(va, va));
            let b_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(vb, vb));
            let b_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(vb, vb));
            acc = _mm_add_epi32(acc, _mm_madd_epi16(a_lo, b_lo));
            acc = _mm_add_epi32(acc, _mm_madd_epi16(a_hi, b_hi));
            i += 16;
        }
        let mut sum = hsum_epi32(acc);
        while i < n {
            sum += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Requires AVX2 (checked by `Isa::detect`) and `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let wa = _mm256_cvtepi8_epi16(va);
            let wb = _mm256_cvtepi8_epi16(vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
            i += 16;
        }
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256::<1>(acc);
        let mut sum = hsum_epi32(_mm_add_epi32(lo, hi));
        while i < n {
            sum += *a.get_unchecked(i) as i32 * *b.get_unchecked(i) as i32;
            i += 1;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// AOT weight pre-packing (compile-time only; the serving path never packs)
// ---------------------------------------------------------------------------

/// Pack an int8 anchor weight into the per-output-lane panel form the
/// microkernels read (see the module docs' table).  `layout` is the
/// anchor's data layout (`None` for dense); `ws` the source weight shape.
/// A pure permutation: `out.len() == w.len()`, deterministic in
/// `(w, ws, layout)` alone.
pub fn pack_weight(layout: Option<Layout>, w: &[i8], ws: &[usize]) -> Vec<i8> {
    match layout {
        // OIHW already stores each output channel's `[C][R][S]` panel
        // contiguously; the owned copy is the panel form.
        Some(Layout::Nchw) => w.to_vec(),
        Some(Layout::Nhwc) => {
            // [R][S][C][K] → [K][R][S][C]
            let (r, s, c, k) = (ws[0], ws[1], ws[2], ws[3]);
            let mut out = vec![0i8; w.len()];
            for ry in 0..r {
                for sx in 0..s {
                    for ci in 0..c {
                        let src = ((ry * s + sx) * c + ci) * k;
                        for ki in 0..k {
                            out[((ki * r + ry) * s + sx) * c + ci] = w[src + ki];
                        }
                    }
                }
            }
            out
        }
        Some(Layout::Nchwc(_)) => {
            // [K/b][C/b][R][S][cb][kb] → [K/b][C/b][R][S][kb][cb]
            let (ko, co, r, s, cb, kb) = (ws[0], ws[1], ws[2], ws[3], ws[4], ws[5]);
            let mut out = vec![0i8; w.len()];
            let taps = ko * co * r * s;
            for t in 0..taps {
                let base = t * cb * kb;
                for ci in 0..cb {
                    for ki in 0..kb {
                        out[base + ki * cb + ci] = w[base + ci * kb + ki];
                    }
                }
            }
            out
        }
        // Dense [K][N] → [N][K]
        None => {
            let (k, n) = (ws[0], ws[1]);
            let mut out = vec![0i8; w.len()];
            for kk in 0..k {
                for j in 0..n {
                    out[j * k + kk] = w[kk * n + j];
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(a: &[i8], b: &[i8]) -> i32 {
        a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum()
    }

    #[test]
    fn every_isa_and_chunk_matches_the_naive_dot() {
        use crate::util::rng::Rng64;
        let mut rng = Rng64::seed_from_u64(0x5eed_d07);
        // Lengths straddling the 16-byte SIMD step and the scalar chunk
        // boundaries, including the tails.
        for n in [0usize, 1, 3, 7, 15, 16, 17, 31, 32, 33, 64, 100] {
            let a: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
            let want = naive_dot(&a, &b);
            for ku in [1usize, 2, 4, 8, 16] {
                assert_eq!(dot_i8(Isa::Scalar, ku, &a, &b), want, "scalar ku={ku} n={n}");
            }
            #[cfg(target_arch = "x86_64")]
            {
                assert_eq!(dot_i8(Isa::Sse2, 4, &a, &b), want, "sse2 n={n}");
                if std::arch::is_x86_feature_detected!("avx2") {
                    assert_eq!(dot_i8(Isa::Avx2, 4, &a, &b), want, "avx2 n={n}");
                }
            }
        }
    }

    #[test]
    fn packing_is_a_pure_permutation() {
        use crate::util::rng::Rng64;
        let mut rng = Rng64::seed_from_u64(7);
        // NHWC [2][3][5][4]
        let ws = [2usize, 3, 5, 4];
        let w: Vec<i8> = (0..ws.iter().product::<usize>()).map(|_| rng.i8()).collect();
        let p = pack_weight(Some(Layout::Nhwc), &w, &ws);
        assert_eq!(p.len(), w.len());
        let (r, s, c, k) = (ws[0], ws[1], ws[2], ws[3]);
        for ry in 0..r {
            for sx in 0..s {
                for ci in 0..c {
                    for ki in 0..k {
                        assert_eq!(
                            p[((ki * r + ry) * s + sx) * c + ci],
                            w[((ry * s + sx) * c + ci) * k + ki]
                        );
                    }
                }
            }
        }
        // NCHWc [1][2][1][1][4][4]: trailing block transposed.
        let ws = [1usize, 2, 1, 1, 4, 4];
        let w: Vec<i8> = (0..32).map(|_| rng.i8()).collect();
        let p = pack_weight(Some(Layout::Nchwc(4)), &w, &ws);
        for t in 0..2 {
            for ci in 0..4 {
                for ki in 0..4 {
                    assert_eq!(p[t * 16 + ki * 4 + ci], w[t * 16 + ci * 4 + ki]);
                }
            }
        }
        // Dense [3][5] transposes; NCHW is the identity copy.
        let w: Vec<i8> = (0..15).map(|_| rng.i8()).collect();
        let p = pack_weight(None, &w, &[3, 5]);
        for kk in 0..3 {
            for j in 0..5 {
                assert_eq!(p[j * 3 + kk], w[kk * 5 + j]);
            }
        }
        let p = pack_weight(Some(Layout::Nchw), &w, &[5, 3, 1, 1]);
        assert_eq!(p, w);
    }
}
