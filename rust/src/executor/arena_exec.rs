//! Arena executor: the static-plan tier the paper says TVM's graph
//! executor is — fused steps over one pre-planned arena, **zero heap
//! allocation per inference**.
//!
//! Where [`super::GraphExecutor`] and [`super::VmExecutor`] run AOT HLO
//! artifacts over PJRT, `ArenaExec` compiles the in-process graph IR
//! directly ([`crate::graph::compile`]): one upfront arena allocation at
//! build time, then every step writes through pre-placed `&mut` windows.
//! [`crate::graph::interp::evaluate`] is the semantic oracle — the
//! differential tests require bit-for-bit equality, which pins every
//! kernel here to the interpreter's per-output-element operation order
//! (f32 reduction order is observable; parallelism and blocking are only
//! applied across independent output elements, and to integer
//! accumulation, which is order-exact).  The purely elementwise/pooling
//! kernels are not even duplicated: both tiers call the shared cores in
//! [`crate::graph::kernels`].
//!
//! Parallelism: conv/dense kernels split output rows across a
//! **persistent worker pool** ([`super::WorkerPool`]) owned by the
//! executor — workers are spawned once at build time and each kernel
//! dispatch hands them disjoint row bands through a lock-protected slot,
//! so serving an inference allocates nothing at *any* thread count (the
//! allocation-counting test locks this down for `threads == 1` and
//! `threads == 4`).  With `threads == 1` no pool exists and everything
//! runs inline.  Bands default to contiguous row ranges where rows cost
//! the same (NCHW/NCHW{c}: one row = one output plane) and interleaved
//! residue classes where they don't ([`Banding::Interleaved`], NHWC: one
//! row = one spatial line, ragged at padded borders) — but the banding
//! mode, the dynamic-dequeue chunk, and the band cap are **schedule
//! knobs**: each step carries a resolved
//! [`StepSched`](crate::graph::compile::StepSched) (from
//! [`ArenaExec::with_schedule`]'s overrides, typically found by the
//! `crate::tune` autotuner), and [`Banding::Dynamic`] turns the fan-out
//! into a chunked work-stealing dequeue for pathological row costs.
//! Every mode assigns each row to exactly one band, so the schedule can
//! never change a result bit.
//!
//! The pool's epoch protocol itself is model-checked: `crate::check`
//! runs the same generic `dispatch`/`worker_loop` code this executor's
//! pool monomorphizes under a deterministic scheduler that enumerates
//! interleavings exhaustively (`tests/pool_check.rs` — covering exactly
//! once, termination under every schedule, unwind soundness), and the
//! pool's slot lock recovers from poisoning, so one kernel panic cannot
//! wedge later dispatches.
//!
//! Layouts: every conv kernel exists for NCHW, NHWC, and NCHW{c}, in
//! fp32, standalone int8 (i32 out), and fused-quantized (q→conv→dq
//! collapsed) forms, each with the full `[bias] [add] [relu] [add]`
//! epilogue; the packed fused kernel accumulates i32 over the channel
//! block in a stack-resident lane array while the block fits
//! [`MAX_FUSED_QCONV_CB`], and in per-band spill windows planned into the
//! step's scratch slot beyond that — zero heap allocations either way.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, Result};

use super::microkernel::{dot_i8, Isa};
use super::pool::{Banding, WorkerPool};
use super::{ExecCounters, ExecSnapshot, Executor};
use crate::graph::compile::{
    compile_graph_with, CompiledGraph, Epilogue, MicroKernel, Residual, ScheduleOverrides,
    Slot, Step, StepOp, StepSched, MAX_FUSED_QCONV_CB,
};
use crate::graph::ir::{ConstValue, Graph, IrDType, Layout};
use crate::graph::kernels as gk;
use crate::quant::QMAX;
use crate::runtime::{DType, TensorData};
use crate::telem::{ProfileSink, StepKey, StepProfiler};

fn to_dtype(ir: IrDType) -> DType {
    match ir {
        IrDType::F32 => DType::F32,
        IrDType::S8 => DType::S8,
        IrDType::S32 => DType::S32,
    }
}

pub struct ArenaExec {
    cg: CompiledGraph,
    /// u64-backed so the base pointer is 8-aligned; plan offsets are
    /// `ARENA_ALIGN`-aligned on top of that.  RefCell: the executor runs
    /// confined to one thread (kernels fan out *inside* a step via the
    /// worker pool over disjoint windows).
    arena: RefCell<Vec<u64>>,
    /// Persistent kernel fan-out workers; `None` when `threads == 1`.
    pool: Option<WorkerPool>,
    threads: usize,
    /// Widest int8 dot-product ISA detected at construction; every
    /// microkernel dispatch reads this instead of re-probing CPUID.
    isa: Isa,
    name: String,
    batch: usize,
    counters: ExecCounters,
    /// Sampled per-step attribution ([`ArenaExec::set_profiling`]);
    /// `None` = profiling off (the default, and the zero-cost path).
    profiler: Option<StepProfiler>,
}

impl ArenaExec {
    /// Compile with fusion on, single-threaded kernels.
    pub fn compile(g: &Graph) -> Result<Self> {
        Self::with_options(g, true, 1)
    }

    /// `fuse = false` is the unfused ablation; `threads` sets the width of
    /// the persistent worker pool the conv/dense kernels fan out over.
    pub fn with_options(g: &Graph, fuse: bool, threads: usize) -> Result<Self> {
        Self::with_schedule(g, fuse, threads, &ScheduleOverrides::default())
    }

    /// [`ArenaExec::with_options`] under explicit schedule overrides (the
    /// tuned path): per-class banding / band-cap knobs and the packed
    /// lane-accumulator bound.  `overrides.threads` is always overwritten
    /// with `threads`, so spill windows are sized for exactly this
    /// executor's pool width.
    pub fn with_schedule(
        g: &Graph,
        fuse: bool,
        threads: usize,
        overrides: &ScheduleOverrides,
    ) -> Result<Self> {
        let threads = threads.max(1);
        let mut ovr = overrides.clone();
        ovr.threads = threads;
        let cg = compile_graph_with(g, fuse, &ovr)?;
        let words = cg.arena_bytes / 8 + 1;
        let batch = cg.input_ty.shape.first().copied().unwrap_or(1);
        let pool = if threads > 1 { Some(WorkerPool::new(threads)) } else { None };
        let name = format!(
            "arena(b{batch}{}{})",
            if fuse { ",fused" } else { ",unfused" },
            if ovr.is_default_schedule() { "" } else { ",tuned" }
        );
        Ok(Self {
            cg,
            arena: RefCell::new(vec![0u64; words]),
            pool,
            threads,
            isa: Isa::detect(),
            name,
            batch,
            counters: ExecCounters::default(),
            profiler: None,
        })
    }

    /// Wrap an already-compiled program — the warm-start path: the
    /// compile cache (or an in-situ tuner's publication) hands over a
    /// deserialized/verified [`CompiledGraph`] and this constructor runs
    /// **zero** compiler calls, only allocating the arena and spawning
    /// the pool.  The plan's spill windows must have been sized for
    /// `threads` (the cache keys entries by pool width for exactly this
    /// reason); a wider pool than the plan was built for is rejected.
    pub fn from_compiled(cg: CompiledGraph, threads: usize) -> Result<Self> {
        let threads = threads.max(1);
        for (i, step) in cg.steps.iter().enumerate() {
            if let Some(sp) = &step.spill {
                if sp.bands < threads {
                    return Err(anyhow!(
                        "step {i} spill windows sized for {} bands, pool width is {threads}",
                        sp.bands
                    ));
                }
            }
            if let Some(pi) = step.packed {
                if pi >= cg.packed.len() {
                    return Err(anyhow!(
                        "step {i} references packed weight {pi}, pool holds {}",
                        cg.packed.len()
                    ));
                }
            }
        }
        let words = cg.arena_bytes / 8 + 1;
        let batch = cg.input_ty.shape.first().copied().unwrap_or(1);
        let pool = if threads > 1 { Some(WorkerPool::new(threads)) } else { None };
        let name = format!("arena(b{batch},cached)");
        Ok(Self {
            cg,
            arena: RefCell::new(vec![0u64; words]),
            pool,
            threads,
            isa: Isa::detect(),
            name,
            batch,
            counters: ExecCounters::default(),
            profiler: None,
        })
    }

    pub fn compiled(&self) -> &CompiledGraph {
        &self.cg
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enable sampled per-step profiling: every `every`-th inference runs
    /// with per-step timestamps, attributed into `sink` under keys of
    /// (op, output shape, layout, precision, ISA, micro tile) — the
    /// paper's Table-1 attribution axes, for live traffic.  `every == 0`
    /// disables.  Key construction (and the sink interning) allocates,
    /// so this happens at build/configure time; the sampled inference
    /// itself only reads clocks and bumps pre-registered atomics, keeping
    /// the serve path zero-alloc whether or not a run is sampled.
    pub fn set_profiling(&mut self, every: u64, sink: &ProfileSink) {
        if every == 0 {
            self.profiler = None;
            return;
        }
        let keys: Vec<StepKey> = self.cg.steps.iter().map(|s| self.step_key(s)).collect();
        self.profiler = Some(StepProfiler::new(every, sink, keys));
    }

    /// Attribution key of one compiled step (see [`StepKey`]).
    fn step_key(&self, step: &Step) -> StepKey {
        let (op, layout) = match &step.op {
            StepOp::LoadInput => ("load_input", None),
            StepOp::Conv2d { layout, .. } => ("conv2d", Some(*layout)),
            StepOp::QConv2d { layout, .. } => ("qconv2d", Some(*layout)),
            StepOp::Dense { .. } => ("dense", None),
            StepOp::QDense { .. } => ("qdense", None),
            StepOp::BiasAdd { layout } => ("bias_add", Some(*layout)),
            StepOp::Relu => ("relu", None),
            StepOp::Add => ("add", None),
            StepOp::MaxPool { layout, .. } => ("max_pool", Some(*layout)),
            StepOp::GlobalAvgPool { layout } => ("global_avg_pool", Some(*layout)),
            StepOp::Quantize { .. } => ("quantize", None),
            StepOp::Dequantize { .. } => ("dequantize", None),
            StepOp::LayoutTransform { .. } => ("layout_transform", None),
        };
        let layout = match layout {
            None => "-".to_string(),
            Some(Layout::Nchw) => "nchw".into(),
            Some(Layout::Nhwc) => "nhwc".into(),
            Some(Layout::Nchwc(cb)) => format!("nchw{cb}c"),
        };
        // Precision = the *compute* precision: quantized anchors and int8
        // operands are int8 work even when the fused destination is f32.
        let int8 = matches!(&step.op, StepOp::QConv2d { .. } | StepOp::QDense { .. })
            || step.srcs.first().map(|s| s.1.dtype == IrDType::S8).unwrap_or(false);
        let precision = if int8 { "int8" } else { "fp32" };
        let micro = match step.sched.micro {
            None => "-".to_string(),
            Some(m) => format!("m{}n{}k{}", m.mr, m.nr, m.ku),
        };
        StepKey {
            op: op.to_string(),
            shape: step.dst_ty.shape.clone(),
            layout,
            precision: precision.to_string(),
            isa: format!("{:?}", self.isa).to_ascii_lowercase(),
            micro,
        }
    }

    /// Execute into a caller-provided output tensor: the zero-allocation
    /// serving path (no heap traffic at all after construction at any
    /// thread count — the allocation-counting test asserts exactly this).
    pub fn run_into(&self, input: &TensorData, out: &mut TensorData) -> Result<()> {
        if input.shape != self.cg.input_ty.shape
            || input.dtype != to_dtype(self.cg.input_ty.dtype)
        {
            return Err(anyhow!(
                "arena: input {:?}/{:?} != compiled {:?}/{:?}",
                input.shape, input.dtype, self.cg.input_ty.shape, self.cg.input_ty.dtype
            ));
        }
        if out.shape != self.cg.output_ty.shape
            || out.dtype != to_dtype(self.cg.output_ty.dtype)
        {
            return Err(anyhow!(
                "arena: output buffer {:?}/{:?} != compiled {:?}/{:?}",
                out.shape, out.dtype, self.cg.output_ty.shape, self.cg.output_ty.dtype
            ));
        }
        self.counters.invocations.fetch_add(1, Ordering::Relaxed);
        self.counters.dispatches.fetch_add(1, Ordering::Relaxed);
        self.counters
            .instructions
            .fetch_add(self.cg.steps.len() as u64, Ordering::Relaxed);

        // SAFETY: all arena windows below are derived from this one live
        // mutable borrow.  The static plan guarantees (verified at compile
        // time) that values with overlapping lifetimes occupy disjoint byte
        // ranges, so a step's destination/scratch windows never overlap its
        // source windows (including a fused step's residual operand), and
        // concurrent kernel workers only ever split the destination window
        // disjointly.
        let mut arena = self.arena.borrow_mut();
        let base = arena.as_mut_ptr() as *mut u8;
        match &self.profiler {
            // Sampled run: timestamp every step.  Clock reads and the
            // profiler's atomic adds allocate nothing, so even sampled
            // inferences stay zero-heap-alloc (the allocation-counting
            // test covers the profiler-attached configuration).
            Some(p) if p.should_sample() => {
                for (i, step) in self.cg.steps.iter().enumerate() {
                    let t0 = std::time::Instant::now();
                    self.exec_step(step, base, input)
                        .map_err(|e| e.context(format!("step '{}'", step.name)))?;
                    p.record(i, t0.elapsed().as_nanos() as u64);
                }
            }
            _ => {
                for step in &self.cg.steps {
                    self.exec_step(step, base, input)
                        .map_err(|e| e.context(format!("step '{}'", step.name)))?;
                }
            }
        }
        let (off, bytes) = match self.cg.output_slot {
            Slot::Arena { offset, bytes } => (offset, bytes),
            Slot::Const(_) => return Err(anyhow!("arena: constant output slot")),
        };
        let src = unsafe { std::slice::from_raw_parts(base.add(off) as *const u8, bytes) };
        out.data.copy_from_slice(src);
        drop(arena);
        Ok(())
    }

    fn src_bytes<'a>(&'a self, slot: &Slot, base: *const u8) -> &'a [u8] {
        match slot {
            Slot::Arena { offset, bytes } => unsafe {
                std::slice::from_raw_parts(base.add(*offset), *bytes)
            },
            Slot::Const(ci) => const_bytes(&self.cg.consts[*ci].0),
        }
    }

    /// A bias operand must be an f32 constant (enforced at compile time).
    fn bias_slice(&self, ci: usize) -> Result<&[f32]> {
        match &self.cg.consts[ci].0 {
            ConstValue::F32(v) => Ok(v),
            other => Err(anyhow!("bias constant is {:?}, not f32", other.dtype())),
        }
    }

    /// Resolve an anchor step's epilogue into element-ready values: the
    /// bias constant and, for a two-input step, the residual operand
    /// (always `srcs[2]`, planned disjoint from the destination).
    fn epi_vals<'a>(&'a self, step: &Step, epi: &Epilogue, base: *const u8) -> Result<EpiVals<'a>> {
        let bias = match epi.bias {
            Some(ci) => Some(self.bias_slice(ci)?),
            None => None,
        };
        let res = match epi.residual {
            Some(pos) => {
                let slot = step
                    .srcs
                    .get(2)
                    .ok_or_else(|| anyhow!("residual epilogue without a third operand"))?;
                Some((f32s(self.src_bytes(&slot.0, base))?, pos))
            }
            None => None,
        };
        Ok(EpiVals { bias, relu: epi.relu, res })
    }

    /// Resolve a fused packed q-conv step's lane-accumulator strategy:
    /// `None` means the `cb`-lane accumulator fits the kernel's stack
    /// array; `Some((base, stride))` points at the per-band i32 spill
    /// windows the compiler planned into the scratch slot (`stride` in
    /// i32 elements per band).
    fn spill_windows(
        &self,
        step: &Step,
        scratch: &Slot,
        base: *mut u8,
        cb: usize,
    ) -> Result<Option<(SendPtr<i32>, usize)>> {
        let Some(sp) = step.spill else {
            if cb > MAX_FUSED_QCONV_CB {
                return Err(anyhow!(
                    "fused packed conv block {cb} exceeds the stack accumulator \
                     ({MAX_FUSED_QCONV_CB}) and has no spill plan"
                ));
            }
            return Ok(None);
        };
        let Slot::Arena { offset, bytes } = scratch else {
            return Err(anyhow!("scratch in the constant pool"));
        };
        if sp.offset + sp.bands * sp.band_bytes > *bytes || sp.band_bytes < cb * 4 {
            return Err(anyhow!("spill windows exceed the scratch slot"));
        }
        // The kernel indexes windows by band id; bands are clamped to the
        // pool width, so the plan must cover at least that many.
        if self.threads > sp.bands {
            return Err(anyhow!(
                "spill plan sized for {} bands, pool width is {}",
                sp.bands, self.threads
            ));
        }
        // 64-aligned slot offset + 64-aligned window offsets keep every
        // window i32-aligned.
        let ptr = unsafe { base.add(offset + sp.offset) } as *mut i32;
        Ok(Some((SendPtr(ptr), sp.band_bytes / 4)))
    }

    fn exec_step(&self, step: &Step, base: *mut u8, input: &TensorData) -> Result<()> {
        let dst_b = arena_bytes_mut(base, &step.dst)?;
        let os = &step.dst_ty.shape;
        let rc = RowCfg { pool: self.pool.as_ref(), sched: step.sched };
        match &step.op {
            StepOp::LoadInput => {
                dst_b.copy_from_slice(&input.data);
            }
            StepOp::Conv2d { stride, padding, layout, epi } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let (wb, wt) = (self.src_bytes(&step.srcs[1].0, base), &step.srcs[1].1);
                match (xt.dtype, layout) {
                    (IrDType::F32, Layout::Nchw) => {
                        let ev = self.epi_vals(step, epi, base)?;
                        conv2d_nchw_f32(
                            f32s(xb)?, &xt.shape, f32s(wb)?, &wt.shape,
                            *stride, *padding, ev, f32s_mut(dst_b)?, os, rc,
                        );
                    }
                    (IrDType::F32, Layout::Nhwc) => {
                        let ev = self.epi_vals(step, epi, base)?;
                        conv2d_nhwc_f32(
                            f32s(xb)?, &xt.shape, f32s(wb)?, &wt.shape,
                            *stride, *padding, ev, f32s_mut(dst_b)?, os, rc,
                        );
                    }
                    (IrDType::F32, Layout::Nchwc(cb)) => {
                        let ev = self.epi_vals(step, epi, base)?;
                        conv2d_nchwc_f32(
                            f32s(xb)?, &xt.shape, f32s(wb)?, &wt.shape,
                            *stride, *padding, *cb, ev, f32s_mut(dst_b)?, os, rc,
                        );
                    }
                    // Standalone int8 convs (the unfused ablation, or bare
                    // int8 graphs): i32 out, never an epilogue — fused
                    // chains always end in f32.  A pre-packed weight picks
                    // the register-blocked microkernel body; i32 addition
                    // is order-exact either way.
                    (IrDType::S8, Layout::Nchw) if epi.is_identity() => match step.packed {
                        Some(pi) => conv2d_nchw_i8_micro(
                            i8s(xb), &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            *stride, *padding, i32s_mut(dst_b)?, os, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => conv2d_nchw_i8(
                            i8s(xb), &xt.shape, i8s(wb), &wt.shape,
                            *stride, *padding, i32s_mut(dst_b)?, os, rc,
                        ),
                    },
                    (IrDType::S8, Layout::Nhwc) if epi.is_identity() => match step.packed {
                        Some(pi) => conv2d_nhwc_i8_micro(
                            i8s(xb), &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            *stride, *padding, i32s_mut(dst_b)?, os, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => conv2d_nhwc_i8(
                            i8s(xb), &xt.shape, i8s(wb), &wt.shape,
                            *stride, *padding, i32s_mut(dst_b)?, os, rc,
                        ),
                    },
                    (IrDType::S8, Layout::Nchwc(cb)) if epi.is_identity() => match step.packed {
                        Some(pi) => conv2d_nchwc_i8_micro(
                            i8s(xb), &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            *stride, *padding, *cb, i32s_mut(dst_b)?, os, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => conv2d_nchwc_i8(
                            i8s(xb), &xt.shape, i8s(wb), &wt.shape,
                            *stride, *padding, *cb, i32s_mut(dst_b)?, os, rc,
                        ),
                    },
                    other => {
                        return Err(anyhow!(
                            "arena conv: unsupported operands {:?} (int8 epilogues never fuse)",
                            other
                        ));
                    }
                }
            }
            StepOp::QConv2d { qscale, dqscale, stride, padding, layout, epi } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let (wb, wt) = (self.src_bytes(&step.srcs[1].0, base), &step.srcs[1].1);
                let scratch = step
                    .scratch
                    .as_ref()
                    .ok_or_else(|| anyhow!("fused conv without scratch slot"))?;
                // The quantized input occupies the first `qlen` scratch
                // bytes; anything beyond (the packed spill windows) must
                // not be aliased by the i8 view.
                let qlen = step.srcs[0].1.element_count();
                let qb = arena_bytes_mut(base, scratch)?;
                if qb.len() < qlen {
                    return Err(anyhow!("scratch slot smaller than quantized input"));
                }
                let xq = i8s_mut(&mut qb[..qlen]);
                quantize_into(f32s(xb)?, *qscale, xq);
                let ev = self.epi_vals(step, epi, base)?;
                match layout {
                    Layout::Nchw => match step.packed {
                        Some(pi) => qconv2d_nchw_micro(
                            xq, &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            *stride, *padding, *dqscale, ev, f32s_mut(dst_b)?, os, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => qconv2d_nchw(
                            xq, &xt.shape, i8s(wb), &wt.shape, *stride, *padding,
                            *dqscale, ev, f32s_mut(dst_b)?, os, rc,
                        ),
                    },
                    Layout::Nhwc => match step.packed {
                        Some(pi) => qconv2d_nhwc_micro(
                            xq, &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            *stride, *padding, *dqscale, ev, f32s_mut(dst_b)?, os, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => qconv2d_nhwc(
                            xq, &xt.shape, i8s(wb), &wt.shape, *stride, *padding,
                            *dqscale, ev, f32s_mut(dst_b)?, os, rc,
                        ),
                    },
                    Layout::Nchwc(cb) => {
                        if wt.shape[4] != *cb || wt.shape[5] != *cb {
                            return Err(anyhow!(
                                "fused packed conv block {cb} does not match weight {:?}",
                                wt.shape
                            ));
                        }
                        let spill = self.spill_windows(step, scratch, base, *cb)?;
                        match step.packed {
                            Some(pi) => qconv2d_nchwc_micro(
                                xq, &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                                *stride, *padding, *cb, *dqscale, ev, spill,
                                f32s_mut(dst_b)?, os, rc,
                                step.sched.micro.unwrap_or_default(), self.isa,
                            ),
                            None => qconv2d_nchwc(
                                xq, &xt.shape, i8s(wb), &wt.shape, *stride, *padding,
                                *cb, *dqscale, ev, spill, f32s_mut(dst_b)?, os, rc,
                            ),
                        }
                    }
                }
            }
            StepOp::Dense { epi } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let (wb, wt) = (self.src_bytes(&step.srcs[1].0, base), &step.srcs[1].1);
                match xt.dtype {
                    IrDType::F32 => {
                        // BiasAdd is rank-4 only, so the compiler never
                        // fuses a bias onto Dense; reject loudly rather
                        // than silently dropping one if that ever changes.
                        if epi.bias.is_some() {
                            return Err(anyhow!("arena dense: bias epilogue unsupported"));
                        }
                        let ev = self.epi_vals(step, epi, base)?;
                        dense_f32(
                            f32s(xb)?, &xt.shape, f32s(wb)?, &wt.shape,
                            ev, f32s_mut(dst_b)?, rc,
                        );
                    }
                    IrDType::S8 if epi.is_identity() => match step.packed {
                        Some(pi) => dense_i8_micro(
                            i8s(xb), &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                            i32s_mut(dst_b)?, rc,
                            step.sched.micro.unwrap_or_default(), self.isa,
                        ),
                        None => dense_i8(
                            i8s(xb), &xt.shape, i8s(wb), &wt.shape,
                            i32s_mut(dst_b)?, rc,
                        ),
                    },
                    other => return Err(anyhow!("arena dense: unsupported {:?} operands", other)),
                }
            }
            StepOp::QDense { qscale, dqscale, epi } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let (wb, wt) = (self.src_bytes(&step.srcs[1].0, base), &step.srcs[1].1);
                let scratch = step
                    .scratch
                    .as_ref()
                    .ok_or_else(|| anyhow!("fused dense without scratch slot"))?;
                let qb = arena_bytes_mut(base, scratch)?;
                let xq = i8s_mut(qb);
                quantize_into(f32s(xb)?, *qscale, xq);
                let ev = self.epi_vals(step, epi, base)?;
                match step.packed {
                    Some(pi) => qdense_micro(
                        xq, &xt.shape, &self.cg.packed[pi].data, &wt.shape,
                        *dqscale, ev, f32s_mut(dst_b)?, rc,
                        step.sched.micro.unwrap_or_default(), self.isa,
                    ),
                    None => qdense(
                        xq, &xt.shape, i8s(wb), &wt.shape, *dqscale, ev,
                        f32s_mut(dst_b)?, rc,
                    ),
                }
            }
            StepOp::BiasAdd { layout } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let bb = self.src_bytes(&step.srcs[1].0, base);
                gk::bias_add_f32(f32s(xb)?, &xt.shape, f32s(bb)?, *layout, f32s_mut(dst_b)?)?;
            }
            StepOp::Relu => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                match xt.dtype {
                    IrDType::F32 => {
                        let (x, o) = (f32s(xb)?, f32s_mut(dst_b)?);
                        for (d, v) in o.iter_mut().zip(x) {
                            *d = v.max(0.0);
                        }
                    }
                    IrDType::S32 => {
                        let (x, o) = (i32s(xb)?, i32s_mut(dst_b)?);
                        for (d, v) in o.iter_mut().zip(x) {
                            *d = (*v).max(0);
                        }
                    }
                    IrDType::S8 => {
                        let (x, o) = (i8s(xb), i8s_mut(dst_b));
                        for (d, v) in o.iter_mut().zip(x) {
                            *d = (*v).max(0);
                        }
                    }
                }
            }
            StepOp::Add => {
                let (ab, at) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let bb = self.src_bytes(&step.srcs[1].0, base);
                match at.dtype {
                    IrDType::F32 => {
                        let (a, b, o) = (f32s(ab)?, f32s(bb)?, f32s_mut(dst_b)?);
                        for i in 0..o.len() {
                            o[i] = a[i] + b[i];
                        }
                    }
                    IrDType::S32 => {
                        let (a, b, o) = (i32s(ab)?, i32s(bb)?, i32s_mut(dst_b)?);
                        for i in 0..o.len() {
                            o[i] = a[i] + b[i];
                        }
                    }
                    IrDType::S8 => {
                        let (a, b, o) = (i8s(ab), i8s(bb), i8s_mut(dst_b));
                        for i in 0..o.len() {
                            o[i] = a[i].saturating_add(b[i]);
                        }
                    }
                }
            }
            StepOp::MaxPool { window, stride, padding, layout } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                if xt.dtype != IrDType::F32 {
                    return Err(anyhow!("arena maxpool: f32 only"));
                }
                gk::maxpool_f32(
                    f32s(xb)?, &xt.shape, *window, *stride, *padding, *layout,
                    f32s_mut(dst_b)?, os,
                )?;
            }
            StepOp::GlobalAvgPool { layout } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                if xt.dtype != IrDType::F32 {
                    return Err(anyhow!("arena global_avg_pool: f32 only"));
                }
                gk::global_avgpool_f32(f32s(xb)?, &xt.shape, *layout, f32s_mut(dst_b)?)?;
            }
            StepOp::Quantize { scale } => {
                let xb = self.src_bytes(&step.srcs[0].0, base);
                quantize_into(f32s(xb)?, *scale, i8s_mut(dst_b));
            }
            StepOp::Dequantize { scale } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                let o = f32s_mut(dst_b)?;
                match xt.dtype {
                    IrDType::S8 => {
                        let x = i8s(xb);
                        for (d, v) in o.iter_mut().zip(x) {
                            *d = *v as f32 * scale;
                        }
                    }
                    IrDType::S32 => {
                        let x = i32s(xb)?;
                        for (d, v) in o.iter_mut().zip(x) {
                            *d = *v as f32 * scale;
                        }
                    }
                    IrDType::F32 => return Err(anyhow!("arena dequantize of f32")),
                }
            }
            StepOp::LayoutTransform { from, to } => {
                let (xb, xt) = (self.src_bytes(&step.srcs[0].0, base), &step.srcs[0].1);
                if xt.dtype != IrDType::F32 {
                    return Err(anyhow!("arena layout_transform: f32 only"));
                }
                layout_transform_f32(f32s(xb)?, &xt.shape, *from, *to, f32s_mut(dst_b)?)?;
            }
        }
        Ok(())
    }
}

impl Executor for ArenaExec {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        let mut out = TensorData::zeros(
            to_dtype(self.cg.output_ty.dtype),
            self.cg.output_ty.shape.clone(),
        );
        self.run_into(input, &mut out)?;
        Ok(out)
    }

    /// The trait's serving entry point is exactly the inherent
    /// zero-allocation path.
    fn run_into(&self, input: &TensorData, out: &mut TensorData) -> Result<()> {
        ArenaExec::run_into(self, input, out)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        (self.cg.input_ty.shape.clone(), to_dtype(self.cg.input_ty.dtype))
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        (self.cg.output_ty.shape.clone(), to_dtype(self.cg.output_ty.dtype))
    }

    fn counters(&self) -> ExecSnapshot {
        self.counters.snapshot()
    }
}

// ---------------------------------------------------------------------------
// Typed views (the arena is bytes; offsets are cache-line aligned)
// ---------------------------------------------------------------------------

fn arena_bytes_mut<'a>(base: *mut u8, slot: &Slot) -> Result<&'a mut [u8]> {
    match slot {
        Slot::Arena { offset, bytes } => {
            Ok(unsafe { std::slice::from_raw_parts_mut(base.add(*offset), *bytes) })
        }
        Slot::Const(_) => Err(anyhow!("constant slot used as a destination")),
    }
}

fn const_bytes(c: &ConstValue) -> &[u8] {
    match c {
        ConstValue::F32(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        },
        ConstValue::I8(v) => unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
        },
    }
}

fn f32s(b: &[u8]) -> Result<&[f32]> {
    let (pre, mid, post) = unsafe { b.align_to::<f32>() };
    if !pre.is_empty() || !post.is_empty() {
        return Err(anyhow!("misaligned f32 view ({} bytes)", b.len()));
    }
    Ok(mid)
}

fn f32s_mut(b: &mut [u8]) -> Result<&mut [f32]> {
    let (pre, mid, post) = unsafe { b.align_to_mut::<f32>() };
    if !pre.is_empty() || !post.is_empty() {
        return Err(anyhow!("misaligned mutable f32 view"));
    }
    Ok(mid)
}

fn i32s(b: &[u8]) -> Result<&[i32]> {
    let (pre, mid, post) = unsafe { b.align_to::<i32>() };
    if !pre.is_empty() || !post.is_empty() {
        return Err(anyhow!("misaligned i32 view"));
    }
    Ok(mid)
}

fn i32s_mut(b: &mut [u8]) -> Result<&mut [i32]> {
    let (pre, mid, post) = unsafe { b.align_to_mut::<i32>() };
    if !pre.is_empty() || !post.is_empty() {
        return Err(anyhow!("misaligned mutable i32 view"));
    }
    Ok(mid)
}

fn i8s(b: &[u8]) -> &[i8] {
    unsafe { std::slice::from_raw_parts(b.as_ptr() as *const i8, b.len()) }
}

fn i8s_mut(b: &mut [u8]) -> &mut [i8] {
    unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut i8, b.len()) }
}

// ---------------------------------------------------------------------------
// Epilogue application
// ---------------------------------------------------------------------------

/// Element-ready epilogue operands: the bias constant and the residual
/// slice (both borrowed from the constant pool / arena for one step).
#[derive(Clone, Copy)]
struct EpiVals<'a> {
    bias: Option<&'a [f32]>,
    relu: bool,
    res: Option<(&'a [f32], Residual)>,
}

impl EpiVals<'_> {
    fn is_identity(&self) -> bool {
        self.bias.is_none() && !self.relu && self.res.is_none()
    }
}

/// Apply the fused elementwise tail to one output element, in exactly the
/// graph's operation order: (bias) → (pre-relu add) → (relu) →
/// (post-relu add).  `bias` is the per-channel value hoisted by the
/// caller; `idx` is the element's flat index into the output (and into
/// the residual operand, which always has the output's shape).  `Add`
/// operand order is preserved via `chain_lhs` — float addition is not
/// bit-commutative for NaN.
#[inline(always)]
fn epi_apply(
    mut v: f32,
    bias: Option<f32>,
    relu: bool,
    res: Option<(&[f32], Residual)>,
    idx: usize,
) -> f32 {
    if let Some(b) = bias {
        v += b;
    }
    if let Some((r, pos)) = res {
        if pos.pre_relu {
            v = if pos.chain_lhs { v + r[idx] } else { r[idx] + v };
        }
    }
    if relu {
        v = v.max(0.0);
    }
    if let Some((r, pos)) = res {
        if !pos.pre_relu {
            v = if pos.chain_lhs { v + r[idx] } else { r[idx] + v };
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Row-parallel driver
// ---------------------------------------------------------------------------

/// Raw base pointer that may cross into pool workers; the banding below
/// guarantees the workers write disjoint windows.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A kernel dispatch's row-parallel configuration: the executor's pool
/// plus the step's resolved schedule knobs ([`StepSched`]) — the tuner's
/// banding-mode / band-granularity / band-count axes, read here instead
/// of being hard-coded per kernel.
#[derive(Clone, Copy)]
struct RowCfg<'a> {
    pool: Option<&'a WorkerPool>,
    sched: StepSched,
}

/// Call `f(band, row_index, row)` for every `row_len`-element row of
/// `out`, fanning row bands out over the persistent pool.  The banding
/// mode is the step's override when set, else `default_banding` (the
/// kernel's historical choice); `sched.max_bands` caps the fan-out.  With
/// no pool (or a single band) everything runs inline; either way the
/// dispatch allocates nothing, and every row is written by exactly one
/// band ([`Banding::for_band_rows`]), so per-output-element results are
/// identical regardless of fan-out, banding mode, or chunk size.
fn par_rows<T: Send>(
    rc: RowCfg<'_>,
    default_banding: Banding,
    out: &mut [T],
    row_len: usize,
    f: impl Fn(usize, usize, &mut [T]) + Sync,
) {
    if row_len == 0 || out.is_empty() {
        return;
    }
    // Every kernel passes an exactly-dividing row length; the banded path
    // below relies on it (a remainder would be written inline but skipped
    // by the bands).
    debug_assert_eq!(out.len() % row_len, 0, "par_rows: ragged row length");
    let rows = out.len() / row_len;
    let mut bands = rc.pool.map_or(1, |p| p.threads()).min(rows).max(1);
    if rc.sched.max_bands > 0 {
        bands = bands.min(rc.sched.max_bands);
    }
    if bands == 1 {
        for (r, chunk) in out.chunks_mut(row_len).enumerate() {
            f(0, r, chunk);
        }
        return;
    }
    let banding = rc.sched.banding.unwrap_or(default_banding);
    let cursor = AtomicUsize::new(0);
    let base = SendPtr(out.as_mut_ptr());
    let f = &f;
    let job = move |band: usize| {
        // SAFETY: each row index belongs to exactly one band
        // (`Banding::for_band_rows`: disjoint contiguous ranges, disjoint
        // residue classes, or disjoint atomic-cursor grabs), and the pool
        // does not return from `run` until every band finished.
        banding.for_band_rows(band, bands, rows, &cursor, |r| {
            let row = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r * row_len), row_len)
            };
            f(band, r, row);
        });
    };
    rc.pool.expect("bands > 1 implies a pool").run(bands, &job);
}

// ---------------------------------------------------------------------------
// Kernels.  Every per-output-element operation sequence matches
// `graph::interp` exactly (see module docs); do not "improve" float
// reduction order here without changing the oracle in lockstep.
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn conv2d_nchw_f32(
    x: &[f32], xs: &[usize], w: &[f32], ws: &[usize],
    stride: usize, padding: usize, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>,
) {
    let (c, h, wd) = (xs[1], xs[2], xs[3]);
    let (k, r, s) = (ws[0], ws[2], ws[3]);
    let (oh, ow) = (os[2], os[3]);
    let ohw = oh * ow;
    par_rows(rc, Banding::Contiguous, out, ohw, |_, row, plane| {
        let (ni, ki) = (row / k, row % k);
        let b = ev.bias.map(|b| b[ki]);
        let plane_base = row * ohw;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0f32;
                for ci in 0..c {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            acc += x[((ni * c + ci) * h + iy) * wd + ix]
                                * w[((ki * c + ci) * r + ry) * s + sx];
                        }
                    }
                }
                plane[oy * ow + ox] =
                    epi_apply(acc, b, ev.relu, ev.res, plane_base + oy * ow + ox);
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn conv2d_nchw_i8(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>,
) {
    let (c, h, wd) = (xs[1], xs[2], xs[3]);
    let (k, r, s) = (ws[0], ws[2], ws[3]);
    let (oh, ow) = (os[2], os[3]);
    par_rows(rc, Banding::Contiguous, out, oh * ow, |_, row, plane| {
        let (ni, ki) = (row / k, row % k);
        for oy in 0..oh {
            for ox in 0..ow {
                plane[oy * ow + ox] = i8_conv_acc(
                    x, w, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                );
            }
        }
    });
}

/// Standalone int8 NHWC conv (HWIO weight): i32 out, no epilogue.  Rows
/// are spatial lines, so the banding is interleaved (border lines clipped
/// by padding are shallower than interior ones).
#[allow(clippy::too_many_arguments)]
fn conv2d_nhwc_i8(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>,
) {
    let (h, wd, c) = (xs[1], xs[2], xs[3]);
    let (r, s, k) = (ws[0], ws[1], ws[3]);
    let (oh, ow) = (os[1], os[2]);
    par_rows(rc, Banding::Interleaved, out, ow * k, |_, row, slab| {
        let (ni, oy) = (row / oh, row % oh);
        for ox in 0..ow {
            for ki in 0..k {
                slab[ox * k + ki] = i8_conv_acc_nhwc(
                    x, w, c, h, wd, r, s, k, stride, padding, ni, ki, oy, ox,
                );
            }
        }
    });
}

/// Standalone int8 packed conv (NCHW{cb} data, OIHW{i}{o} weight): i32
/// out, channel-blocked accumulation straight into the destination plane.
#[allow(clippy::too_many_arguments)]
fn conv2d_nchwc_i8(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, cb: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>,
) {
    let (co, h, wd) = (xs[1], xs[2], xs[3]);
    let (ko, r, s, kb) = (ws[0], ws[2], ws[3], ws[5]);
    let (oh, ow) = (os[2], os[3]);
    par_rows(rc, Banding::Contiguous, out, oh * ow * kb, |_, row, plane| {
        let (ni, ok) = (row / ko, row % ko);
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = (oy * ow + ox) * kb;
                plane[obase..obase + kb].fill(0);
                for oc in 0..co {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                            let wbase = ((((ok * co + oc) * r + ry) * s + sx) * cb) * kb;
                            for ci in 0..cb {
                                let xi = x[xbase + ci] as i32;
                                let wrow = wbase + ci * kb;
                                for ki in 0..kb {
                                    plane[obase + ki] += xi * w[wrow + ki] as i32;
                                }
                            }
                        }
                    }
                }
            }
        }
    });
}

/// One int8 output element: i32 accumulation with a unit-stride inner
/// loop over `sx` where the window is interior (no padding clipping), the
/// clipped scalar walk otherwise.  Integer addition is order-exact, so
/// this blocking cannot diverge from the interpreter.
#[allow(clippy::too_many_arguments)]
#[inline]
fn i8_conv_acc(
    x: &[i8], w: &[i8], c: usize, h: usize, wd: usize, r: usize, s: usize,
    stride: usize, padding: usize, ni: usize, ki: usize, oy: usize, ox: usize,
) -> i32 {
    let mut acc = 0i32;
    let x0 = ox * stride;
    let interior_x = x0 >= padding && x0 + s <= wd + padding;
    for ci in 0..c {
        let xplane = (ni * c + ci) * h;
        let wbase = (ki * c + ci) * r;
        for ry in 0..r {
            let iy = oy * stride + ry;
            if iy < padding || iy >= h + padding {
                continue;
            }
            let iy = iy - padding;
            if interior_x {
                let xrow = (xplane + iy) * wd + (x0 - padding);
                let wrow = (wbase + ry) * s;
                for sx in 0..s {
                    acc += x[xrow + sx] as i32 * w[wrow + sx] as i32;
                }
            } else {
                for sx in 0..s {
                    let ix = x0 + sx;
                    if ix < padding || ix >= wd + padding {
                        continue;
                    }
                    let ix = ix - padding;
                    acc += x[(xplane + iy) * wd + ix] as i32
                        * w[(wbase + ry) * s + sx] as i32;
                }
            }
        }
    }
    acc
}

/// One int8 NHWC output element: i32 accumulation, unit-stride over the
/// data operand's innermost channel dimension.
#[allow(clippy::too_many_arguments)]
#[inline]
fn i8_conv_acc_nhwc(
    x: &[i8], w: &[i8], c: usize, h: usize, wd: usize, r: usize, s: usize, k: usize,
    stride: usize, padding: usize, ni: usize, ki: usize, oy: usize, ox: usize,
) -> i32 {
    let mut acc = 0i32;
    for ry in 0..r {
        let iy = oy * stride + ry;
        if iy < padding || iy >= h + padding {
            continue;
        }
        let iy = iy - padding;
        for sx in 0..s {
            let ix = ox * stride + sx;
            if ix < padding || ix >= wd + padding {
                continue;
            }
            let ix = ix - padding;
            let xbase = ((ni * h + iy) * wd + ix) * c;
            let wbase = (ry * s + sx) * c * k + ki;
            for ci in 0..c {
                acc += x[xbase + ci] as i32 * w[wbase + ci * k] as i32;
            }
        }
    }
    acc
}

/// Fused quantized conv: int8 data (already quantized into scratch) ×
/// int8 weights → i32 accumulator → `acc as f32 * dqscale` through the
/// epilogue (bias / residual add / relu), written once.  The interior
/// i32/f32 boundary tensors never materialize.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nchw(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, dqscale: f32, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>,
) {
    let (c, h, wd) = (xs[1], xs[2], xs[3]);
    let (k, r, s) = (ws[0], ws[2], ws[3]);
    let (oh, ow) = (os[2], os[3]);
    let ohw = oh * ow;
    par_rows(rc, Banding::Contiguous, out, ohw, |_, row, plane| {
        let (ni, ki) = (row / k, row % k);
        let b = ev.bias.map(|b| b[ki]);
        let plane_base = row * ohw;
        for oy in 0..oh {
            for ox in 0..ow {
                let acc = i8_conv_acc(
                    x, w, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                );
                // Exactly dequantize → epilogue, elementwise.
                plane[oy * ow + ox] = epi_apply(
                    acc as f32 * dqscale, b, ev.relu, ev.res,
                    plane_base + oy * ow + ox,
                );
            }
        }
    });
}

/// Fused quantized NHWC conv: like [`qconv2d_nchw`], with the channel as
/// the innermost output dimension and interleaved spatial-line banding.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nhwc(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, dqscale: f32, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>,
) {
    let (h, wd, c) = (xs[1], xs[2], xs[3]);
    let (r, s, k) = (ws[0], ws[1], ws[3]);
    let (oh, ow) = (os[1], os[2]);
    let row_len = ow * k;
    par_rows(rc, Banding::Interleaved, out, row_len, |_, row, slab| {
        let (ni, oy) = (row / oh, row % oh);
        let row_base = row * row_len;
        for ox in 0..ow {
            for ki in 0..k {
                let acc = i8_conv_acc_nhwc(
                    x, w, c, h, wd, r, s, k, stride, padding, ni, ki, oy, ox,
                );
                slab[ox * k + ki] = epi_apply(
                    acc as f32 * dqscale, ev.bias.map(|b| b[ki]), ev.relu, ev.res,
                    row_base + ox * k + ki,
                );
            }
        }
    });
}

/// Fused quantized packed conv: channel-blocked i32 accumulation over the
/// `cb` input lanes into a `kb`-lane accumulator, then dequantize →
/// epilogue per lane.  The accumulator is **stack-resident** while the
/// block fits [`MAX_FUSED_QCONV_CB`] (and the tuner's stack-lanes knob);
/// wider blocks use the per-band spill windows the compiler planned into
/// the step's scratch slot — still zero heap allocations at serving time.
/// The epilogue bias is the logical-channel vector: lane `ki` of block
/// `ok` is channel `ok·kb + ki`.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nchwc(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    stride: usize, padding: usize, cb: usize, dqscale: f32, ev: EpiVals<'_>,
    spill: Option<(SendPtr<i32>, usize)>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>,
) {
    let (co, h, wd) = (xs[1], xs[2], xs[3]);
    let (ko, r, s, kb) = (ws[0], ws[2], ws[3], ws[5]);
    let (oh, ow) = (os[2], os[3]);
    let row_len = oh * ow * kb;
    par_rows(rc, Banding::Contiguous, out, row_len, |band, row, plane| {
        let (ni, ok) = (row / ko, row % ko);
        let plane_base = row * row_len;
        let mut stack = [0i32; MAX_FUSED_QCONV_CB];
        // SAFETY (spill arm): band ids never reach the plan's window
        // count (`spill_windows` checked pool width ≤ bands), windows are
        // disjoint per band and disjoint from every other byte range this
        // step touches (they live past the quantized input inside the
        // step's own scratch slot), and one band's rows run sequentially,
        // so the window is never shared.
        let acc: &mut [i32] = match spill {
            Some((sbase, stride_i32)) => unsafe {
                std::slice::from_raw_parts_mut(sbase.0.add(band * stride_i32), kb)
            },
            None => &mut stack[..kb],
        };
        for oy in 0..oh {
            for ox in 0..ow {
                acc[..kb].fill(0);
                for oc in 0..co {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                            let wbase = ((((ok * co + oc) * r + ry) * s + sx) * cb) * kb;
                            for ci in 0..cb {
                                let xi = x[xbase + ci] as i32;
                                let wrow = wbase + ci * kb;
                                for ki in 0..kb {
                                    acc[ki] += xi * w[wrow + ki] as i32;
                                }
                            }
                        }
                    }
                }
                let obase = (oy * ow + ox) * kb;
                for ki in 0..kb {
                    plane[obase + ki] = epi_apply(
                        acc[ki] as f32 * dqscale,
                        ev.bias.map(|b| b[ok * kb + ki]),
                        ev.relu,
                        ev.res,
                        plane_base + obase + ki,
                    );
                }
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn conv2d_nhwc_f32(
    x: &[f32], xs: &[usize], w: &[f32], ws: &[usize],
    stride: usize, padding: usize, ev: EpiVals<'_>, out: &mut [f32], os: &[usize],
    rc: RowCfg<'_>,
) {
    let (h, wd, c) = (xs[1], xs[2], xs[3]);
    let (r, s, k) = (ws[0], ws[1], ws[3]);
    let (oh, ow) = (os[1], os[2]);
    let row_len = ow * k;
    par_rows(rc, Banding::Interleaved, out, row_len, |_, row, slab| {
        let (ni, oy) = (row / oh, row % oh);
        let row_base = row * row_len;
        for ox in 0..ow {
            for ki in 0..k {
                let mut acc = 0f32;
                for ry in 0..r {
                    let iy = oy * stride + ry;
                    if iy < padding || iy >= h + padding {
                        continue;
                    }
                    let iy = iy - padding;
                    for sx in 0..s {
                        let ix = ox * stride + sx;
                        if ix < padding || ix >= wd + padding {
                            continue;
                        }
                        let ix = ix - padding;
                        for ci in 0..c {
                            acc += x[((ni * h + iy) * wd + ix) * c + ci]
                                * w[((ry * s + sx) * c + ci) * k + ki];
                        }
                    }
                }
                slab[ox * k + ki] = epi_apply(
                    acc, ev.bias.map(|b| b[ki]), ev.relu, ev.res,
                    row_base + ox * k + ki,
                );
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn conv2d_nchwc_f32(
    x: &[f32], xs: &[usize], w: &[f32], ws: &[usize],
    stride: usize, padding: usize, cb: usize, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>,
) {
    let (co, h, wd) = (xs[1], xs[2], xs[3]);
    let (ko, r, s, kb) = (ws[0], ws[2], ws[3], ws[5]);
    let (oh, ow) = (os[2], os[3]);
    let row_len = oh * ow * kb;
    par_rows(rc, Banding::Contiguous, out, row_len, |_, row, plane| {
        let (ni, ok) = (row / ko, row % ko);
        let plane_base = row * row_len;
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = (oy * ow + ox) * kb;
                plane[obase..obase + kb].fill(0.0);
                for oc in 0..co {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                            let wbase = ((((ok * co + oc) * r + ry) * s + sx) * cb) * kb;
                            for ci in 0..cb {
                                let xi = x[xbase + ci];
                                let wrow = wbase + ci * kb;
                                for ki in 0..kb {
                                    plane[obase + ki] += xi * w[wrow + ki];
                                }
                            }
                        }
                    }
                }
                if !ev.is_identity() {
                    // Lane `ki` of block `ok` is logical channel `ok·kb + ki`.
                    for ki in 0..kb {
                        plane[obase + ki] = epi_apply(
                            plane[obase + ki],
                            ev.bias.map(|b| b[ok * kb + ki]),
                            ev.relu,
                            ev.res,
                            plane_base + obase + ki,
                        );
                    }
                }
            }
        }
    });
}

fn dense_f32(
    x: &[f32], xs: &[usize], w: &[f32], ws: &[usize], ev: EpiVals<'_>,
    out: &mut [f32], rc: RowCfg<'_>,
) {
    let k = xs[1];
    let n = ws[1];
    par_rows(rc, Banding::Contiguous, out, n, |_, i, row| {
        row.fill(0.0);
        for kk in 0..k {
            let xik = x[i * k + kk];
            for j in 0..n {
                row[j] += xik * w[kk * n + j];
            }
        }
        if !ev.is_identity() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = epi_apply(*slot, None, ev.relu, ev.res, i * n + j);
            }
        }
    });
}

fn dense_i8(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize], out: &mut [i32],
    rc: RowCfg<'_>,
) {
    let k = xs[1];
    let n = ws[1];
    par_rows(rc, Banding::Contiguous, out, n, |_, i, row| {
        row.fill(0);
        for kk in 0..k {
            let xik = x[i * k + kk] as i32;
            for j in 0..n {
                row[j] += xik * w[kk * n + j] as i32;
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn qdense(
    x: &[i8], xs: &[usize], w: &[i8], ws: &[usize],
    dqscale: f32, ev: EpiVals<'_>, out: &mut [f32], rc: RowCfg<'_>,
) {
    let k = xs[1];
    let n = ws[1];
    par_rows(rc, Banding::Contiguous, out, n, |_, i, row| {
        for (j, slot) in row.iter_mut().enumerate() {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += x[i * k + kk] as i32 * w[kk * n + j] as i32;
            }
            *slot = epi_apply(acc as f32 * dqscale, None, ev.relu, ev.res, i * n + j);
        }
    });
}

// ---------------------------------------------------------------------------
// Register-blocked int8 microkernels.  Each mirrors the scalar kernel of
// the same layout exactly — same row mapping, same banding default, same
// epilogue order — but reads the compiler's pre-packed weight panel
// (`CompiledGraph::packed`) and reduces contiguous spans through
// [`dot_i8`].  The `MicroKernel` knobs shape the loops only (mr output
// positions per tile, nr output lanes per tile, ku scalar-chunk width):
// i32 accumulation is associative+commutative, so no knob setting and no
// ISA tier can change a single output bit.  See `executor::microkernel`
// module docs for the packed layouts.
// ---------------------------------------------------------------------------

/// One int8 NCHW output element over the identity-packed weight: the
/// interior fast path hands the whole `s`-wide filter row to [`dot_i8`];
/// clipped windows fall back to the scalar walk (same as [`i8_conv_acc`]).
#[allow(clippy::too_many_arguments)]
#[inline]
fn i8_conv_acc_micro_nchw(
    x: &[i8], wp: &[i8], c: usize, h: usize, wd: usize, r: usize, s: usize,
    stride: usize, padding: usize, ni: usize, ki: usize, oy: usize, ox: usize,
    ku: usize, isa: Isa,
) -> i32 {
    let mut acc = 0i32;
    let x0 = ox * stride;
    let interior_x = x0 >= padding && x0 + s <= wd + padding;
    for ci in 0..c {
        let xplane = (ni * c + ci) * h;
        let wbase = (ki * c + ci) * r;
        for ry in 0..r {
            let iy = oy * stride + ry;
            if iy < padding || iy >= h + padding {
                continue;
            }
            let iy = iy - padding;
            if interior_x {
                let xrow = (xplane + iy) * wd + (x0 - padding);
                let wrow = (wbase + ry) * s;
                acc += dot_i8(isa, ku, &x[xrow..xrow + s], &wp[wrow..wrow + s]);
            } else {
                for sx in 0..s {
                    let ix = x0 + sx;
                    if ix < padding || ix >= wd + padding {
                        continue;
                    }
                    let ix = ix - padding;
                    acc += x[(xplane + iy) * wd + ix] as i32
                        * wp[(wbase + ry) * s + sx] as i32;
                }
            }
        }
    }
    acc
}

/// One int8 NHWC output element over the `[K][R][S][C]`-packed weight:
/// every surviving filter tap reduces the full channel axis as one
/// contiguous dot product (data is channels-last, the pack made the
/// weight panel match).
#[allow(clippy::too_many_arguments)]
#[inline]
fn i8_conv_acc_micro_nhwc(
    x: &[i8], wp: &[i8], c: usize, h: usize, wd: usize, r: usize, s: usize,
    stride: usize, padding: usize, ni: usize, ki: usize, oy: usize, ox: usize,
    ku: usize, isa: Isa,
) -> i32 {
    let mut acc = 0i32;
    let wpanel = ki * r * s * c;
    for ry in 0..r {
        let iy = oy * stride + ry;
        if iy < padding || iy >= h + padding {
            continue;
        }
        let iy = iy - padding;
        for sx in 0..s {
            let ix = ox * stride + sx;
            if ix < padding || ix >= wd + padding {
                continue;
            }
            let ix = ix - padding;
            let xbase = ((ni * h + iy) * wd + ix) * c;
            let wbase = wpanel + (ry * s + sx) * c;
            acc += dot_i8(isa, ku, &x[xbase..xbase + c], &wp[wbase..wbase + c]);
        }
    }
    acc
}

/// Register-blocked standalone int8 NCHW conv: `mr` output positions per
/// tile along `ox`, each reduced via [`i8_conv_acc_micro_nchw`].
#[allow(clippy::too_many_arguments)]
fn conv2d_nchw_i8_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (c, h, wd) = (xs[1], xs[2], xs[3]);
    let (k, r, s) = (ws[0], ws[2], ws[3]);
    let (oh, ow) = (os[2], os[3]);
    let mr = mk.mr.max(1);
    par_rows(rc, Banding::Contiguous, out, oh * ow, |_, row, plane| {
        let (ni, ki) = (row / k, row % k);
        for oy in 0..oh {
            let mut ox0 = 0;
            while ox0 < ow {
                let oxe = (ox0 + mr).min(ow);
                for ox in ox0..oxe {
                    plane[oy * ow + ox] = i8_conv_acc_micro_nchw(
                        x, wp, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                        mk.ku, isa,
                    );
                }
                ox0 = oxe;
            }
        }
    });
}

/// Register-blocked standalone int8 NHWC conv: `nr` output lanes per tile
/// along the channel axis, each a full-channel dot per filter tap.
#[allow(clippy::too_many_arguments)]
fn conv2d_nhwc_i8_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (h, wd, c) = (xs[1], xs[2], xs[3]);
    let (r, s, k) = (ws[0], ws[1], ws[3]);
    let (oh, ow) = (os[1], os[2]);
    let nr = mk.nr.max(1);
    par_rows(rc, Banding::Interleaved, out, ow * k, |_, row, slab| {
        let (ni, oy) = (row / oh, row % oh);
        for ox in 0..ow {
            let mut kt = 0;
            while kt < k {
                let ke = (kt + nr).min(k);
                for ki in kt..ke {
                    slab[ox * k + ki] = i8_conv_acc_micro_nhwc(
                        x, wp, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                        mk.ku, isa,
                    );
                }
                kt = ke;
            }
        }
    });
}

/// Register-blocked standalone int8 packed conv over the
/// `[K/b][C/b][R][S][kb][cb]`-packed weight: per output lane `ki`, the
/// tap's `cb` input lanes reduce as one contiguous dot product.
#[allow(clippy::too_many_arguments)]
fn conv2d_nchwc_i8_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, cb: usize, out: &mut [i32], os: &[usize],
    rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (co, h, wd) = (xs[1], xs[2], xs[3]);
    let (ko, r, s, kb) = (ws[0], ws[2], ws[3], ws[5]);
    let (oh, ow) = (os[2], os[3]);
    par_rows(rc, Banding::Contiguous, out, oh * ow * kb, |_, row, plane| {
        let (ni, ok) = (row / ko, row % ko);
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = (oy * ow + ox) * kb;
                plane[obase..obase + kb].fill(0);
                for oc in 0..co {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                            let xspan = &x[xbase..xbase + cb];
                            let tap = (((ok * co + oc) * r + ry) * s + sx) * kb;
                            for ki in 0..kb {
                                let wrow = (tap + ki) * cb;
                                plane[obase + ki] +=
                                    dot_i8(isa, mk.ku, xspan, &wp[wrow..wrow + cb]);
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Fused quantized NCHW conv on the microkernel path: [`qconv2d_nchw`]
/// with the register-blocked accumulator.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nchw_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, dqscale: f32, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (c, h, wd) = (xs[1], xs[2], xs[3]);
    let (k, r, s) = (ws[0], ws[2], ws[3]);
    let (oh, ow) = (os[2], os[3]);
    let ohw = oh * ow;
    let mr = mk.mr.max(1);
    par_rows(rc, Banding::Contiguous, out, ohw, |_, row, plane| {
        let (ni, ki) = (row / k, row % k);
        let b = ev.bias.map(|b| b[ki]);
        let plane_base = row * ohw;
        for oy in 0..oh {
            let mut ox0 = 0;
            while ox0 < ow {
                let oxe = (ox0 + mr).min(ow);
                for ox in ox0..oxe {
                    let acc = i8_conv_acc_micro_nchw(
                        x, wp, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                        mk.ku, isa,
                    );
                    plane[oy * ow + ox] = epi_apply(
                        acc as f32 * dqscale, b, ev.relu, ev.res,
                        plane_base + oy * ow + ox,
                    );
                }
                ox0 = oxe;
            }
        }
    });
}

/// Fused quantized NHWC conv on the microkernel path: [`qconv2d_nhwc`]
/// with nr-lane tiles of full-channel dot products.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nhwc_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, dqscale: f32, ev: EpiVals<'_>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (h, wd, c) = (xs[1], xs[2], xs[3]);
    let (r, s, k) = (ws[0], ws[1], ws[3]);
    let (oh, ow) = (os[1], os[2]);
    let row_len = ow * k;
    let nr = mk.nr.max(1);
    par_rows(rc, Banding::Interleaved, out, row_len, |_, row, slab| {
        let (ni, oy) = (row / oh, row % oh);
        let row_base = row * row_len;
        for ox in 0..ow {
            let mut kt = 0;
            while kt < k {
                let ke = (kt + nr).min(k);
                for ki in kt..ke {
                    let acc = i8_conv_acc_micro_nhwc(
                        x, wp, c, h, wd, r, s, stride, padding, ni, ki, oy, ox,
                        mk.ku, isa,
                    );
                    slab[ox * k + ki] = epi_apply(
                        acc as f32 * dqscale, ev.bias.map(|b| b[ki]), ev.relu,
                        ev.res, row_base + ox * k + ki,
                    );
                }
                kt = ke;
            }
        }
    });
}

/// Fused quantized packed conv on the microkernel path: same
/// stack-or-spill `kb`-lane accumulator discipline as [`qconv2d_nchwc`],
/// with each lane's tap reduced by a contiguous dot product over the
/// packed `[kb][cb]` trailing block.
#[allow(clippy::too_many_arguments)]
fn qconv2d_nchwc_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    stride: usize, padding: usize, cb: usize, dqscale: f32, ev: EpiVals<'_>,
    spill: Option<(SendPtr<i32>, usize)>,
    out: &mut [f32], os: &[usize], rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let (co, h, wd) = (xs[1], xs[2], xs[3]);
    let (ko, r, s, kb) = (ws[0], ws[2], ws[3], ws[5]);
    let (oh, ow) = (os[2], os[3]);
    let row_len = oh * ow * kb;
    par_rows(rc, Banding::Contiguous, out, row_len, |band, row, plane| {
        let (ni, ok) = (row / ko, row % ko);
        let plane_base = row * row_len;
        let mut stack = [0i32; MAX_FUSED_QCONV_CB];
        // SAFETY (spill arm): identical to `qconv2d_nchwc` — band ids
        // never reach the plan's window count, windows are disjoint per
        // band and from every other byte range this step touches, and one
        // band's rows run sequentially.
        let acc: &mut [i32] = match spill {
            Some((sbase, stride_i32)) => unsafe {
                std::slice::from_raw_parts_mut(sbase.0.add(band * stride_i32), kb)
            },
            None => &mut stack[..kb],
        };
        for oy in 0..oh {
            for ox in 0..ow {
                acc[..kb].fill(0);
                for oc in 0..co {
                    for ry in 0..r {
                        let iy = oy * stride + ry;
                        if iy < padding || iy >= h + padding {
                            continue;
                        }
                        let iy = iy - padding;
                        for sx in 0..s {
                            let ix = ox * stride + sx;
                            if ix < padding || ix >= wd + padding {
                                continue;
                            }
                            let ix = ix - padding;
                            let xbase = (((ni * co + oc) * h + iy) * wd + ix) * cb;
                            let xspan = &x[xbase..xbase + cb];
                            let tap = (((ok * co + oc) * r + ry) * s + sx) * kb;
                            for ki in 0..kb {
                                let wrow = (tap + ki) * cb;
                                acc[ki] +=
                                    dot_i8(isa, mk.ku, xspan, &wp[wrow..wrow + cb]);
                            }
                        }
                    }
                }
                let obase = (oy * ow + ox) * kb;
                for ki in 0..kb {
                    plane[obase + ki] = epi_apply(
                        acc[ki] as f32 * dqscale,
                        ev.bias.map(|b| b[ok * kb + ki]),
                        ev.relu,
                        ev.res,
                        plane_base + obase + ki,
                    );
                }
            }
        }
    });
}

/// Standalone int8 dense over the `[N][K]`-packed (transposed) weight:
/// each output column is one contiguous K-axis dot product, tiled `nr`
/// columns at a time.
#[allow(clippy::too_many_arguments)]
fn dense_i8_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize], out: &mut [i32],
    rc: RowCfg<'_>, mk: MicroKernel, isa: Isa,
) {
    let k = xs[1];
    let n = ws[1];
    let nr = mk.nr.max(1);
    par_rows(rc, Banding::Contiguous, out, n, |_, i, row| {
        let xrow = &x[i * k..(i + 1) * k];
        let mut j0 = 0;
        while j0 < n {
            let je = (j0 + nr).min(n);
            for j in j0..je {
                row[j] = dot_i8(isa, mk.ku, xrow, &wp[j * k..(j + 1) * k]);
            }
            j0 = je;
        }
    });
}

/// Fused quantized dense on the microkernel path.
#[allow(clippy::too_many_arguments)]
fn qdense_micro(
    x: &[i8], xs: &[usize], wp: &[i8], ws: &[usize],
    dqscale: f32, ev: EpiVals<'_>, out: &mut [f32], rc: RowCfg<'_>,
    mk: MicroKernel, isa: Isa,
) {
    let k = xs[1];
    let n = ws[1];
    let nr = mk.nr.max(1);
    par_rows(rc, Banding::Contiguous, out, n, |_, i, row| {
        let xrow = &x[i * k..(i + 1) * k];
        let mut j0 = 0;
        while j0 < n {
            let je = (j0 + nr).min(n);
            for j in j0..je {
                let acc = dot_i8(isa, mk.ku, xrow, &wp[j * k..(j + 1) * k]);
                row[j] = epi_apply(acc as f32 * dqscale, None, ev.relu, ev.res, i * n + j);
            }
            j0 = je;
        }
    });
}

/// `q = clip(round(x / s))` — must match `crate::quant::quantize` exactly.
fn quantize_into(x: &[f32], scale: f32, out: &mut [i8]) {
    for (d, v) in out.iter_mut().zip(x) {
        *d = (v / scale).round().clamp(-QMAX, QMAX) as i8;
    }
}

/// Direct `from → to` permutation.  Equal to the interpreter's two-hop
/// (via NCHW) composition because both are pure index permutations.
fn layout_transform_f32(
    x: &[f32], xs: &[usize], from: Layout, to: Layout, out: &mut [f32],
) -> Result<()> {
    use crate::graph::ir::{dims_of, layout_offset};
    let (n, c, h, w) = dims_of(xs, from)?;
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..h {
                for xx in 0..w {
                    out[layout_offset(to, c, h, w, ni, ci, y, xx)] =
                        x[layout_offset(from, c, h, w, ni, ci, y, xx)];
                }
            }
        }
    }
    Ok(())
}
