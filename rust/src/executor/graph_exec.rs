//! Graph executor: the paper's fix (Table 1, `TVM-Quant-Graph`).
//!
//! "The Graph Executor is designed for efficient execution of pre-optimized
//! computation graphs.  It takes a static model graph, where every operation
//! is pre-defined, and optimizes it through various graph-level
//! optimizations for the target hardware." (§3.1)
//!
//! Concretely: the whole model is ONE fused HLO module — XLA performs the
//! cross-operator fusion and static buffer planning that TVM's graph
//! executor gets from its memory planner — and serving an inference is a
//! single executable dispatch with no interpretation and no per-node
//! allocation.

use std::rc::Rc;
use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use super::{EngineKind, ExecCounters, ExecSnapshot, Executor};
use crate::manifest::{Bundle, Manifest};
use crate::memplan::StaticPlan;
use crate::runtime::{DType, LoadedModule, Runtime, TensorData};

pub struct GraphExecutor {
    rt: Rc<Runtime>,
    module: Rc<LoadedModule>,
    /// Static memory plan over the (single-module) execution — degenerate
    /// here but recorded for footprint accounting parity with the VM.
    pub plan: StaticPlan,
    name: String,
    batch: usize,
    counters: ExecCounters,
}

impl GraphExecutor {
    pub fn new(rt: Rc<Runtime>, manifest: &Manifest, bundle: &Bundle) -> Result<Self> {
        if bundle.executor != EngineKind::Graph {
            return Err(anyhow!(
                "bundle {:?} is a {} bundle, not graph",
                bundle.id, bundle.executor
            ));
        }
        let module = rt.load_module(&manifest.root, &bundle.modules[0])?;
        let plan = StaticPlan::for_chain(&bundle.modules);
        Ok(Self {
            rt,
            module,
            plan,
            name: bundle.id.clone(),
            batch: bundle.batch,
            counters: ExecCounters::default(),
        })
    }
}

impl Executor for GraphExecutor {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        if input.shape != self.module.inputs[0].shape {
            return Err(anyhow!(
                "{}: input shape {:?} != compiled {:?}",
                self.name, input.shape, self.module.inputs[0].shape
            ));
        }
        self.counters.invocations.fetch_add(1, Ordering::Relaxed);
        self.counters.dispatches.fetch_add(1, Ordering::Relaxed);
        self.rt.execute_host(&self.module, &[input])
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        let spec = &self.module.inputs[0];
        (spec.shape.clone(), DType::parse(&spec.dtype))
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        let spec = &self.module.output;
        (spec.shape.clone(), DType::parse(&spec.dtype))
    }

    fn counters(&self) -> ExecSnapshot {
        self.counters.snapshot()
    }
}
