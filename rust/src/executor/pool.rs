//! Persistent worker pool for the arena executor's kernel fan-out.
//!
//! `std::thread::scope` spawns OS threads (and therefore heap-allocates)
//! on every kernel call; this pool spawns its workers once at executor
//! build time and then dispatches each kernel's row bands through a single
//! shared slot guarded by a mutex and two condvars.  The dispatch path
//! performs **no heap allocation** — std's mutex/condvar are futex-backed
//! on Linux and allocation-free to lock/wait/notify — which is what
//! restores the arena tier's zero-allocations-per-inference property at
//! `threads > 1` (pinned by `tests/arena_alloc.rs`).
//!
//! Protocol: [`WorkerPool::run`] publishes a type-erased `&dyn Fn(usize)`
//! job (a reference into the caller's stack frame), bumps an epoch, and
//! wakes every worker.  Worker `w` runs `job(w + 1)` — the caller itself
//! runs band 0 — then acknowledges; `run` blocks until every worker has
//! acknowledged the epoch, so the job reference never outlives the call.
//! That containment is what makes the lifetime transmute sound.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A band-parallel job, lifetime-erased for the shared slot.  Only ever
/// dereferenced between the epoch bump and the final acknowledgement of
/// the same epoch, while the underlying closure is still alive.
type Job = &'static (dyn Fn(usize) + Sync);

/// How a kernel splits its output rows across bands.
///
/// Every mode assigns every row to exactly one band, so results are
/// identical; only the load balance differs.  The arena tuner
/// (`crate::tune`) treats the mode — and `Dynamic`'s chunk size — as a
/// schedule knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Banding {
    /// Band `b` takes the contiguous range `[b·⌈rows/bands⌉, …)` — best
    /// cache behaviour when rows cost the same (NCHW/NCHW{c} convs: every
    /// row is a full output plane).
    Contiguous,
    /// Band `b` takes the strided residue class `b, b+bands, b+2·bands, …`
    /// — for ragged row costs that correlate with the row index (NHWC
    /// convs: a row is one spatial line, and padding-clipped border lines
    /// are shallower than interior ones), so contiguous banding would hand
    /// whole cheap regions to one band and deep regions to another.
    Interleaved,
    /// Dynamic dequeue (work stealing, distilled): every band repeatedly
    /// claims the next `chunk` rows from a shared atomic cursor until the
    /// rows run out.  Static banding cannot balance *pathological* row
    /// distributions — costs that correlate with neither position nor
    /// residue class — because the assignment is fixed before any row
    /// runs; here a band that lands on cheap rows simply comes back for
    /// more.  Smaller chunks balance better, larger chunks keep more
    /// locality per grab.  Allocation-free: the cursor lives on the
    /// dispatching caller's stack.
    Dynamic { chunk: usize },
}

impl Banding {
    /// Visit every row assigned to `band` (of `bands` total over `rows`
    /// rows), in that band's visiting order.  `cursor` is the dispatch's
    /// shared row cursor: one `AtomicUsize` starting at 0 shared by all
    /// bands of one dispatch (only [`Banding::Dynamic`] reads it).
    ///
    /// Across the `bands` bands of one dispatch, every row in `0..rows`
    /// is visited exactly once, in every mode — the property the arena
    /// kernels' disjoint-write safety rests on (and the unit tests below
    /// pin).
    pub fn for_band_rows(
        self,
        band: usize,
        bands: usize,
        rows: usize,
        cursor: &AtomicUsize,
        mut f: impl FnMut(usize),
    ) {
        debug_assert!(band < bands);
        match self {
            Banding::Contiguous => {
                let per = (rows + bands - 1) / bands;
                for r in (band * per)..((band + 1) * per).min(rows) {
                    f(r);
                }
            }
            Banding::Interleaved => {
                let mut r = band;
                while r < rows {
                    f(r);
                    r += bands;
                }
            }
            Banding::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                loop {
                    // Relaxed suffices: the cursor only partitions row
                    // indices; completion ordering comes from the pool's
                    // dispatch barrier.
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= rows {
                        break;
                    }
                    for r in start..(start + chunk).min(rows) {
                        f(r);
                    }
                }
            }
        }
    }
}

struct Slot {
    job: Option<Job>,
    /// Bands in the current dispatch; workers with `w + 1 >= bands` skip
    /// the job but still acknowledge the epoch.
    bands: usize,
    /// Bumped once per dispatch; each worker runs each epoch exactly once.
    epoch: u64,
    /// Workers that have not yet acknowledged the current epoch.
    outstanding: usize,
    /// A worker's job panicked during the current epoch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Wakes workers: new epoch or shutdown.
    work: Condvar,
    /// Wakes the dispatcher: all workers acknowledged.
    done: Condvar,
}

/// A fixed-width pool of `threads - 1` workers plus the dispatching
/// thread.  Dropping the pool shuts the workers down and joins them.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads - 1` workers (the dispatching thread is band 0).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                bands: 0,
                epoch: 0,
                outstanding: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|band| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tvmq-arena-{band}"))
                    .spawn(move || worker_loop(&shared, band))
                    .expect("spawn arena worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Total parallel width: the workers plus the dispatching thread.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `job(band)` once for every `band < min(bands, threads())`:
    /// band 0 inline on the caller, the rest on the workers.  `bands`
    /// beyond the pool width are clamped away (size work to `threads()`,
    /// as [`par_rows`](super::ArenaExec) does).  Returns after every band
    /// has finished.  Allocation-free on the happy path.
    ///
    /// # Panics
    /// Panics (on the caller) if a worker's job panicked, after all
    /// workers have acknowledged — the pool stays usable.
    pub fn run(&self, bands: usize, job: &(dyn Fn(usize) + Sync)) {
        if bands == 0 {
            return;
        }
        if bands == 1 || self.workers.is_empty() {
            for band in 0..bands.min(self.threads()) {
                job(band);
            }
            return;
        }
        // SAFETY: purely a lifetime erasure between identically laid-out
        // fat references.  `run` does not leave this frame — by return OR
        // by unwind (the `EpochBarrier` drop guard below blocks until
        // every worker acknowledged the epoch) — while any worker can
        // still touch the reference, so the 'static never outlives the
        // borrow it erases.
        let job_static: Job =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), Job>(job) };
        {
            let mut s = self.shared.slot.lock().unwrap();
            // A previous epoch whose band 0 unwound never reached the
            // panicked check below; start clean so this dispatch cannot
            // inherit a stale flag.
            s.panicked = false;
            s.job = Some(job_static);
            s.bands = bands.min(self.threads());
            s.epoch += 1;
            s.outstanding = self.workers.len();
            self.shared.work.notify_all();
        }
        {
            // Even if band 0 panics, wait for the workers before this
            // stack frame unwinds: they hold the lifetime-erased job
            // reference into it, and the slot state must be clean for
            // the next dispatch.
            let _barrier = EpochBarrier(&self.shared);
            job(0);
        }
        let mut s = self.shared.slot.lock().unwrap();
        if s.panicked {
            s.panicked = false;
            drop(s);
            panic!("arena worker panicked while running a kernel band");
        }
    }
}

/// Drop guard for one dispatch epoch: blocks until every worker has
/// acknowledged, then retires the job reference — on normal return *and*
/// on unwind from the dispatcher's own band.
struct EpochBarrier<'a>(&'a Shared);

impl Drop for EpochBarrier<'_> {
    fn drop(&mut self) {
        let mut s = self.0.slot.lock().unwrap();
        while s.outstanding != 0 {
            s = self.0.done.wait(s).unwrap();
        }
        s.job = None;
    }
}

fn worker_loop(shared: &Shared, band: usize) {
    let mut seen = 0u64;
    loop {
        let (job, bands) = {
            let mut s = shared.slot.lock().unwrap();
            while s.epoch == seen && !s.shutdown {
                s = shared.work.wait(s).unwrap();
            }
            if s.shutdown {
                return;
            }
            seen = s.epoch;
            (s.job, s.bands)
        };
        let mut panicked = false;
        if let Some(job) = job {
            if band < bands {
                // Keep the worker alive across kernel panics so the pool
                // (and the dispatcher waiting on it) never deadlocks; the
                // dispatcher re-raises after the epoch completes.
                panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    job(band)
                }))
                .is_err();
            }
        }
        let mut s = shared.slot.lock().unwrap();
        s.panicked |= panicked;
        s.outstanding -= 1;
        if s.outstanding == 0 {
            shared.done.notify_one();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut s = self.shared.slot.lock().unwrap();
            s.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_band_exactly_once() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = [const { AtomicUsize::new(0) }; 4];
        for _ in 0..100 {
            pool.run(4, &|band| {
                hits[band].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 100);
        }
    }

    #[test]
    fn bands_beyond_width_are_clamped_and_small_dispatches_inline() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2, "bands clamp to pool width");
        pool.run(1, &|band| {
            assert_eq!(band, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        pool.run(0, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(3, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1, "no workers: only band 0 runs");
    }

    /// Every banding mode must assign each row to exactly one band —
    /// contiguous/interleaved by arithmetic, dynamic via the shared
    /// cursor — including ragged row counts that don't divide evenly.
    #[test]
    fn every_banding_mode_covers_each_row_exactly_once() {
        for rows in [1usize, 2, 5, 7, 16, 33] {
            for bands in [1usize, 2, 3, 4] {
                for banding in [
                    Banding::Contiguous,
                    Banding::Interleaved,
                    Banding::Dynamic { chunk: 1 },
                    Banding::Dynamic { chunk: 2 },
                    Banding::Dynamic { chunk: 5 },
                    // chunk 0 must behave as chunk 1, not spin forever
                    Banding::Dynamic { chunk: 0 },
                ] {
                    let cursor = AtomicUsize::new(0);
                    let mut hits = vec![0usize; rows];
                    for band in 0..bands {
                        banding.for_band_rows(band, bands, rows, &cursor, |r| {
                            hits[r] += 1;
                        });
                    }
                    assert!(
                        hits.iter().all(|&h| h == 1),
                        "{banding:?} rows={rows} bands={bands}: hits {hits:?}"
                    );
                }
            }
        }
    }

    /// Dynamic dequeue through real pool workers: concurrent bands pull
    /// from one cursor and still cover every row exactly once.
    #[test]
    fn dynamic_banding_covers_rows_across_pool_workers() {
        let pool = WorkerPool::new(4);
        let rows = 103usize;
        let hits: Vec<AtomicUsize> = (0..rows).map(|_| AtomicUsize::new(0)).collect();
        let cursor = AtomicUsize::new(0);
        pool.run(4, &|band| {
            Banding::Dynamic { chunk: 3 }.for_band_rows(band, 4, rows, &cursor, |r| {
                hits[r].fetch_add(1, Ordering::Relaxed);
            });
        });
        for (r, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "row {r} visited wrong count");
        }
    }

    #[test]
    fn results_are_written_from_worker_threads() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 9];
        let base = out.as_mut_ptr() as usize;
        pool.run(3, &|band| {
            for i in 0..3 {
                // Disjoint windows per band, same shape the kernels use.
                unsafe { *(base as *mut usize).add(band * 3 + i) = band * 10 + i };
            }
        });
        assert_eq!(out, vec![0, 1, 2, 10, 11, 12, 20, 21, 22]);
    }
}
