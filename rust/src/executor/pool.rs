//! Persistent worker pool for the arena executor's kernel fan-out.
//!
//! `std::thread::scope` spawns OS threads (and therefore heap-allocates)
//! on every kernel call; this pool spawns its workers once at executor
//! build time and then dispatches each kernel's row bands through a single
//! shared slot guarded by a mutex and two condvars.  The dispatch path
//! performs **no heap allocation** — std's mutex/condvar are futex-backed
//! on Linux and allocation-free to lock/wait/notify — which is what
//! restores the arena tier's zero-allocations-per-inference property at
//! `threads > 1` (pinned by `tests/arena_alloc.rs`).
//!
//! Protocol: [`WorkerPool::run`] publishes a type-erased `&dyn Fn(usize)`
//! job (a reference into the caller's stack frame), bumps an epoch, and
//! wakes every worker.  Worker `w` runs `job(w + 1)` — the caller itself
//! runs band 0 — then acknowledges; `run` blocks until every worker has
//! acknowledged the epoch, so the job reference never outlives the call.
//! That containment is what makes the lifetime transmute sound.
//!
//! ## Checkability
//!
//! The epoch protocol itself — [`dispatch`], [`worker_loop`],
//! [`signal_shutdown`] — is written once, generically, over the small
//! [`SyncOps`] trait (one slot lock, two condvars, a yield point).  Two
//! implementations exist:
//!
//! - [`StdSync`] (here): the production substrate.  `Mutex` + `Condvar`,
//!   zero-cost over the previous hand-inlined code, poison-recovering (a
//!   panic from an unrelated worker must not take down dispatch — the
//!   slot state is re-validated at every epoch anyway, see
//!   [`StdSync::lock`]).
//! - `check::sched::ModelSync`: a deterministic cooperative scheduler
//!   that owns every lock/wait/notify decision and enumerates thread
//!   interleavings exhaustively (bounded DFS).  `tests/pool_check.rs`
//!   proves covering-exactly-once, no-lost-wakeup termination, unwind
//!   soundness, and shutdown drain over small worker/band/epoch
//!   configurations on **this exact protocol code**, not a model of it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// A band-parallel job, lifetime-erased for the shared slot.  Only ever
/// dereferenced between the epoch bump and the final acknowledgement of
/// the same epoch, while the underlying closure is still alive.
pub(crate) type Job = &'static (dyn Fn(usize) + Sync);

/// How a kernel splits its output rows across bands.
///
/// Every mode assigns every row to exactly one band, so results are
/// identical; only the load balance differs.  The arena tuner
/// (`crate::tune`) treats the mode — and `Dynamic`'s chunk size — as a
/// schedule knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Banding {
    /// Band `b` takes the contiguous range `[b·⌈rows/bands⌉, …)` — best
    /// cache behaviour when rows cost the same (NCHW/NCHW{c} convs: every
    /// row is a full output plane).
    Contiguous,
    /// Band `b` takes the strided residue class `b, b+bands, b+2·bands, …`
    /// — for ragged row costs that correlate with the row index (NHWC
    /// convs: a row is one spatial line, and padding-clipped border lines
    /// are shallower than interior ones), so contiguous banding would hand
    /// whole cheap regions to one band and deep regions to another.
    Interleaved,
    /// Dynamic dequeue (work stealing, distilled): every band repeatedly
    /// claims the next `chunk` rows from a shared atomic cursor until the
    /// rows run out.  Static banding cannot balance *pathological* row
    /// distributions — costs that correlate with neither position nor
    /// residue class — because the assignment is fixed before any row
    /// runs; here a band that lands on cheap rows simply comes back for
    /// more.  Smaller chunks balance better, larger chunks keep more
    /// locality per grab.  Allocation-free: the cursor lives on the
    /// dispatching caller's stack.
    Dynamic { chunk: usize },
}

impl Banding {
    /// Visit every row assigned to `band` (of `bands` total over `rows`
    /// rows), in that band's visiting order.  `cursor` is the dispatch's
    /// shared row cursor: one `AtomicUsize` starting at 0 shared by all
    /// bands of one dispatch (only [`Banding::Dynamic`] reads it).
    ///
    /// Across the `bands` bands of one dispatch, every row in `0..rows`
    /// is visited exactly once, in every mode — the property the arena
    /// kernels' disjoint-write safety rests on (and the unit tests below
    /// pin).
    pub fn for_band_rows(
        self,
        band: usize,
        bands: usize,
        rows: usize,
        cursor: &AtomicUsize,
        mut f: impl FnMut(usize),
    ) {
        debug_assert!(band < bands);
        match self {
            Banding::Contiguous => {
                let per = (rows + bands - 1) / bands;
                for r in (band * per)..((band + 1) * per).min(rows) {
                    f(r);
                }
            }
            Banding::Interleaved => {
                let mut r = band;
                while r < rows {
                    f(r);
                    r += bands;
                }
            }
            Banding::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                loop {
                    // Relaxed suffices: the cursor only partitions row
                    // indices; completion ordering comes from the pool's
                    // dispatch barrier.
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= rows {
                        break;
                    }
                    for r in start..(start + chunk).min(rows) {
                        f(r);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The epoch protocol, written once over an abstract sync substrate
// ---------------------------------------------------------------------------

/// The shared dispatch slot — the epoch protocol's entire mutable state,
/// always accessed under the substrate's lock.
pub(crate) struct Slot {
    pub(crate) job: Option<Job>,
    /// Bands in the current dispatch; workers with `w + 1 >= bands` skip
    /// the job but still acknowledge the epoch.
    pub(crate) bands: usize,
    /// Bumped once per dispatch; each worker runs each epoch exactly once.
    pub(crate) epoch: u64,
    /// Workers that have not yet acknowledged the current epoch.
    pub(crate) outstanding: usize,
    /// A worker's job panicked during the current epoch.
    pub(crate) panicked: bool,
    pub(crate) shutdown: bool,
}

impl Slot {
    pub(crate) fn new() -> Self {
        Slot {
            job: None,
            bands: 0,
            epoch: 0,
            outstanding: 0,
            panicked: false,
            shutdown: false,
        }
    }
}

/// The protocol's two sleep/wake channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Cv {
    /// Wakes workers: new epoch or shutdown.
    Work,
    /// Wakes the dispatcher: all workers acknowledged.
    Done,
}

/// Wake requests recorded inside a critical section and delivered when
/// the lock is released (std applies them immediately; the model
/// scheduler flips waiter states — and the checker's sabotage wrappers
/// drop them to prove the checker notices).
#[derive(Default)]
pub(crate) struct Wake {
    pub(crate) work_all: bool,
    /// Wake exactly one `Cv::Work` waiter (the admission queue's push
    /// path: one item needs one consumer).  Subsumed by `work_all`.
    pub(crate) work_one: bool,
    pub(crate) done_one: bool,
}

impl Wake {
    pub(crate) fn notify_work_all(&mut self) {
        self.work_all = true;
    }

    pub(crate) fn notify_work_one(&mut self) {
        self.work_one = true;
    }

    pub(crate) fn notify_done_one(&mut self) {
        self.done_one = true;
    }
}

/// The synchronization substrate a checkable protocol runs on: one lock
/// around the protocol state `St`, the two condvars of [`Cv`], and an
/// optional yield point.  The pool's epoch protocol instantiates it with
/// `St = Slot`; the coordinator's admission queue with `St = QState`.
/// Production uses [`StdSync`]-style substrates (futex-backed,
/// allocation-free); the model checker substitutes
/// `check::sched::ModelSync`, whose implementation hands every one of
/// these decisions to a deterministic scheduler — which is what makes a
/// protocol *checkable*: the checker runs this very code under every
/// interleaving it enumerates.
pub(crate) trait SyncOps: Sync {
    /// The protocol's entire mutable state, always accessed under the
    /// substrate's lock.
    type St;

    /// Critical section: run `f` under the state lock, then deliver the
    /// wakes `f` requested.
    fn locked<R>(&self, f: impl FnOnce(&mut Self::St, &mut Wake) -> R) -> R;

    /// Critical section with a wait loop: run `f` under the lock; when it
    /// returns `None`, release the lock, sleep on `cv` until notified,
    /// and re-run `f` under the re-acquired lock.  Wakes requested by `f`
    /// are delivered at every release (including before sleeping).
    fn locked_wait<R>(
        &self,
        cv: Cv,
        f: impl FnMut(&mut Self::St, &mut Wake) -> Option<R>,
    ) -> R;

    /// A scheduler-visible point in *unlocked* code (the model scheduler
    /// may preempt here); free in production.
    fn yield_point(&self) {}
}

/// The protocol functions take `&S`; forwarding through a reference lets
/// a harness hand each logical thread a borrowed substrate (the checker
/// wraps a per-thread `&ModelSync`).
impl<S: SyncOps> SyncOps for &S {
    type St = S::St;

    fn locked<R>(&self, f: impl FnOnce(&mut Self::St, &mut Wake) -> R) -> R {
        (**self).locked(f)
    }

    fn locked_wait<R>(
        &self,
        cv: Cv,
        f: impl FnMut(&mut Self::St, &mut Wake) -> Option<R>,
    ) -> R {
        (**self).locked_wait(cv, f)
    }

    fn yield_point(&self) {
        (**self).yield_point()
    }
}

/// One dispatch epoch over `workers` acknowledging workers: publish the
/// job, run band 0 inline, wait for every acknowledgement, re-raise a
/// worker panic.  `bands` must already be clamped to the pool width and
/// `>= 1`; `workers >= 1` (the inline fast paths never reach here).
pub(crate) fn dispatch<S: SyncOps<St = Slot>>(
    sync: &S,
    workers: usize,
    bands: usize,
    job: &(dyn Fn(usize) + Sync),
) {
    debug_assert!(workers >= 1 && bands >= 1);
    // SAFETY: purely a lifetime erasure between identically laid-out
    // fat references.  `dispatch` does not leave this frame — by return
    // OR by unwind (the `EpochBarrier` drop guard below blocks until
    // every worker acknowledged the epoch) — while any worker can still
    // touch the reference, so the 'static never outlives the borrow it
    // erases.
    let job_static: Job =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), Job>(job) };
    sync.locked(|s, w| {
        // A previous epoch whose band 0 unwound never reached the
        // panicked check below; start clean so this dispatch cannot
        // inherit a stale flag.
        s.panicked = false;
        s.job = Some(job_static);
        s.bands = bands;
        s.epoch += 1;
        s.outstanding = workers;
        w.notify_work_all();
    });
    {
        // Even if band 0 panics, wait for the workers before this stack
        // frame unwinds: they hold the lifetime-erased job reference into
        // it, and the slot state must be clean for the next dispatch.
        let _barrier = EpochBarrier(sync);
        sync.yield_point();
        job(0);
    }
    let worker_panicked = sync.locked(|s, _| {
        let p = s.panicked;
        s.panicked = false;
        p
    });
    if worker_panicked {
        panic!("arena worker panicked while running a kernel band");
    }
}

/// Drop guard for one dispatch epoch: blocks until every worker has
/// acknowledged, then retires the job reference — on normal return *and*
/// on unwind from the dispatcher's own band.
struct EpochBarrier<'a, S: SyncOps<St = Slot>>(&'a S);

impl<S: SyncOps<St = Slot>> Drop for EpochBarrier<'_, S> {
    fn drop(&mut self) {
        self.0.locked_wait(Cv::Done, |s, _| {
            if s.outstanding == 0 {
                s.job = None;
                Some(())
            } else {
                None
            }
        });
    }
}

/// One worker of the pool: claim each epoch exactly once, run its band,
/// acknowledge — and keep the worker alive across kernel panics so the
/// dispatcher waiting on the epoch never deadlocks (it re-raises after
/// the barrier).  Returns on shutdown.
pub(crate) fn worker_loop<S: SyncOps<St = Slot>>(sync: &S, band: usize) {
    let mut seen = 0u64;
    loop {
        let claimed = sync.locked_wait(Cv::Work, |s, _| {
            if s.shutdown {
                return Some(None);
            }
            if s.epoch != seen {
                seen = s.epoch;
                return Some(Some((s.job, s.bands)));
            }
            None
        });
        let (job, bands) = match claimed {
            Some(c) => c,
            None => return,
        };
        let mut panicked = false;
        if let Some(job) = job {
            if band < bands {
                panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    job(band)
                }))
                .is_err();
            }
        }
        sync.locked(|s, w| {
            s.panicked |= panicked;
            // Saturating: in correct executions outstanding is always
            // positive here (the checker proves each worker acks each
            // epoch exactly once); saturation keeps the checker's
            // failure-drain path from turning one detected bug into an
            // underflow panic cascade.
            s.outstanding = s.outstanding.saturating_sub(1);
            if s.outstanding == 0 {
                w.notify_done_one();
            }
        });
    }
}

/// Ask every worker to exit (the pool's drop path; the checker's
/// scenarios call it to prove shutdown drains without deadlock).
pub(crate) fn signal_shutdown<S: SyncOps<St = Slot>>(sync: &S) {
    sync.locked(|s, w| {
        s.shutdown = true;
        w.notify_work_all();
    });
}

// ---------------------------------------------------------------------------
// Production substrate: std mutex + condvars
// ---------------------------------------------------------------------------

/// The production [`SyncOps`]: one `Mutex<Slot>` and two `Condvar`s —
/// futex-backed on Linux, allocation-free to lock/wait/notify, and
/// monomorphized into exactly the code the pool hand-inlined before the
/// protocol went generic.
pub(crate) struct StdSync {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
}

impl StdSync {
    fn new() -> Self {
        StdSync {
            slot: Mutex::new(Slot::new()),
            work: Condvar::new(),
            done: Condvar::new(),
        }
    }

    /// Lock the slot, recovering a poisoned guard: the slot holds plain
    /// counters and flags that the protocol re-validates every epoch
    /// (each dispatch resets `panicked`/`job`/`bands`/`outstanding`), and
    /// worker jobs run under `catch_unwind` — so a poisoned mutex can
    /// only mean a panic from an *unrelated* thread unwound past a guard,
    /// and propagating it would turn one worker's panic into a
    /// dispatch-path panic for every subsequent caller.
    fn lock(&self) -> MutexGuard<'_, Slot> {
        self.slot.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn condvar(&self, cv: Cv) -> &Condvar {
        match cv {
            Cv::Work => &self.work,
            Cv::Done => &self.done,
        }
    }

    fn deliver(&self, w: &Wake) {
        if w.work_all {
            self.work.notify_all();
        } else if w.work_one {
            self.work.notify_one();
        }
        if w.done_one {
            self.done.notify_one();
        }
    }
}

impl SyncOps for StdSync {
    type St = Slot;

    fn locked<R>(&self, f: impl FnOnce(&mut Slot, &mut Wake) -> R) -> R {
        let mut g = self.lock();
        let mut w = Wake::default();
        let r = f(&mut g, &mut w);
        drop(g);
        // Notify after release: the waiter re-checks its predicate under
        // the lock, so late delivery is safe and avoids a pointless wake
        // into a still-held mutex.
        self.deliver(&w);
        r
    }

    fn locked_wait<R>(
        &self,
        cv: Cv,
        mut f: impl FnMut(&mut Slot, &mut Wake) -> Option<R>,
    ) -> R {
        let mut g = self.lock();
        loop {
            let mut w = Wake::default();
            let r = f(&mut g, &mut w);
            // Deliver while holding the lock — the sleep below must not
            // open a window between f's state change and its wakes.
            self.deliver(&w);
            match r {
                Some(r) => return r,
                None => {
                    g = self
                        .condvar(cv)
                        .wait(g)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// A fixed-width pool of `threads - 1` workers plus the dispatching
/// thread.  Dropping the pool shuts the workers down and joins them.
pub struct WorkerPool {
    shared: Arc<StdSync>,
    workers: Vec<JoinHandle<()>>,
    /// Kernel fan-outs ever issued through [`WorkerPool::run`] (including
    /// inline single-band ones) — observability for "did this executor
    /// actually parallelize", at one relaxed add per dispatch.
    dispatches: std::sync::atomic::AtomicU64,
}

impl WorkerPool {
    /// Spawn `threads - 1` workers (the dispatching thread is band 0).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(StdSync::new());
        let workers = (1..threads.max(1))
            .map(|band| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tvmq-arena-{band}"))
                    .spawn(move || worker_loop(&*shared, band))
                    .expect("spawn arena worker")
            })
            .collect();
        WorkerPool { shared, workers, dispatches: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Total parallel width: the workers plus the dispatching thread.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Kernel dispatches issued so far (see the field docs).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Run `job(band)` once for every `band < min(bands, threads())`:
    /// band 0 inline on the caller, the rest on the workers.  `bands`
    /// beyond the pool width are clamped away (size work to `threads()`,
    /// as [`par_rows`](super::ArenaExec) does).  Returns after every band
    /// has finished.  Allocation-free on the happy path.
    ///
    /// # Panics
    /// Panics (on the caller) if a worker's job panicked, after all
    /// workers have acknowledged — the pool stays usable.
    pub fn run(&self, bands: usize, job: &(dyn Fn(usize) + Sync)) {
        if bands == 0 {
            return;
        }
        self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if bands == 1 || self.workers.is_empty() {
            for band in 0..bands.min(self.threads()) {
                job(band);
            }
            return;
        }
        dispatch(&*self.shared, self.workers.len(), bands.min(self.threads()), job);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        signal_shutdown(&*self.shared);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_band_exactly_once() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = [const { AtomicUsize::new(0) }; 4];
        for _ in 0..100 {
            pool.run(4, &|band| {
                hits[band].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 100);
        }
        assert_eq!(pool.dispatches(), 100, "one dispatch counted per run()");
    }

    #[test]
    fn bands_beyond_width_are_clamped_and_small_dispatches_inline() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2, "bands clamp to pool width");
        pool.run(1, &|band| {
            assert_eq!(band, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        pool.run(0, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(3, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1, "no workers: only band 0 runs");
    }

    /// Every banding mode must assign each row to exactly one band —
    /// contiguous/interleaved by arithmetic, dynamic via the shared
    /// cursor — including ragged row counts that don't divide evenly.
    #[test]
    fn every_banding_mode_covers_each_row_exactly_once() {
        for rows in [1usize, 2, 5, 7, 16, 33] {
            for bands in [1usize, 2, 3, 4] {
                for banding in [
                    Banding::Contiguous,
                    Banding::Interleaved,
                    Banding::Dynamic { chunk: 1 },
                    Banding::Dynamic { chunk: 2 },
                    Banding::Dynamic { chunk: 5 },
                    // chunk 0 must behave as chunk 1, not spin forever
                    Banding::Dynamic { chunk: 0 },
                ] {
                    let cursor = AtomicUsize::new(0);
                    let mut hits = vec![0usize; rows];
                    for band in 0..bands {
                        banding.for_band_rows(band, bands, rows, &cursor, |r| {
                            hits[r] += 1;
                        });
                    }
                    assert!(
                        hits.iter().all(|&h| h == 1),
                        "{banding:?} rows={rows} bands={bands}: hits {hits:?}"
                    );
                }
            }
        }
    }

    /// Dynamic dequeue through real pool workers: concurrent bands pull
    /// from one cursor and still cover every row exactly once.
    #[test]
    fn dynamic_banding_covers_rows_across_pool_workers() {
        let pool = WorkerPool::new(4);
        let rows = 103usize;
        let hits: Vec<AtomicUsize> = (0..rows).map(|_| AtomicUsize::new(0)).collect();
        let cursor = AtomicUsize::new(0);
        pool.run(4, &|band| {
            Banding::Dynamic { chunk: 3 }.for_band_rows(band, 4, rows, &cursor, |r| {
                hits[r].fetch_add(1, Ordering::Relaxed);
            });
        });
        for (r, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "row {r} visited wrong count");
        }
    }

    #[test]
    fn results_are_written_from_worker_threads() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 9];
        let base = out.as_mut_ptr() as usize;
        pool.run(3, &|band| {
            for i in 0..3 {
                // Disjoint windows per band, same shape the kernels use.
                unsafe { *(base as *mut usize).add(band * 3 + i) = band * 10 + i };
            }
        });
        assert_eq!(out, vec![0, 1, 2, 10, 11, 12, 20, 21, 22]);
    }

    /// A worker-band panic must re-raise on the dispatcher *after* the
    /// epoch completes, and the pool must stay usable for the next
    /// dispatch (the model checker proves this under every interleaving;
    /// this pins the production substrate end-to-end).
    #[test]
    fn worker_panic_reraises_and_pool_survives() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(3, &|band| {
                hits.fetch_add(1, Ordering::Relaxed);
                if band == 1 {
                    panic!("injected band panic");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the dispatcher");
        assert_eq!(hits.load(Ordering::Relaxed), 3, "all bands ran despite the panic");
        // The next dispatch starts clean.
        pool.run(3, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 6);
    }
}
