//! Typed engine specification: the (layout, schedule, precision, engine)
//! quadruple every lookup and every serving configuration is keyed by.
//!
//! The quadruple used to travel as four free-form `String`s (manifest
//! lookups, `ServeConfig`, bench combos, CLI flags), which meant a typo'd
//! `"spatial-pack"` surfaced as a "no bundle" error at serving time.
//! [`EngineSpec`] closes the set: each axis is an enum with `Display`/
//! `FromStr` that round-trip the exact strings the artifact manifest and
//! the CLI use, so parsing fails loudly at the boundary and everything
//! past it is type-checked.

use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, Error};

/// Activation memory layout of the model variant.
///
/// `Nchwc` is the channel-blocked packed layout (TVM's `NCHW{c}c`,
/// oneDNN's `nChwXc`); the tag doesn't carry the block width — that is an
/// engine detail (the native arena factory packs with
/// [`crate::executor::factory::ARENA_PACK_BLOCK`]).  Packed models keep
/// their *input* in plain NCHW (the 3-channel stem is never blocked), so
/// clients feed NCHW images to both `NCHW` and `NCHWc` variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayoutTag {
    Nchw,
    Nhwc,
    Nchwc,
}

impl LayoutTag {
    pub fn as_str(self) -> &'static str {
        match self {
            LayoutTag::Nchw => "NCHW",
            LayoutTag::Nhwc => "NHWC",
            LayoutTag::Nchwc => "NCHWc",
        }
    }
}

/// Conv schedule family (the paper's Table-2 axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// Naive loops — the eager/PyTorch stand-in.
    Reference,
    /// TVM's NCHW spatial-pack default (best int8 schedule).
    SpatialPack,
    /// vmlal-class vector schedule (no alter-layout).
    Simd,
    /// MMLA-class interleaved NHWC schedule.
    Interleaved,
    /// The native arena engine plans its own schedule (fusion + static
    /// arena); the axis is recorded for display but selects nothing.
    Native,
    /// The native arena engine under **autotuned** schedule overrides
    /// (`crate::tune`): banding / band-cap / lane-strategy knobs loaded
    /// from a persisted records file (`--tuned records.json`).
    Tuned,
}

impl Schedule {
    pub fn as_str(self) -> &'static str {
        match self {
            Schedule::Reference => "reference",
            Schedule::SpatialPack => "spatial_pack",
            Schedule::Simd => "simd",
            Schedule::Interleaved => "interleaved",
            Schedule::Native => "native",
            Schedule::Tuned => "tuned",
        }
    }
}

/// Numeric precision of the lowered model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Int8,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
        }
    }
}

/// Which executor tier serves the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// One fused AOT HLO module over PJRT (artifact-backed).
    Graph,
    /// Relay-VM-style bytecode over per-primitive AOT modules
    /// (artifact-backed; the paper's bug).
    Vm,
    /// The native in-process IR engine (`ArenaExec`) — no artifacts.
    Arena,
}

impl EngineKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Graph => "graph",
            EngineKind::Vm => "vm",
            EngineKind::Arena => "arena",
        }
    }

    /// Whether engines of this kind are built from AOT artifacts (vs
    /// compiled natively from the in-process graph IR).
    pub fn needs_artifacts(self) -> bool {
        !matches!(self, EngineKind::Arena)
    }
}

macro_rules! display_fromstr {
    ($ty:ident, $($tok:literal => $variant:expr),+ $(,)?) => {
        impl fmt::Display for $ty {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str(self.as_str())
            }
        }

        impl FromStr for $ty {
            type Err = Error;

            fn from_str(s: &str) -> Result<Self, Error> {
                match s {
                    $($tok => Ok($variant),)+
                    other => Err(anyhow!(
                        "unknown {} {:?} (expected one of: {})",
                        stringify!($ty),
                        other,
                        [$($tok),+].join(" ")
                    )),
                }
            }
        }
    };
}

display_fromstr!(
    LayoutTag,
    "NCHW" => LayoutTag::Nchw,
    "NHWC" => LayoutTag::Nhwc,
    "NCHWc" => LayoutTag::Nchwc,
);
display_fromstr!(
    Schedule,
    "reference" => Schedule::Reference,
    "spatial_pack" => Schedule::SpatialPack,
    "simd" => Schedule::Simd,
    "interleaved" => Schedule::Interleaved,
    "native" => Schedule::Native,
    "tuned" => Schedule::Tuned,
);
display_fromstr!(Precision, "fp32" => Precision::Fp32, "int8" => Precision::Int8);
display_fromstr!(
    EngineKind,
    "graph" => EngineKind::Graph,
    "vm" => EngineKind::Vm,
    "arena" => EngineKind::Arena,
);

/// The typed model-variant selector: which layout/schedule/precision
/// variant runs under which executor tier.
///
/// Construct with the builder (`EngineSpec::new(kind).precision(...)`) or
/// parse the canonical `"NCHW/spatial_pack/int8/graph"` form produced by
/// `Display` — the two round-trip exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EngineSpec {
    pub layout: LayoutTag,
    pub schedule: Schedule,
    pub precision: Precision,
    pub engine: EngineKind,
}

impl EngineSpec {
    /// Start from the defaults the paper's best configuration uses
    /// (NCHW / spatial_pack / int8) under the given engine.  The arena
    /// engine gets the `native` schedule tag — it plans its own.
    pub fn new(engine: EngineKind) -> Self {
        EngineSpec {
            layout: LayoutTag::Nchw,
            schedule: if engine == EngineKind::Arena {
                Schedule::Native
            } else {
                Schedule::SpatialPack
            },
            precision: Precision::Int8,
            engine,
        }
    }

    pub fn layout(mut self, layout: LayoutTag) -> Self {
        self.layout = layout;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::new(EngineKind::Graph)
    }
}

impl fmt::Display for EngineSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}/{}/{}/{}",
            self.layout, self.schedule, self.precision, self.engine
        )
    }
}

impl FromStr for EngineSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self, Error> {
        let parts: Vec<&str> = s.split('/').collect();
        let [layout, schedule, precision, engine] = parts.as_slice() else {
            return Err(anyhow!(
                "engine spec {s:?} is not LAYOUT/SCHEDULE/PRECISION/ENGINE \
                 (e.g. NCHW/spatial_pack/int8/graph)"
            ));
        };
        Ok(EngineSpec {
            layout: layout.parse()?,
            schedule: schedule.parse()?,
            precision: precision.parse()?,
            engine: engine.parse()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_display_fromstr_round_trips() {
        for layout in [LayoutTag::Nchw, LayoutTag::Nhwc, LayoutTag::Nchwc] {
            for schedule in [
                Schedule::Reference,
                Schedule::SpatialPack,
                Schedule::Simd,
                Schedule::Interleaved,
                Schedule::Native,
                Schedule::Tuned,
            ] {
                for precision in [Precision::Fp32, Precision::Int8] {
                    for engine in [EngineKind::Graph, EngineKind::Vm, EngineKind::Arena] {
                        let spec = EngineSpec { layout, schedule, precision, engine };
                        let back: EngineSpec = spec.to_string().parse().unwrap();
                        assert_eq!(spec, back);
                    }
                }
            }
        }
    }

    #[test]
    fn axis_tokens_match_manifest_vocabulary() {
        // These exact strings are what the python compile path writes into
        // manifest.json; the enum parse must accept them verbatim.
        assert_eq!("NCHW".parse::<LayoutTag>().unwrap(), LayoutTag::Nchw);
        assert_eq!("NCHWc".parse::<LayoutTag>().unwrap(), LayoutTag::Nchwc);
        assert_eq!("spatial_pack".parse::<Schedule>().unwrap(), Schedule::SpatialPack);
        assert_eq!("interleaved".parse::<Schedule>().unwrap(), Schedule::Interleaved);
        assert_eq!("int8".parse::<Precision>().unwrap(), Precision::Int8);
        assert_eq!("vm".parse::<EngineKind>().unwrap(), EngineKind::Vm);
    }

    #[test]
    fn unknown_tokens_are_rejected_with_the_valid_set() {
        let err = "spatial-pack".parse::<Schedule>().unwrap_err().to_string();
        assert!(err.contains("spatial_pack"), "error should list valid tokens: {err}");
        assert!("NCHW/int8/graph".parse::<EngineSpec>().is_err(), "arity check");
        assert!("NCHW/spatial_pack/int8/jit".parse::<EngineSpec>().is_err());
    }

    #[test]
    fn builder_defaults_track_the_engine_kind() {
        let g = EngineSpec::new(EngineKind::Graph);
        assert_eq!(g.schedule, Schedule::SpatialPack);
        let a = EngineSpec::new(EngineKind::Arena);
        assert_eq!(a.schedule, Schedule::Native);
        let custom = EngineSpec::new(EngineKind::Graph)
            .layout(LayoutTag::Nhwc)
            .schedule(Schedule::Interleaved)
            .precision(Precision::Fp32);
        assert_eq!(custom.to_string(), "NHWC/interleaved/fp32/graph");
    }
}
