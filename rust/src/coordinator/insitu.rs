//! In-situ tuned-engine hot-swap: upgrade serving engines *while the
//! server is live*, without dropping, double-serving, or corrupting a
//! single request.
//!
//! The protocol is deliberately minimal:
//!
//! - A background producer (typically [`spawn_insitu_tuner`], or a test
//!   harness) publishes an [`EngineUpgrade`] into the shared
//!   [`UpgradeSlot`].  An upgrade carries a *builder closure*, not an
//!   engine: executors may be `!Send` (PJRT handles, `RefCell` arenas),
//!   so the engine itself is always constructed **on the worker thread
//!   that will run it**.
//! - Every coordinator worker polls the slot's generation counter at the
//!   top of its batch loop — i.e. strictly **between** batches.  On a
//!   bump it rebuilds the affected bucket engines in place and tags them
//!   with the upgrade's generation.
//!
//! Because the swap happens only at batch boundaries, every request is
//! gathered, executed, and replied to by exactly one engine generation —
//! there is no window where a half-swapped engine can see a batch.  The
//! fault-injected test in `tests/insitu_swap.rs` drives live client load
//! through a swap (including deliberately failing and wrong-batch
//! upgrade builds) and proves served logits stay bit-identical to the
//! interpreter oracle throughout.
//!
//! Publication ordering: [`UpgradeSlot::publish`] inserts the upgrade
//! into the bucket map *before* bumping the generation counter with
//! `Release`; workers read the counter with `Acquire` before touching
//! the map, so a bumped counter always observes the fully-inserted
//! upgrade.  A failed build keeps the old engine serving (and the worker
//! records the generation so it does not retry a known-bad build every
//! batch).
//!
//! The tuner side ([`spawn_insitu_tuner`]) runs the oracle-gated
//! [`crate::tune`] search over each live bucket graph and publishes only
//! configs that are **strictly better** than the measured default — and
//! every candidate it measures already passed the measurer's bit-for-bit
//! oracle gate, so a hot-swapped engine can change latency but never
//! bytes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::cache::{CacheKey, CompileCache};
use crate::executor::{ArenaExec, EngineFactory, Executor, NativeArenaFactory};
use crate::graph::{calibrate_ir, compile_graph_with};
use crate::telem::{CounterId, Telemetry};
use crate::tune::{tune_graph, TuneOptions};

/// A published engine replacement for one serving bucket.
///
/// The engine is *not* built at publish time — `build` runs on each
/// worker's own thread (executors may be `!Send`), once per worker that
/// adopts the upgrade.
pub struct EngineUpgrade {
    /// The bucket batch size this upgrade replaces the engine for.
    pub bucket: usize,
    /// Slot-assigned, strictly increasing across all publishes.
    pub generation: u64,
    /// Measured speed of the upgraded config (whole-plan ns/iter).
    pub ns_per_iter: f64,
    /// Measured speed of the default schedule it beat.
    pub baseline_ns: f64,
    /// Human-readable description for logs.
    pub describe: String,
    build: Box<dyn Fn() -> Result<Box<dyn Executor>> + Send + Sync>,
}

impl EngineUpgrade {
    /// Construct the upgraded engine — called on the adopting worker's
    /// thread.  Errors leave the worker's current engine serving.
    pub fn build_engine(&self) -> Result<Box<dyn Executor>> {
        (self.build)()
    }
}

/// The shared mailbox between upgrade producers and coordinator workers:
/// the latest upgrade per bucket, plus a generation counter workers can
/// poll without taking the lock.
#[derive(Default)]
pub struct UpgradeSlot {
    generation: AtomicU64,
    latest: Mutex<HashMap<usize, Arc<EngineUpgrade>>>,
}

impl UpgradeSlot {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// The latest published generation (0 = nothing published).  Workers
    /// poll this between batches; `Acquire` pairs with the `Release` bump
    /// in [`UpgradeSlot::publish`] so a changed counter guarantees the
    /// map insert is visible.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Publish a replacement engine for `bucket`; returns the assigned
    /// generation.  A later publish for the same bucket supersedes the
    /// earlier one — workers only ever adopt the latest.
    pub fn publish(
        &self,
        bucket: usize,
        ns_per_iter: f64,
        baseline_ns: f64,
        describe: String,
        build: Box<dyn Fn() -> Result<Box<dyn Executor>> + Send + Sync>,
    ) -> u64 {
        let mut latest = self.latest.lock().unwrap_or_else(|p| p.into_inner());
        // Serialized by the map lock: generation assignment and insertion
        // happen atomically with respect to other publishers, and the
        // counter bump below is the last thing a publish does.
        let generation = self.generation.load(Ordering::Relaxed) + 1;
        latest.insert(
            bucket,
            Arc::new(EngineUpgrade { bucket, generation, ns_per_iter, baseline_ns, describe, build }),
        );
        self.generation.store(generation, Ordering::Release);
        generation
    }

    /// The latest upgrade for one bucket, if any.
    pub fn latest_for(&self, bucket: usize) -> Option<Arc<EngineUpgrade>> {
        self.latest.lock().unwrap_or_else(|p| p.into_inner()).get(&bucket).cloned()
    }

    /// Every bucket's latest upgrade (diagnostics / tests).
    pub fn snapshot(&self) -> Vec<Arc<EngineUpgrade>> {
        let mut v: Vec<_> =
            self.latest.lock().unwrap_or_else(|p| p.into_inner()).values().cloned().collect();
        v.sort_by_key(|u| u.bucket);
        v
    }
}

/// Tune every bucket of a live [`NativeArenaFactory`] in the background
/// and hot-swap strictly-better verified configs into the serving tier.
///
/// For each bucket (smallest first, so the cheapest wins land soonest)
/// the tuner re-derives the exact graph the serving engine compiled
/// (`factory.graph(b)`), runs the budgeted oracle-gated search, and — only
/// when the winner measured strictly faster than the default schedule —
/// compiles the winning config **once** into a [`CompiledGraph`] and
/// publishes an upgrade whose builder clones it per adopting worker
/// (`ArenaExec::from_compiled` — zero compiler calls on the worker).
/// Tuned programs are also stored into `cache` when one is attached, so
/// the *next* cold start warm-starts straight into the tuned schedule.
///
/// The returned handle joins when every bucket has been processed; the
/// server keeps serving (old engines) throughout and adopts upgrades at
/// its own batch boundaries.
pub fn spawn_insitu_tuner(
    factory: Arc<NativeArenaFactory>,
    slot: Arc<UpgradeSlot>,
    opts: TuneOptions,
    cache: Option<Arc<CompileCache>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("tvmq-insitu-tuner".into())
        .spawn(move || {
            for b in EngineFactory::buckets(&*factory) {
                if let Err(e) = retune_bucket(&factory, &slot, &opts, cache.as_deref(), b) {
                    eprintln!("tvmq: insitu: bucket {b}: tuning failed (engine unchanged): {e:#}");
                }
            }
        })
        .expect("spawn insitu tuner thread")
}

/// Run one oracle-gated tuning pass over `bucket`'s live graph and
/// publish a hot-swap upgrade when (and only when) the winner measured
/// strictly faster than the default schedule.  Returns whether an
/// upgrade was published.  Shared by the one-shot startup tuner
/// ([`spawn_insitu_tuner`]) and the drift-driven re-tuner
/// ([`spawn_drift_retuner`]).
pub fn retune_bucket(
    factory: &NativeArenaFactory,
    slot: &UpgradeSlot,
    opts: &TuneOptions,
    cache: Option<&CompileCache>,
    bucket: usize,
) -> Result<bool> {
    let g = factory.graph(bucket)?;
    let x = calibrate_ir(&g, opts.seed);
    let mut opts = *opts;
    opts.threads = factory.threads();
    let outcome = tune_graph(&g, x, &opts)?;
    if outcome.best.ns_per_iter >= outcome.default_ns {
        eprintln!(
            "tvmq: insitu: bucket {bucket}: default schedule already best \
             ({:.0} ns/iter) — no swap",
            outcome.default_ns
        );
        return Ok(false);
    }
    let fuse = outcome.best.plan.fuse;
    let ovr = outcome.best.plan.overrides(opts.threads);
    // Compile the winner once; adopting workers clone the program and
    // wrap it without re-running the compiler.
    let cg = compile_graph_with(&g, fuse, &ovr)?;
    if let Some(cache) = cache {
        let key = CacheKey::of(&g, &ovr, fuse, opts.threads);
        if let Err(e) = cache.store(&key, &cg) {
            eprintln!("tvmq: insitu: bucket {bucket}: could not cache tuned program: {e:#}");
        }
    }
    let threads = opts.threads;
    let describe = format!(
        "bucket {bucket}: {} ({:.0} -> {:.0} ns/iter, {:.1}%)",
        outcome.best.plan.describe(),
        outcome.default_ns,
        outcome.best.ns_per_iter,
        outcome.improvement_pct()
    );
    eprintln!("tvmq: insitu: publishing upgrade — {describe}");
    let cg_for_build = cg;
    slot.publish(
        bucket,
        outcome.best.ns_per_iter,
        outcome.default_ns,
        describe,
        Box::new(move || {
            Ok(Box::new(ArenaExec::from_compiled(cg_for_build.clone(), threads)?)
                as Box<dyn Executor>)
        }),
    );
    Ok(true)
}

/// Continuous re-tuning, driven by serving-latency drift: a background
/// thread that waits for the telemetry spine's [`Telemetry`] drift
/// detector to arm a re-tune request (sustained latency regression vs
/// the frozen baseline window) and then runs [`retune_bucket`] passes.
///
/// Bucket order comes from live traffic: the shape recorder's tasks,
/// hottest first — so the re-tune budget lands on the shapes production
/// actually serves (the "per-shape tuning task" feed).  Buckets never
/// observed (yet) fall back to the factory's full bucket list.  Each
/// completed pass bumps the `retune_passes` counter; requests arriving
/// *while* a pass runs coalesce into one follow-up pass (the detector
/// re-baselines on trigger, so a fixed regression does not re-fire).
///
/// The thread exits when `stop` is raised.  It polls at a coarse
/// interval — drift is a minutes-scale signal, not a hot path.
pub fn spawn_drift_retuner(
    factory: Arc<NativeArenaFactory>,
    slot: Arc<UpgradeSlot>,
    opts: TuneOptions,
    cache: Option<Arc<CompileCache>>,
    telem: Arc<Telemetry>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("tvmq-drift-retuner".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if !telem.take_retune_request() {
                    std::thread::sleep(Duration::from_millis(50));
                    continue;
                }
                let buckets = retune_order(&factory, &telem);
                eprintln!(
                    "tvmq: insitu: latency drift detected — re-tuning buckets {buckets:?}"
                );
                for b in buckets {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    match retune_bucket(&factory, &slot, &opts, cache.as_deref(), b) {
                        Ok(_) => telem.registry.count(CounterId::RetunePasses, 1),
                        Err(e) => eprintln!(
                            "tvmq: insitu: bucket {b}: drift re-tune failed \
                             (engine unchanged): {e:#}"
                        ),
                    }
                }
            }
        })
        .expect("spawn drift retuner thread")
}

/// Buckets to re-tune, hottest-traffic first: the shape recorder's
/// observed buckets (by request count) filtered to buckets the factory
/// can actually build, then any factory buckets never seen in traffic.
fn retune_order(factory: &NativeArenaFactory, telem: &Telemetry) -> Vec<usize> {
    let known = EngineFactory::buckets(factory);
    let mut order: Vec<usize> = Vec::with_capacity(known.len());
    for task in telem.shapes.tasks() {
        if known.contains(&task.batch) && !order.contains(&task.batch) {
            order.push(task.batch);
        }
    }
    for b in known {
        if !order.contains(&b) {
            order.push(b);
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_bumps_generation_and_supersedes() {
        let slot = UpgradeSlot::new();
        assert_eq!(slot.generation(), 0);
        assert!(slot.latest_for(4).is_none());

        let g1 = slot.publish(4, 100.0, 200.0, "first".into(), Box::new(|| unreachable!()));
        assert_eq!(g1, 1);
        assert_eq!(slot.generation(), 1);
        assert_eq!(slot.latest_for(4).unwrap().describe, "first");

        let g2 = slot.publish(4, 90.0, 200.0, "second".into(), Box::new(|| unreachable!()));
        assert_eq!(g2, 2);
        // Same bucket: the later publish supersedes.
        assert_eq!(slot.latest_for(4).unwrap().describe, "second");
        assert_eq!(slot.snapshot().len(), 1);

        slot.publish(8, 50.0, 60.0, "other bucket".into(), Box::new(|| unreachable!()));
        assert_eq!(slot.generation(), 3);
        assert_eq!(slot.snapshot().len(), 2);
    }
}
