//! The sharded coordinator's bounded admission queue, written as a
//! checkable protocol over [`SyncOps`] — the same discipline the arena
//! pool's epoch protocol follows (PR 6's gate: new concurrency lands
//! with its checker scenario, not after it).
//!
//! ## Protocol
//!
//! Producers (client threads inside [`super::InferenceServer::submit`])
//! call [`q_push`]: if the queue is at its bound the request is **shed**
//! — counted, never enqueued, the caller gets a typed
//! `Rejected::Overloaded` — otherwise it is appended and exactly one
//! sleeping consumer is woken (`notify_one` on the work condvar; one
//! item needs one worker).  Consumers (serving workers) call [`q_pop`]:
//! take the head item, or sleep until one arrives or shutdown is
//! signalled.  [`q_shutdown`] sets the flag and wakes every consumer;
//! pops **drain remaining items first** and only then observe shutdown,
//! so accepted work is never silently dropped by a clean shutdown.
//!
//! The settle counters (`pushed`/`popped`/`shed`) make the whole flow
//! auditable: every offered item is eventually accounted as popped or
//! shed, which [`q_await_settled`] can wait on (the check scenarios'
//! closer thread does, turning a lost consumer wakeup into a scheduler-
//! convicted deadlock instead of a silent truncation).
//!
//! ## Substrates
//!
//! - [`StdQueue`]: production.  One futex-backed `Mutex<QState>` + two
//!   condvars; push/pop are allocation-free beyond the `VecDeque`'s
//!   steady-state ring (preallocated to the bound at construction).  It
//!   additionally offers [`StdQueue::pop_until`], the deadline-bounded
//!   pop the batch gather loop needs — *timing* is explicitly outside
//!   the model checker's scope (see `check`'s module docs; the fault
//!   layer covers stalls).
//! - `check::sched::ModelSync<QState>`: the model checker, which runs
//!   `q_push`/`q_pop`/`q_shutdown`/`q_await_settled` — this exact code —
//!   under exhaustively enumerated interleavings
//!   (`check::queue_model`, driven by `tests/queue_check.rs`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::executor::pool::{Cv, SyncOps, Wake};

/// The queue protocol's entire mutable state, always accessed under the
/// substrate's lock.
pub(crate) struct QState<T> {
    pub(crate) items: VecDeque<T>,
    /// Admission bound: `q_push` sheds instead of growing past it.
    pub(crate) bound: usize,
    pub(crate) shutdown: bool,
    /// Offered items rejected at the admission gate.
    pub(crate) shed: u64,
    /// Items accepted into the queue.
    pub(crate) pushed: u64,
    /// Items handed to a consumer.
    pub(crate) popped: u64,
    /// Someone is (or is about to be) waiting on the done condvar for
    /// the settle counters; pop/shed paths only pay a done-notify while
    /// this is set, keeping the steady-state serve path at one wake per
    /// push and zero per pop.
    pub(crate) done_watch: bool,
}

impl<T> QState<T> {
    pub(crate) fn new(bound: usize) -> Self {
        let bound = bound.max(1);
        QState {
            items: VecDeque::with_capacity(bound),
            bound,
            shutdown: false,
            shed: 0,
            pushed: 0,
            popped: 0,
            done_watch: false,
        }
    }
}

/// The drain hook for failing model-checker runs: shutting the queue
/// down is always safe (pops drain items first), so no part of it needs
/// the all-parked gate the pool's epoch counter does.
impl<T: Send + 'static> crate::check::sched::ProtoState for QState<T> {
    fn drain(&mut self, _all_parked: bool) {
        self.shutdown = true;
    }
}

/// What happened to one offered item at the admission gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PushOutcome {
    Accepted,
    /// Queue at bound: the item was counted and discarded.
    Shed { depth: usize },
    /// Shutdown already signalled: nothing will consume the item.
    Closed,
}

/// Offer one item.  Sheds (never blocks) when the queue is at bound —
/// backpressure by rejection, so a burst degrades into fast typed
/// errors instead of unbounded memory growth and unbounded latency.
pub(crate) fn q_push<T, S: SyncOps<St = QState<T>>>(sync: &S, item: T) -> PushOutcome {
    sync.locked(|q, w| {
        if q.shutdown {
            return PushOutcome::Closed;
        }
        if q.items.len() >= q.bound {
            q.shed += 1;
            if q.done_watch {
                w.notify_done_one();
            }
            return PushOutcome::Shed { depth: q.items.len() };
        }
        q.items.push_back(item);
        q.pushed += 1;
        debug_assert!(q.items.len() <= q.bound);
        w.notify_work_one();
        PushOutcome::Accepted
    })
}

/// Take the head item, sleeping until one arrives.  Returns `None` only
/// when the queue is shut down **and** empty: accepted work drains
/// before consumers go home.
pub(crate) fn q_pop<T, S: SyncOps<St = QState<T>>>(sync: &S) -> Option<T> {
    sync.locked_wait(Cv::Work, |q, w| {
        if let Some(item) = q.items.pop_front() {
            q.popped += 1;
            if q.done_watch {
                w.notify_done_one();
            }
            return Some(Some(item));
        }
        if q.shutdown {
            return Some(None);
        }
        None
    })
}

/// Signal shutdown and wake every sleeping consumer so each can drain
/// and exit.
pub(crate) fn q_shutdown<T, S: SyncOps<St = QState<T>>>(sync: &S) {
    sync.locked(|q, w| {
        q.shutdown = true;
        w.notify_work_all();
    });
}

/// Block until every one of `offered` items has settled — been popped or
/// shed.  The check scenarios' closer thread gates shutdown on this,
/// which is what makes a lost push wake *convictable*: a stranded
/// consumer means the counters never settle, the closer never closes,
/// and the scheduler reports a deadlock.
pub(crate) fn q_await_settled<T, S: SyncOps<St = QState<T>>>(sync: &S, offered: u64) {
    sync.locked_wait(Cv::Done, |q, _| {
        q.done_watch = true;
        (q.popped + q.shed >= offered).then_some(())
    })
}

// ---------------------------------------------------------------------------
// Production substrate
// ---------------------------------------------------------------------------

/// Result of a deadline-bounded pop (production gather loop only).
pub(crate) enum PopTimed<T> {
    Got(T),
    TimedOut,
    /// Shut down and drained: the consumer should process what it has
    /// and exit.
    Closed,
}

/// The production queue substrate: `Mutex<QState>` + work/done condvars,
/// mirroring `executor::pool::StdSync` (poison-recovering for the same
/// reason: a panicking worker must not poison admission for everyone
/// else — the state is plain counters plus jobs that are re-validated
/// downstream).
pub(crate) struct StdQueue<T> {
    state: Mutex<QState<T>>,
    work: Condvar,
    done: Condvar,
}

impl<T> StdQueue<T> {
    pub(crate) fn new(bound: usize) -> Self {
        StdQueue {
            state: Mutex::new(QState::new(bound)),
            work: Condvar::new(),
            done: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, QState<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn deliver(&self, w: &Wake) {
        if w.work_all {
            self.work.notify_all();
        } else if w.work_one {
            self.work.notify_one();
        }
        if w.done_one {
            self.done.notify_one();
        }
    }

    /// Deadline-bounded pop for the batch gather loop: an item, a
    /// drained shutdown, or the deadline — whichever comes first.
    pub(crate) fn pop_until(&self, deadline: Instant) -> PopTimed<T> {
        let mut g = self.lock();
        loop {
            if let Some(item) = g.items.pop_front() {
                g.popped += 1;
                let watch = g.done_watch;
                drop(g);
                if watch {
                    self.done.notify_one();
                }
                return PopTimed::Got(item);
            }
            if g.shutdown {
                return PopTimed::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimed::TimedOut;
            }
            g = self
                .work
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
    }

    /// Drop every queued item (the last-worker-death path): their reply
    /// channels close as the jobs drop, so blocked clients resolve with
    /// a disconnect error promptly instead of hanging on work nobody
    /// will ever serve.  Returns how many were purged.
    pub(crate) fn purge(&self) -> usize {
        let drained: Vec<T> = {
            let mut g = self.lock();
            g.items.drain(..).collect()
        };
        // Drop outside the lock: dropping a job sends nothing but may
        // run arbitrary channel teardown.
        drained.len()
    }

    /// Snapshot `(shed, current depth)` for stats reporting.
    pub(crate) fn shed_and_depth(&self) -> (u64, usize) {
        let g = self.lock();
        (g.shed, g.items.len())
    }
}

impl<T: Send> SyncOps for StdQueue<T> {
    type St = QState<T>;

    fn locked<R>(&self, f: impl FnOnce(&mut QState<T>, &mut Wake) -> R) -> R {
        let mut g = self.lock();
        let mut w = Wake::default();
        let r = f(&mut g, &mut w);
        drop(g);
        // Notify after release: waiters re-check under the lock, so late
        // delivery is safe and avoids waking into a held mutex.
        self.deliver(&w);
        r
    }

    fn locked_wait<R>(
        &self,
        cv: Cv,
        mut f: impl FnMut(&mut QState<T>, &mut Wake) -> Option<R>,
    ) -> R {
        let mut g = self.lock();
        loop {
            let mut w = Wake::default();
            let r = f(&mut g, &mut w);
            // Deliver while holding the lock — the sleep below must not
            // open a window between f's state change and its wakes.
            self.deliver(&w);
            match r {
                Some(r) => return r,
                None => {
                    let cv = match cv {
                        Cv::Work => &self.work,
                        Cv::Done => &self.done,
                    };
                    g = cv.wait(g).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn push_pop_preserves_order_and_counters() {
        let q: StdQueue<usize> = StdQueue::new(4);
        for i in 0..3 {
            assert_eq!(q_push(&q, i), PushOutcome::Accepted);
        }
        for i in 0..3 {
            assert_eq!(q_pop(&q), Some(i));
        }
        let g = q.lock();
        assert_eq!((g.pushed, g.popped, g.shed), (3, 3, 0));
    }

    #[test]
    fn push_past_bound_sheds_instead_of_growing() {
        let q: StdQueue<usize> = StdQueue::new(2);
        assert_eq!(q_push(&q, 0), PushOutcome::Accepted);
        assert_eq!(q_push(&q, 1), PushOutcome::Accepted);
        assert_eq!(q_push(&q, 2), PushOutcome::Shed { depth: 2 });
        assert_eq!(q.shed_and_depth(), (1, 2));
        // Popping opens a slot again.
        assert_eq!(q_pop(&q), Some(0));
        assert_eq!(q_push(&q, 3), PushOutcome::Accepted);
    }

    #[test]
    fn shutdown_drains_remaining_items_then_closes() {
        let q: StdQueue<usize> = StdQueue::new(4);
        q_push(&q, 7);
        q_shutdown(&q);
        assert_eq!(q_push(&q, 8), PushOutcome::Closed);
        assert_eq!(q_pop(&q), Some(7), "accepted work drains before close");
        assert_eq!(q_pop(&q), None);
    }

    #[test]
    fn pop_until_times_out_on_an_empty_queue() {
        let q: StdQueue<usize> = StdQueue::new(4);
        let t0 = Instant::now();
        match q.pop_until(t0 + Duration::from_millis(5)) {
            PopTimed::TimedOut => {}
            _ => panic!("empty queue must time out"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn sleeping_consumer_is_woken_by_a_push() {
        let q: Arc<StdQueue<usize>> = Arc::new(StdQueue::new(4));
        let qc = Arc::clone(&q);
        let h = std::thread::spawn(move || q_pop(&*qc));
        // Give the consumer a moment to park, then push.
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q_push(&*q, 42), PushOutcome::Accepted);
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn await_settled_accounts_pops_and_sheds() {
        let q: Arc<StdQueue<usize>> = Arc::new(StdQueue::new(1));
        assert_eq!(q_push(&*q, 0), PushOutcome::Accepted);
        assert!(matches!(q_push(&*q, 1), PushOutcome::Shed { .. }));
        let qc = Arc::clone(&q);
        let h = std::thread::spawn(move || q_await_settled(&*qc, 2));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(q_pop(&*q), Some(0));
        h.join().unwrap();
    }
}
