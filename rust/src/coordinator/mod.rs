//! Inference coordinator: request queue → dynamic batcher → executor worker.
//!
//! The serving layer that hosts the paper's memory-bound experiments
//! (Table 3) as a real system: clients submit single images; the batcher
//! gathers them under a max-batch/timeout policy and routes each batch to
//! the executor compiled for the smallest fitting **bucket** (XLA modules
//! are static-shaped, so the AOT path emits one per batch size — vLLM-style
//! bucket batching).
//!
//! PJRT handles are `!Send`, so the runtime and executors live on one
//! dedicated worker thread; clients talk to it over channels and get their
//! replies via oneshot.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::executor::{Executor, GraphExecutor, VmExecutor};
use crate::manifest::Manifest;
use crate::metrics::EpochStats;
use crate::runtime::{Runtime, TensorData};

/// Which model variant the server runs, plus batching policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub layout: String,
    pub schedule: String,
    pub precision: String,
    pub executor: String,
    /// Upper bound on gathered batch size (clamped to largest bucket).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            layout: "NCHW".into(),
            schedule: "spatial_pack".into(),
            precision: "int8".into(),
            executor: "graph".into(),
            max_batch: 64,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// One inference reply.
#[derive(Debug, Clone)]
pub struct InferenceReply {
    pub logits: TensorData,
    pub class: usize,
    /// Batch size the request was served in (bucket).
    pub batch: usize,
    pub latency: Duration,
}

/// One-shot reply channel (std-based; the offline build has no tokio).
type ReplyTx = std::sync::mpsc::SyncSender<Result<InferenceReply>>;

/// A pending reply: wait on it to get the inference result.
pub struct PendingReply(std::sync::mpsc::Receiver<Result<InferenceReply>>);

impl PendingReply {
    pub fn wait(self) -> Result<InferenceReply> {
        self.0.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<InferenceReply> {
        self.0
            .recv_timeout(d)
            .map_err(|_| anyhow!("timed out or server dropped request"))?
    }
}

struct Job {
    image: TensorData,
    enqueued: Instant,
    reply: ReplyTx,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub batch_histogram: std::collections::BTreeMap<usize, u64>,
    pub latencies_ms: Vec<f64>,
    pub padded_slots: u64,
}

impl ServerStats {
    pub fn latency_stats(&self) -> EpochStats {
        EpochStats::from_samples(&self.latencies_ms, 0)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total: u64 = self
            .batch_histogram
            .iter()
            .map(|(b, n)| *b as u64 * n)
            .sum();
        total as f64 / self.batches as f64
    }
}

pub struct InferenceServer {
    tx: std::sync::mpsc::Sender<Msg>,
    stats: Arc<Mutex<ServerStats>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    pub buckets: Vec<usize>,
}

impl InferenceServer {
    /// Start the worker thread: loads the manifest, compiles the bucket
    /// executors, then serves until shutdown.
    pub fn start(artifacts: std::path::PathBuf, cfg: ServeConfig) -> Result<Self> {
        let manifest = Manifest::load(&artifacts)?;
        let buckets =
            manifest.batch_buckets(&cfg.layout, &cfg.schedule, &cfg.precision, &cfg.executor);
        if buckets.is_empty() {
            return Err(anyhow!(
                "no bundles for {}/{}/{} {}",
                cfg.layout, cfg.schedule, cfg.precision, cfg.executor
            ));
        }
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let (tx, rx) = std::sync::mpsc::channel::<Msg>();
        let worker_stats = stats.clone();
        let worker_buckets = buckets.clone();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("tvmq-worker".into())
            .spawn(move || {
                worker_loop(manifest, cfg, worker_buckets, rx, worker_stats, ready_tx)
            })
            .map_err(|e| anyhow!("spawning worker: {e}"))?;
        // Wait for executor compilation so `submit` never races startup.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;
        Ok(Self { tx, stats, handle: Some(handle), buckets })
    }

    /// Fire-and-wait-later submit: enqueue the image, get a pending reply.
    pub fn submit(&self, image: TensorData) -> Result<PendingReply> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Msg::Job(Job { image, enqueued: Instant::now(), reply }))
            .map_err(|_| anyhow!("server is down"))?;
        Ok(PendingReply(rx))
    }

    /// Submit and wait (for simple callers and benches).
    pub fn submit_blocking(&self, image: TensorData) -> Result<InferenceReply> {
        self.submit(image)?.wait()
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().expect("stats lock").clone()
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn build_executor(
    rt: std::rc::Rc<Runtime>,
    manifest: &Manifest,
    cfg: &ServeConfig,
    batch: usize,
) -> Result<Box<dyn Executor>> {
    let bundle = manifest.find(
        &cfg.layout, &cfg.schedule, &cfg.precision, batch, &cfg.executor,
    )?;
    Ok(match cfg.executor.as_str() {
        "graph" => Box::new(GraphExecutor::new(rt, manifest, bundle)?),
        "vm" => Box::new(VmExecutor::new(rt, manifest, bundle)?),
        other => return Err(anyhow!("unknown executor {other:?}")),
    })
}

fn worker_loop(
    manifest: Manifest,
    cfg: ServeConfig,
    buckets: Vec<usize>,
    rx: std::sync::mpsc::Receiver<Msg>,
    stats: Arc<Mutex<ServerStats>>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) -> Result<()> {
    // Compile every bucket executor up front (startup, not request path).
    let rt = match Runtime::new() {
        Ok(rt) => std::rc::Rc::new(rt),
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e}")));
            return Err(e);
        }
    };
    let mut executors: Vec<(usize, Box<dyn Executor>)> = Vec::new();
    for &b in &buckets {
        match build_executor(rt.clone(), &manifest, &cfg, b) {
            Ok(e) => executors.push((b, e)),
            Err(e) => {
                let _ = ready.send(Err(anyhow!("{e}")));
                return Err(e);
            }
        }
    }
    let _ = ready.send(Ok(()));

    let max_bucket = *buckets.last().expect("non-empty buckets");
    let max_batch = cfg.max_batch.min(max_bucket).max(1);

    'serve: loop {
        // Block for the first job.
        let first = match rx.recv() {
            Ok(Msg::Job(j)) => j,
            Ok(Msg::Shutdown) | Err(_) => break 'serve,
        };
        let mut jobs = vec![first];
        // Gather until the batch fills or the timeout expires.
        let deadline = Instant::now() + cfg.batch_timeout;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => jobs.push(j),
                Ok(Msg::Shutdown) => {
                    process_batch(&executors, &buckets, jobs, &stats);
                    break 'serve;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    process_batch(&executors, &buckets, jobs, &stats);
                    break 'serve;
                }
            }
        }
        process_batch(&executors, &buckets, jobs, &stats);
    }
    Ok(())
}

fn process_batch(
    executors: &[(usize, Box<dyn Executor>)],
    buckets: &[usize],
    jobs: Vec<Job>,
    stats: &Arc<Mutex<ServerStats>>,
) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    // Smallest bucket that fits; if none (shouldn't happen: max_batch is
    // clamped), fall back to the largest.
    let bucket = buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .unwrap_or_else(|| *buckets.last().expect("buckets"));
    let exec = &executors
        .iter()
        .find(|(b, _)| *b == bucket)
        .expect("bucket executor")
        .1;

    let run = (|| -> Result<Vec<TensorData>> {
        let imgs: Vec<&TensorData> = jobs.iter().map(|j| &j.image).collect();
        let stacked = TensorData::stack(&imgs)?;
        let padded = stacked.pad_rows(bucket)?;
        let out = exec.run(&padded)?;
        let logits = out.truncate_rows(n)?;
        logits.split_rows(1)
    })();

    match run {
        Ok(per_job) => {
            let mut s = stats.lock().expect("stats lock");
            s.requests += n as u64;
            s.batches += 1;
            *s.batch_histogram.entry(bucket).or_insert(0) += 1;
            s.padded_slots += (bucket - n) as u64;
            for (job, logits) in jobs.into_iter().zip(per_job) {
                let latency = job.enqueued.elapsed();
                s.latencies_ms.push(latency.as_secs_f64() * 1e3);
                let class = logits.argmax_last().map(|v| v[0]).unwrap_or(0);
                let _ = job.reply.send(Ok(InferenceReply {
                    logits,
                    class,
                    batch: bucket,
                    latency,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e}");
            for job in jobs {
                let _ = job.reply.send(Err(anyhow!("batch failed: {msg}")));
            }
        }
    }
}
