//! Inference coordinator: request queue → dynamic batcher → bucket engines.
//!
//! The serving layer that hosts the paper's memory-bound experiments
//! (Table 3) as a real system: clients submit single images; the batcher
//! gathers them under a max-batch/timeout policy and routes each batch to
//! the engine compiled for the smallest fitting **bucket** (both XLA
//! modules and arena plans are static-shaped, so there is one compiled
//! engine per batch size — vLLM-style bucket batching).
//!
//! Engines come from an [`EngineFactory`], not from the coordinator
//! itself: [`InferenceServer::start_with`] accepts any factory, so the
//! same batcher serves AOT PJRT bundles ([`ArtifactFactory`] via
//! [`InferenceServer::start`]) or natively compiled
//! [`crate::executor::ArenaExec`] engines
//! ([`crate::executor::NativeArenaFactory`]) — the latter needs no
//! artifacts at all, which is what makes `tvmq serve --executor arena`
//! work on the offline build.
//!
//! The worker pre-allocates one stacked input and one output tensor per
//! bucket at startup and serves every batch through
//! [`crate::executor::Executor::run_into`]; with arena engines the
//! request path therefore performs **zero heap allocations inside the
//! executor** (`tests/arena_alloc.rs` counts them).  PJRT handles are
//! `!Send`, so engines live on one dedicated worker thread; clients talk
//! to it over channels and get their replies via oneshot.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::executor::{ArtifactFactory, EngineFactory, EngineSpec, Executor};
use crate::manifest::Manifest;
use crate::metrics::EpochStats;
use crate::runtime::TensorData;
use crate::util::rng::Rng64;

/// Which model variant the server runs, plus batching policy.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// The typed variant selector (layout/schedule/precision/engine).
    pub spec: EngineSpec,
    /// Upper bound on gathered batch size (clamped to largest bucket).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            spec: EngineSpec::default(),
            max_batch: 64,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// Panic payload marking an *unrecoverable* worker failure.  The worker
/// converts ordinary engine panics into per-batch errors and keeps
/// serving; a panic carrying this marker is deliberately re-raised
/// instead, killing the worker thread — `check::fault` throws it
/// (`Fault::Die`) to prove the server-side handling of true worker death:
/// pending replies resolve with errors (never hang) and subsequent
/// submissions fail promptly.
#[derive(Debug, Clone, Copy)]
pub struct FatalFault;

/// Lock the stats mutex, recovering from poisoning: the stats are plain
/// monotone counters plus a reservoir — every update is complete the
/// moment it is made, so a panic elsewhere on the worker thread cannot
/// leave them torn, and propagating the poison would turn one engine
/// panic into a `stats()` panic for every later observer.
fn lock_stats(m: &Mutex<ServerStats>) -> MutexGuard<'_, ServerStats> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One inference reply.
#[derive(Debug, Clone)]
pub struct InferenceReply {
    pub logits: TensorData,
    pub class: usize,
    /// Batch size the request was served in (bucket).
    pub batch: usize,
    pub latency: Duration,
}

/// One-shot reply channel (std-based; the offline build has no tokio).
type ReplyTx = std::sync::mpsc::SyncSender<Result<InferenceReply>>;

/// A pending reply: wait on it to get the inference result.
pub struct PendingReply(std::sync::mpsc::Receiver<Result<InferenceReply>>);

impl PendingReply {
    pub fn wait(self) -> Result<InferenceReply> {
        self.0.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<InferenceReply> {
        self.0
            .recv_timeout(d)
            .map_err(|_| anyhow!("timed out or server dropped request"))?
    }
}

struct Job {
    image: TensorData,
    enqueued: Instant,
    reply: ReplyTx,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// Pick the smallest bucket that fits a gathered batch of `n`.
///
/// `buckets` must be sorted ascending (the server normalizes at startup).
/// Errors instead of silently over- or under-padding when nothing fits —
/// the gather loop clamps to the largest bucket, so hitting the error
/// from the serve path means the clamp itself regressed.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Result<usize> {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .ok_or_else(|| anyhow!("no bucket fits a batch of {n} (buckets: {buckets:?})"))
}

/// Bounded latency sample: exact up to [`LATENCY_RESERVOIR_CAP`] samples,
/// a uniform reservoir (Vitter's Algorithm R, deterministic seed) beyond
/// it — so a long-running server's stats stay O(cap) instead of growing
/// one `f64` per request forever.
#[derive(Debug, Clone)]
pub struct LatencyReservoir {
    samples: Vec<f64>,
    /// Total observations ever pushed (`samples` holds min(seen, cap)).
    seen: u64,
    rng: Rng64,
}

/// Reservoir size: percentiles are exact for runs up to this many
/// requests, and an unbiased uniform sample afterwards.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir {
            samples: Vec::new(),
            seen: 0,
            rng: Rng64::seed_from_u64(0x7a11_5eed),
        }
    }
}

impl LatencyReservoir {
    pub fn push(&mut self, ms: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(ms);
            return;
        }
        // Algorithm R: the i-th observation replaces a resident sample
        // with probability cap/i, keeping the reservoir uniform.
        let j = self.rng.next_u64() % self.seen;
        if (j as usize) < LATENCY_RESERVOIR_CAP {
            self.samples[j as usize] = ms;
        }
    }

    /// Observations ever recorded (not the resident sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn stats(&self) -> EpochStats {
        EpochStats::from_samples(&self.samples, 0)
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    /// Requests answered successfully.
    pub requests: u64,
    /// Requests answered with an error (batch failures).
    pub errors: u64,
    pub batches: u64,
    pub batch_histogram: std::collections::BTreeMap<usize, u64>,
    pub latencies: LatencyReservoir,
    pub padded_slots: u64,
}

impl ServerStats {
    pub fn latency_stats(&self) -> EpochStats {
        self.latencies.stats()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total: u64 = self
            .batch_histogram
            .iter()
            .map(|(b, n)| *b as u64 * n)
            .sum();
        total as f64 / self.batches as f64
    }
}

pub struct InferenceServer {
    tx: std::sync::mpsc::Sender<Msg>,
    stats: Arc<Mutex<ServerStats>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    /// Raised when the worker thread exits for any reason — normal
    /// shutdown, error return, or panic (a drop guard on the worker sets
    /// it even mid-unwind) — so `submit` fails promptly instead of
    /// enqueueing onto a dead server.
    down: Arc<AtomicBool>,
    pub buckets: Vec<usize>,
}

/// Sets the server's `down` flag when the worker thread exits, however
/// it exits (the `Drop` runs during unwind too).
struct DownGuard(Arc<AtomicBool>);

impl Drop for DownGuard {
    fn drop(&mut self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

impl InferenceServer {
    /// Artifact-backed start: load the manifest and serve `cfg.spec`
    /// through an [`ArtifactFactory`] (requires `make artifacts` + the
    /// real PJRT bridge).
    pub fn start(artifacts: std::path::PathBuf, cfg: ServeConfig) -> Result<Self> {
        let manifest = Manifest::load(&artifacts)?;
        let factory = ArtifactFactory::new(manifest, cfg.spec)?;
        Self::start_with(factory, cfg)
    }

    /// Start the worker thread over any engine factory: compiles one
    /// engine + one pre-allocated input/output tensor pair per bucket,
    /// then serves until shutdown.
    pub fn start_with<F>(factory: F, cfg: ServeConfig) -> Result<Self>
    where
        F: EngineFactory + Send + 'static,
    {
        let mut buckets = factory.buckets();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            return Err(anyhow!("no engine buckets from {}", factory.describe()));
        }
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let (tx, rx) = std::sync::mpsc::channel::<Msg>();
        let worker_stats = stats.clone();
        let worker_buckets = buckets.clone();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let down = Arc::new(AtomicBool::new(false));
        let worker_down = Arc::clone(&down);
        let handle = std::thread::Builder::new()
            .name("tvmq-worker".into())
            .spawn(move || {
                let _down = DownGuard(worker_down);
                worker_loop(factory, cfg, worker_buckets, rx, worker_stats, ready_tx)
            })
            .map_err(|e| anyhow!("spawning worker: {e}"))?;
        // Wait for engine compilation so `submit` never races startup.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;
        Ok(Self { tx, stats, handle: Some(handle), down, buckets })
    }

    /// Fire-and-wait-later submit: enqueue the image, get a pending reply.
    ///
    /// Fails promptly — never with a reply that would block forever — once
    /// the server is down: after [`InferenceServer::request_shutdown`], or
    /// after the worker thread exited or died (its drop guard raises the
    /// flag even when it dies mid-unwind, before the channel observably
    /// disconnects).
    pub fn submit(&self, image: TensorData) -> Result<PendingReply> {
        if self.down.load(Ordering::SeqCst) {
            return Err(anyhow!("server is down (worker exited or shutdown requested)"));
        }
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Msg::Job(Job { image, enqueued: Instant::now(), reply }))
            .map_err(|_| anyhow!("server is down"))?;
        Ok(PendingReply(rx))
    }

    /// Submit and wait (for simple callers and benches).
    pub fn submit_blocking(&self, image: TensorData) -> Result<InferenceReply> {
        self.submit(image)?.wait()
    }

    pub fn stats(&self) -> ServerStats {
        lock_stats(&self.stats).clone()
    }

    /// Begin shutdown without consuming the server: new submissions fail
    /// immediately, while the worker drains whatever is already queued
    /// (every pending reply resolves — with a result or a clean error).
    /// Call [`InferenceServer::shutdown`] (or drop) afterwards to join.
    pub fn request_shutdown(&self) {
        self.down.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
    }

    pub fn shutdown(mut self) -> Result<()> {
        self.request_shutdown();
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.request_shutdown();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One serving bucket: the compiled engine plus its pre-allocated batched
/// input and output tensors (allocated once at startup; every batch is
/// copied into/out of them so the request path never allocates inside the
/// executor).
struct BucketEngine {
    batch: usize,
    exec: Box<dyn Executor>,
    input: TensorData,
    out: TensorData,
}

fn build_engines<F: EngineFactory>(
    factory: &F,
    buckets: &[usize],
) -> Result<Vec<BucketEngine>> {
    let mut engines = Vec::with_capacity(buckets.len());
    for &b in buckets {
        if b == 0 {
            return Err(anyhow!("bucket batch sizes must be non-zero"));
        }
        let exec = factory.build(b)?;
        if exec.batch() != b {
            return Err(anyhow!(
                "factory built a batch-{} engine for bucket {b}",
                exec.batch()
            ));
        }
        let (in_shape, in_dt) = exec.input_desc();
        let (out_shape, out_dt) = exec.output_desc();
        if in_shape.first() != Some(&b) || out_shape.first() != Some(&b) {
            return Err(anyhow!(
                "bucket {b} engine I/O is not batch-major: {in_shape:?} -> {out_shape:?}"
            ));
        }
        engines.push(BucketEngine {
            batch: b,
            input: TensorData::zeros(in_dt, in_shape),
            out: TensorData::zeros(out_dt, out_shape),
            exec,
        });
    }
    Ok(engines)
}

fn worker_loop<F: EngineFactory>(
    factory: F,
    cfg: ServeConfig,
    buckets: Vec<usize>,
    rx: std::sync::mpsc::Receiver<Msg>,
    stats: Arc<Mutex<ServerStats>>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) -> Result<()> {
    // Compile every bucket engine up front (startup, not request path).
    let mut engines = match build_engines(&factory, &buckets) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e}")));
            return Err(e);
        }
    };
    let _ = ready.send(Ok(()));

    let max_bucket = *buckets.last().expect("non-empty buckets");
    let max_batch = cfg.max_batch.min(max_bucket).max(1);

    'serve: loop {
        // Block for the first job.
        let first = match rx.recv() {
            Ok(Msg::Job(j)) => j,
            Ok(Msg::Shutdown) | Err(_) => break 'serve,
        };
        let mut jobs = vec![first];
        // Gather until the batch fills or the timeout expires.
        let deadline = Instant::now() + cfg.batch_timeout;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => jobs.push(j),
                Ok(Msg::Shutdown) => {
                    process_batch(&mut engines, &buckets, jobs, &stats);
                    break 'serve;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    process_batch(&mut engines, &buckets, jobs, &stats);
                    break 'serve;
                }
            }
        }
        process_batch(&mut engines, &buckets, jobs, &stats);
    }
    Ok(())
}

/// Copy the gathered job images into the engine's pre-allocated stacked
/// input (zeroing the padding rows) and run in place.  Nothing in here
/// allocates except what the engine's own `run_into` does — zero for
/// arena engines.
fn serve_batch(eng: &mut BucketEngine, jobs: &[Job]) -> Result<()> {
    let row_bytes = eng.input.byte_len() / eng.batch;
    for (i, job) in jobs.iter().enumerate() {
        let img = &job.image;
        if img.dtype != eng.input.dtype
            || img.shape.first() != Some(&1)
            || img.shape.get(1..) != eng.input.shape.get(1..)
        {
            return Err(anyhow!(
                "request image {:?}/{:?} does not fit engine input {:?}/{:?}",
                img.shape, img.dtype, eng.input.shape, eng.input.dtype
            ));
        }
        eng.input.data[i * row_bytes..(i + 1) * row_bytes].copy_from_slice(&img.data);
    }
    eng.input.data[jobs.len() * row_bytes..].fill(0);
    let BucketEngine { exec, input, out, .. } = eng;
    exec.run_into(input, out)
}

/// Fail every job in the batch with the same message and count them.
fn fail_batch(jobs: Vec<Job>, stats: &Arc<Mutex<ServerStats>>, e: anyhow::Error) {
    let msg = format!("{e}");
    lock_stats(stats).errors += jobs.len() as u64;
    for job in jobs {
        let _ = job.reply.send(Err(anyhow!("batch failed: {msg}")));
    }
}

/// Run the engine, containing panics: an engine panic becomes a per-batch
/// error (the worker keeps serving) — except a [`FatalFault`]-carrying
/// panic, which is re-raised to model unrecoverable worker death.  The
/// batch's jobs are still owned by the caller either way, so their reply
/// channels drop (clients get prompt errors, never hangs) when the fatal
/// path unwinds the worker.
fn serve_batch_contained(eng: &mut BucketEngine, jobs: &[Job]) -> Result<()> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| serve_batch(eng, jobs))) {
        Ok(r) => r,
        Err(payload) => {
            if payload.is::<FatalFault>() {
                std::panic::resume_unwind(payload);
            }
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string payload>".into());
            Err(anyhow!("engine panicked: {msg}"))
        }
    }
}

fn process_batch(
    engines: &mut [BucketEngine],
    buckets: &[usize],
    jobs: Vec<Job>,
    stats: &Arc<Mutex<ServerStats>>,
) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    let bucket = match pick_bucket(buckets, n) {
        Ok(b) => b,
        Err(e) => return fail_batch(jobs, stats, e),
    };
    let eng = match engines.iter_mut().find(|e| e.batch == bucket) {
        Some(e) => e,
        None => return fail_batch(jobs, stats, anyhow!("no engine for bucket {bucket}")),
    };
    if let Err(e) = serve_batch_contained(eng, &jobs) {
        return fail_batch(jobs, stats, e);
    }

    let out_row = eng.out.byte_len() / eng.batch;
    let mut row_shape = eng.out.shape.clone();
    row_shape[0] = 1;

    let mut s = lock_stats(stats);
    s.requests += n as u64;
    s.batches += 1;
    *s.batch_histogram.entry(bucket).or_insert(0) += 1;
    s.padded_slots += (bucket - n) as u64;
    for (i, job) in jobs.into_iter().enumerate() {
        let latency = job.enqueued.elapsed();
        s.latencies.push(latency.as_secs_f64() * 1e3);
        let logits = TensorData::new(
            eng.out.dtype,
            row_shape.clone(),
            eng.out.data[i * out_row..(i + 1) * out_row].to_vec(),
        )
        .expect("row tensor");
        let class = logits.argmax_last().map(|v| v[0]).unwrap_or(0);
        let _ = job.reply.send(Ok(InferenceReply {
            logits,
            class,
            batch: bucket,
            latency,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_bucket_exact_fit() {
        assert_eq!(pick_bucket(&[1, 4, 8], 4).unwrap(), 4);
        assert_eq!(pick_bucket(&[1, 4, 8], 1).unwrap(), 1);
        assert_eq!(pick_bucket(&[1, 4, 8], 8).unwrap(), 8);
    }

    #[test]
    fn pick_bucket_next_up_fit() {
        assert_eq!(pick_bucket(&[1, 4, 8], 2).unwrap(), 4);
        assert_eq!(pick_bucket(&[1, 4, 8], 5).unwrap(), 8);
        assert_eq!(pick_bucket(&[2, 16], 1).unwrap(), 2);
    }

    #[test]
    fn pick_bucket_overflow_errors() {
        let err = pick_bucket(&[1, 4, 8], 9).unwrap_err().to_string();
        assert!(err.contains("no bucket fits"), "got: {err}");
        assert!(pick_bucket(&[], 1).is_err());
    }

    #[test]
    fn latency_reservoir_is_exact_below_the_cap() {
        let mut r = LatencyReservoir::default();
        for i in 0..100 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 100);
        assert_eq!(r.samples().len(), 100);
        // Exact: every observation still present, so percentiles are true.
        let stats = r.stats();
        assert_eq!(stats.p50_ms, 50.0);
        assert!((stats.mean_ms - 49.5).abs() < 1e-9);
    }

    /// A panic on the worker thread while holding the stats lock must not
    /// make every later `stats()` reader panic: `lock_stats` recovers the
    /// guard (counters are complete at every update, so there is no torn
    /// state to fear).
    #[test]
    fn stats_lock_recovers_from_poisoning() {
        crate::check::fault::silence_injected_faults();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        lock_stats(&stats).requests = 7;
        let poisoner = Arc::clone(&stats);
        let _ = std::thread::spawn(move || {
            let _g = poisoner.lock().unwrap();
            panic!("injected poisoning panic");
        })
        .join();
        assert!(stats.is_poisoned(), "the panic above must have poisoned the lock");
        assert_eq!(lock_stats(&stats).requests, 7);
        lock_stats(&stats).errors += 1;
        assert_eq!(lock_stats(&stats).errors, 1, "the recovered guard still writes");
    }

    #[test]
    fn latency_reservoir_is_bounded_above_the_cap() {
        let mut r = LatencyReservoir::default();
        for i in 0..(LATENCY_RESERVOIR_CAP * 3) {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), (LATENCY_RESERVOIR_CAP * 3) as u64);
        assert_eq!(r.samples().len(), LATENCY_RESERVOIR_CAP);
        // The reservoir must contain late observations too (replacement
        // actually happens), not just the first `cap`.
        let late = r
            .samples()
            .iter()
            .filter(|&&v| v >= LATENCY_RESERVOIR_CAP as f64)
            .count();
        assert!(late > 0, "reservoir never replaced a sample");
    }
}
