//! Inference coordinator: admission queue → sharded batchers → bucket engines.
//!
//! The serving layer that hosts the paper's memory-bound experiments
//! (Table 3) as a real system: clients submit single images into a
//! **bounded admission queue**; N serving workers (CLI `--workers N`,
//! default 1) each gather batches under a max-batch/timeout policy and
//! route them to the engine compiled for the smallest fitting **bucket**
//! (both XLA modules and arena plans are static-shaped, so there is one
//! compiled engine per batch size — vLLM-style bucket batching).
//!
//! Sharding changes two things over the single-worker coordinator:
//!
//! - **backpressure**: the queue sheds with a typed
//!   [`Rejected::Overloaded`] once depth hits `queue_bound`, so a burst
//!   degrades into fast errors instead of unbounded memory growth and
//!   unbounded latency.  The queue itself is a checkable protocol
//!   ([`queue`], model-checked by `check::queue_model`).
//! - **no head-of-line blocking across batches**: while worker 0 runs a
//!   batch-32, worker 1 pops the next arrivals — small batches are no
//!   longer stuck behind big ones.
//!
//! Engines come from an [`EngineFactory`], not from the coordinator
//! itself: [`InferenceServer::start_with`] accepts any factory, so the
//! same batcher serves AOT PJRT bundles ([`ArtifactFactory`] via
//! [`InferenceServer::start`]) or natively compiled
//! [`crate::executor::ArenaExec`] engines
//! ([`crate::executor::NativeArenaFactory`]) — the latter needs no
//! artifacts at all, which is what makes `tvmq serve --executor arena`
//! work on the offline build.  Replicating engines per worker is cheap:
//! the factory's weight set is `Arc`-shared, so each worker's per-bucket
//! engines alias one constant pool.
//!
//! Each worker pre-allocates one stacked input and one output tensor per
//! bucket at startup and serves every batch through
//! [`crate::executor::Executor::run_into`]; with arena engines the
//! request path therefore performs **zero heap allocations inside the
//! executor** (`tests/arena_alloc.rs` counts them, including the sharded
//! steady state).  PJRT handles are `!Send`, so each worker builds its
//! engines on its own thread; clients talk to the shard over the shared
//! queue and get replies via oneshot channels.
//!
//! Worker death is survivable: a worker that dies (panic carrying
//! [`FatalFault`]) drops its in-flight jobs — their reply channels close,
//! so clients get prompt [`WaitError::WorkerDied`] errors — while the
//! surviving workers keep serving.  Only when the *last* worker exits
//! does the server go down: the drop guard raises the `down` flag, closes
//! the queue, and purges queued jobs so nothing ever hangs on work nobody
//! will serve.

pub mod insitu;
pub(crate) mod queue;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::executor::{ArtifactFactory, EngineFactory, EngineSpec, Executor};
use crate::manifest::Manifest;
use crate::metrics::EpochStats;
use crate::runtime::TensorData;
use crate::telem::{CounterId, GaugeId, HistId, Telemetry};
use crate::util::rng::Rng64;

use queue::{q_pop, q_push, q_shutdown, PopTimed, PushOutcome, StdQueue};

/// Which model variant the server runs, plus batching and sharding policy.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// The typed variant selector (layout/schedule/precision/engine).
    pub spec: EngineSpec,
    /// Upper bound on gathered batch size (clamped to largest bucket).
    pub max_batch: usize,
    /// How long a worker waits to fill a batch.
    pub batch_timeout: Duration,
    /// Serving workers, each with its own per-bucket engine set.
    pub workers: usize,
    /// Admission-queue bound: submissions beyond this depth are shed
    /// with [`Rejected::Overloaded`] instead of queueing unboundedly.
    pub queue_bound: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            spec: EngineSpec::default(),
            max_batch: 64,
            batch_timeout: Duration::from_millis(2),
            workers: 1,
            queue_bound: 1024,
        }
    }
}

/// Typed submit-time rejection.  Callers (the load generator, retry
/// layers) classify with `err.downcast_ref::<Rejected>()`; the display
/// strings keep the previous substrings so log-grepping callers survive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    /// The admission queue is at its bound: the request was shed, not
    /// enqueued.  Retry later or elsewhere.
    Overloaded { depth: usize, bound: usize },
    /// The server is down: shutdown was requested or every worker exited.
    Down,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Overloaded { depth, bound } => write!(
                f,
                "server overloaded: admission queue at bound {bound} (depth {depth}); request shed"
            ),
            Rejected::Down => {
                write!(f, "server is down (worker exited or shutdown requested)")
            }
        }
    }
}

impl std::error::Error for Rejected {}

/// Typed wait-time failure: a reply that never arrived, with the *why*
/// preserved — the load generator must tell a client-side timeout from
/// worker death, and a shed (which is a [`Rejected`] at submit time)
/// from both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitError {
    /// The caller's wait bound elapsed; the request may still complete.
    Timeout,
    /// The serving side dropped the reply channel: the worker holding
    /// this job died, or the job was purged when the last worker exited.
    WorkerDied,
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Timeout => write!(f, "timed out waiting for inference reply"),
            WaitError::WorkerDied => write!(f, "server dropped request (worker died)"),
        }
    }
}

impl std::error::Error for WaitError {}

/// Panic payload marking an *unrecoverable* worker failure.  A worker
/// converts ordinary engine panics into per-batch errors and keeps
/// serving; a panic carrying this marker is deliberately re-raised
/// instead, killing that worker thread — `check::fault` throws it
/// (`Fault::Die`) to prove the server-side handling of true worker death:
/// pending replies resolve with errors (never hang), surviving workers
/// keep serving, and once no workers remain submissions fail promptly.
#[derive(Debug, Clone, Copy)]
pub struct FatalFault;

thread_local! {
    /// The serving-worker index of the current thread, if it is one.
    static WORKER_ID: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// The serving-worker index of the current thread (`None` off the
/// coordinator's worker threads).  `check::fault`'s per-worker
/// [`FaultPlan`](crate::check::fault::FaultPlan)s key on this to target
/// fault scripts at a specific worker.
pub fn current_worker() -> Option<usize> {
    WORKER_ID.with(|c| c.get())
}

pub(crate) fn set_worker_id(w: Option<usize>) {
    WORKER_ID.with(|c| c.set(w));
}

/// Lock the stats mutex, recovering from poisoning: the stats are plain
/// monotone counters plus a reservoir — every update is complete the
/// moment it is made, so a panic elsewhere on a worker thread cannot
/// leave them torn, and propagating the poison would turn one engine
/// panic into a `stats()` panic for every later observer.
fn lock_stats(m: &Mutex<ServerStats>) -> MutexGuard<'_, ServerStats> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One inference reply with full logits.
#[derive(Debug, Clone)]
pub struct InferenceReply {
    pub logits: TensorData,
    pub class: usize,
    /// Batch size the request was served in (bucket).
    pub batch: usize,
    pub latency: Duration,
}

/// A class-only reply: no logits row is ever copied for these (the
/// worker computes argmax straight out of the engine's output tensor),
/// which is the cheap path for top-1 clients.
#[derive(Debug, Clone, Copy)]
pub struct ClassReply {
    pub class: usize,
    /// Batch size the request was served in (bucket).
    pub batch: usize,
    pub latency: Duration,
}

/// One-shot reply channels (std-based; the offline build has no tokio).
type ReplyTx = std::sync::mpsc::SyncSender<Result<InferenceReply>>;
type ClassTx = std::sync::mpsc::SyncSender<Result<ClassReply>>;

/// Where one job's answer goes: a full-logits client or a class-only
/// client (which never pays the per-reply logits copy).
enum ReplySink {
    Full(ReplyTx),
    Class(ClassTx),
}

impl ReplySink {
    fn send_err(&self, e: anyhow::Error) {
        match self {
            ReplySink::Full(tx) => {
                let _ = tx.send(Err(e));
            }
            ReplySink::Class(tx) => {
                let _ = tx.send(Err(e));
            }
        }
    }
}

fn classify_recv_timeout(e: std::sync::mpsc::RecvTimeoutError) -> anyhow::Error {
    match e {
        std::sync::mpsc::RecvTimeoutError::Timeout => anyhow::Error::new(WaitError::Timeout),
        std::sync::mpsc::RecvTimeoutError::Disconnected => {
            anyhow::Error::new(WaitError::WorkerDied)
        }
    }
}

/// A pending reply: wait on it to get the inference result.
pub struct PendingReply(std::sync::mpsc::Receiver<Result<InferenceReply>>);

impl PendingReply {
    pub fn wait(self) -> Result<InferenceReply> {
        self.0.recv().map_err(|_| anyhow::Error::new(WaitError::WorkerDied))?
    }

    /// Bounded wait.  The error is typed: [`WaitError::Timeout`] when
    /// `d` elapsed, [`WaitError::WorkerDied`] when the serving side
    /// dropped the channel — downcast to tell them apart.
    pub fn wait_timeout(self, d: Duration) -> Result<InferenceReply> {
        self.0.recv_timeout(d).map_err(classify_recv_timeout)?
    }
}

/// A pending class-only reply (from [`InferenceServer::submit_class`]).
pub struct PendingClassReply(std::sync::mpsc::Receiver<Result<ClassReply>>);

impl PendingClassReply {
    pub fn wait(self) -> Result<ClassReply> {
        self.0.recv().map_err(|_| anyhow::Error::new(WaitError::WorkerDied))?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<ClassReply> {
        self.0.recv_timeout(d).map_err(classify_recv_timeout)?
    }
}

struct Job {
    image: TensorData,
    enqueued: Instant,
    reply: ReplySink,
}

/// Pick the smallest bucket that fits a gathered batch of `n`.
///
/// `buckets` must be sorted ascending (the server normalizes at startup).
/// Errors instead of silently over- or under-padding when nothing fits —
/// the gather loop clamps to the largest bucket, so hitting the error
/// from the serve path means the clamp itself regressed.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Result<usize> {
    buckets
        .iter()
        .copied()
        .find(|&b| b >= n)
        .ok_or_else(|| anyhow!("no bucket fits a batch of {n} (buckets: {buckets:?})"))
}

/// Bounded latency sample: exact up to [`LATENCY_RESERVOIR_CAP`] samples,
/// a uniform reservoir (Vitter's Algorithm R, deterministic seed) beyond
/// it — so a long-running server's stats stay O(cap) instead of growing
/// one `f64` per request forever.
#[derive(Debug, Clone)]
pub struct LatencyReservoir {
    samples: Vec<f64>,
    /// Total observations ever pushed (`samples` holds min(seen, cap)).
    seen: u64,
    rng: Rng64,
}

/// Reservoir size: percentiles are exact for runs up to this many
/// requests, and an unbiased uniform sample afterwards.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir {
            samples: Vec::new(),
            seen: 0,
            rng: Rng64::seed_from_u64(0x7a11_5eed),
        }
    }
}

impl LatencyReservoir {
    pub fn push(&mut self, ms: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(ms);
            return;
        }
        // Algorithm R: the i-th observation replaces a resident sample
        // with probability cap/i, keeping the reservoir uniform.
        let j = self.rng.next_u64() % self.seen;
        if (j as usize) < LATENCY_RESERVOIR_CAP {
            self.samples[j as usize] = ms;
        }
    }

    /// Observations ever recorded (not the resident sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn stats(&self) -> LatencySnapshot {
        LatencySnapshot {
            samples_seen: self.seen,
            sampled: self.seen > self.samples.len() as u64,
            stats: EpochStats::from_samples(&self.samples, 0),
        }
    }
}

/// Percentiles derived from a [`LatencyReservoir`], with the honesty
/// bits attached: once the reservoir overflows its cap the percentiles
/// come from a uniform *sample* (Algorithm R), not the full population —
/// `sampled` says so, and `samples_seen` is the true observation count.
/// `stats` is `None` when nothing was observed at all (an idle server
/// reports "no data", never all-zero latencies).
#[derive(Debug, Clone, Copy)]
pub struct LatencySnapshot {
    /// Total observations ever recorded (not the resident sample count).
    pub samples_seen: u64,
    /// True once percentiles are estimated from a reservoir sample
    /// rather than computed exactly over every observation.
    pub sampled: bool,
    pub stats: Option<EpochStats>,
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    /// Requests answered successfully.
    pub requests: u64,
    /// Requests answered with an error (batch failures, per-job
    /// validation rejections).
    pub errors: u64,
    /// Requests shed at the admission gate ([`Rejected::Overloaded`]).
    pub shed: u64,
    pub batches: u64,
    /// Batches by the *bucket* (padded size) they were served in.
    pub batch_histogram: std::collections::BTreeMap<usize, u64>,
    /// Batches by the *actual gathered* size, pre-padding — the honest
    /// batching-efficiency signal (the bucket histogram alone inflates
    /// it: a 3-request gather served in bucket 4 counts as 4 there).
    pub gathered_histogram: std::collections::BTreeMap<usize, u64>,
    pub latencies: LatencyReservoir,
    pub padded_slots: u64,
}

impl ServerStats {
    pub fn latency_stats(&self) -> LatencySnapshot {
        self.latencies.stats()
    }

    /// Mean *gathered* batch size: served requests per batch.  Computed
    /// from the request/batch counters, NOT from the bucket histogram —
    /// buckets are padded sizes, and averaging them over-reports the
    /// gather efficiency whenever padding happens.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }
}

/// Sharded inference server: N workers over one bounded admission queue.
pub struct InferenceServer {
    queue: Arc<StdQueue<Job>>,
    stats: Arc<Mutex<ServerStats>>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    /// Raised when the *last* worker thread exits for any reason —
    /// normal shutdown, error return, or panic (each worker's drop guard
    /// participates even mid-unwind) — so `submit` fails promptly
    /// instead of enqueueing onto a dead server.  While at least one
    /// worker survives, the server keeps serving.
    down: Arc<AtomicBool>,
    alive: Arc<AtomicUsize>,
    pub buckets: Vec<usize>,
    queue_bound: usize,
    workers: usize,
    /// Live observability spine (None = telemetry off; every publish
    /// point is skipped with one branch).
    telem: Option<Arc<Telemetry>>,
}

/// Per-worker exit guard (runs during unwind too): decrements the live
/// count; the last worker out raises `down`, closes the queue, and
/// purges queued jobs so their reply channels resolve promptly — the
/// shared queue would otherwise hold jobs nobody will ever serve.
struct WorkerGuard {
    down: Arc<AtomicBool>,
    alive: Arc<AtomicUsize>,
    queue: Arc<StdQueue<Job>>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.down.store(true, Ordering::SeqCst);
            // Order matters: close first (pushes racing this drop get a
            // typed `Closed` under the queue lock), then purge what was
            // accepted before the close.
            q_shutdown(&*self.queue);
            self.queue.purge();
        }
    }
}

impl InferenceServer {
    /// Artifact-backed start: load the manifest and serve `cfg.spec`
    /// through an [`ArtifactFactory`] (requires `make artifacts` + the
    /// real PJRT bridge).
    pub fn start(artifacts: std::path::PathBuf, cfg: ServeConfig) -> Result<Self> {
        let manifest = Manifest::load(&artifacts)?;
        let factory = ArtifactFactory::new(manifest, cfg.spec)?;
        Self::start_with(factory, cfg)
    }

    /// Start `cfg.workers` worker threads over one engine factory: each
    /// worker compiles its own engine + pre-allocated input/output tensor
    /// pair per bucket (on its own thread — PJRT handles are `!Send`),
    /// then serves from the shared admission queue until shutdown.  The
    /// factory is shared behind an `Arc`, and with arena factories the
    /// replicated engines alias one `Arc`'d weight set.
    pub fn start_with<F>(factory: F, cfg: ServeConfig) -> Result<Self>
    where
        F: EngineFactory + Send + Sync + 'static,
    {
        Self::start_with_telemetry(factory, cfg, None)
    }

    /// [`InferenceServer::start_with`] plus a live [`Telemetry`] spine:
    /// workers publish queue depth/wait, gather time, batch and latency
    /// histograms, engine generation, and shed/error counters into the
    /// registry as they serve.  Every publish is lock-free atomics on
    /// pre-registered cells, so the request path stays zero-alloc.
    pub fn start_with_telemetry<F>(
        factory: F,
        cfg: ServeConfig,
        telem: Option<Arc<Telemetry>>,
    ) -> Result<Self>
    where
        F: EngineFactory + Send + Sync + 'static,
    {
        let mut buckets = factory.buckets();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            return Err(anyhow!("no engine buckets from {}", factory.describe()));
        }
        let workers = cfg.workers.max(1);
        let queue_bound = cfg.queue_bound.max(1);
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let queue = Arc::new(StdQueue::<Job>::new(queue_bound));
        let down = Arc::new(AtomicBool::new(false));
        let alive = Arc::new(AtomicUsize::new(workers));
        let factory = Arc::new(factory);

        let mut handles = Vec::with_capacity(workers);
        let mut readies = Vec::with_capacity(workers);
        let mut startup_err: Option<anyhow::Error> = None;
        for w in 0..workers {
            let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
            let factory = Arc::clone(&factory);
            let worker_queue = Arc::clone(&queue);
            let worker_stats = Arc::clone(&stats);
            let worker_buckets = buckets.clone();
            let worker_telem = telem.clone();
            let guard = WorkerGuard {
                down: Arc::clone(&down),
                alive: Arc::clone(&alive),
                queue: Arc::clone(&queue),
            };
            match std::thread::Builder::new()
                .name(format!("tvmq-worker-{w}"))
                .spawn(move || {
                    let _guard = guard;
                    worker_loop(
                        w,
                        factory,
                        cfg,
                        worker_buckets,
                        worker_queue,
                        worker_stats,
                        worker_telem,
                        ready_tx,
                    )
                }) {
                Ok(h) => {
                    handles.push(h);
                    readies.push(ready_rx);
                }
                Err(e) => {
                    // Unspawned workers never decrement `alive`; settle
                    // their share so the last *spawned* worker's guard
                    // still closes the server.
                    alive.fetch_sub(workers - w, Ordering::SeqCst);
                    startup_err = Some(anyhow!("spawning worker {w}: {e}"));
                    break;
                }
            }
        }
        if startup_err.is_none() {
            // Wait for every worker's engine compilation so `submit`
            // never races startup; per-worker channels, so one worker
            // panicking mid-build closes *its* channel (not the shared
            // one) and is reported instead of hanging the recv.
            for ready_rx in &readies {
                match ready_rx.recv() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        startup_err = Some(e);
                        break;
                    }
                    Err(_) => {
                        startup_err = Some(anyhow!("worker died during startup"));
                        break;
                    }
                }
            }
        }
        if let Some(e) = startup_err {
            down.store(true, Ordering::SeqCst);
            q_shutdown(&*queue);
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        if let Some(t) = &telem {
            t.registry.gauge_set(GaugeId::Workers, workers as u64);
        }
        Ok(Self { queue, stats, handles, down, alive, buckets, queue_bound, workers, telem })
    }

    fn submit_sink(&self, image: TensorData, reply: ReplySink) -> Result<()> {
        if self.down.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(Rejected::Down));
        }
        match q_push(&*self.queue, Job { image, enqueued: Instant::now(), reply }) {
            PushOutcome::Accepted => Ok(()),
            PushOutcome::Shed { depth } => {
                if let Some(t) = &self.telem {
                    t.registry.count(CounterId::Shed, 1);
                }
                Err(anyhow::Error::new(Rejected::Overloaded { depth, bound: self.queue_bound }))
            }
            PushOutcome::Closed => Err(anyhow::Error::new(Rejected::Down)),
        }
    }

    /// Fire-and-wait-later submit: enqueue the image, get a pending reply.
    ///
    /// Fails promptly with a typed [`Rejected`] — never with a reply that
    /// would block forever — when the admission queue is at bound
    /// (`Overloaded`: the request is shed) or the server is down
    /// (`Down`: after [`InferenceServer::request_shutdown`], or once the
    /// last worker exited; the drop guard raises the flag even mid-unwind).
    pub fn submit(&self, image: TensorData) -> Result<PendingReply> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.submit_sink(image, ReplySink::Full(reply))?;
        Ok(PendingReply(rx))
    }

    /// Class-only submit: the reply carries argmax/batch/latency and the
    /// serve path never copies the logits row for this request.
    pub fn submit_class(&self, image: TensorData) -> Result<PendingClassReply> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.submit_sink(image, ReplySink::Class(reply))?;
        Ok(PendingClassReply(rx))
    }

    /// Submit and wait (for simple callers and benches).
    pub fn submit_blocking(&self, image: TensorData) -> Result<InferenceReply> {
        self.submit(image)?.wait()
    }

    pub fn stats(&self) -> ServerStats {
        let mut s = lock_stats(&self.stats).clone();
        (s.shed, _) = self.queue.shed_and_depth();
        s
    }

    /// Workers the server was started with.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers still serving (drops as workers die).
    pub fn alive_workers(&self) -> usize {
        self.alive.load(Ordering::SeqCst)
    }

    /// Begin shutdown without consuming the server: new submissions fail
    /// immediately, while the workers drain whatever is already queued
    /// (every pending reply resolves — with a result or a clean error).
    /// Call [`InferenceServer::shutdown`] (or drop) afterwards to join.
    pub fn request_shutdown(&self) {
        self.down.store(true, Ordering::SeqCst);
        q_shutdown(&*self.queue);
    }

    /// Shut down and join every worker.  Errs if any worker exited with
    /// an error or panic (a dead worker reports its death instead of
    /// pretending a clean exit).
    pub fn shutdown(mut self) -> Result<()> {
        self.request_shutdown();
        let mut first_err: Option<anyhow::Error> = None;
        for h in self.handles.drain(..) {
            let r = match h.join() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(e),
                Err(_) => Err(anyhow!("worker panicked")),
            };
            if let Err(e) = r {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.request_shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One serving bucket: the compiled engine plus its pre-allocated batched
/// input and output tensors (allocated once at startup; every batch is
/// copied into/out of them so the request path never allocates inside the
/// executor).
struct BucketEngine {
    batch: usize,
    exec: Box<dyn Executor>,
    input: TensorData,
    out: TensorData,
    /// Which [`insitu::EngineUpgrade`] generation this engine came from
    /// (0 = the factory's startup build).  Swaps happen only at batch
    /// boundaries in the worker loop, so every request is served by
    /// exactly one generation.
    generation: u64,
}

fn build_engines<F: EngineFactory + ?Sized>(
    factory: &F,
    buckets: &[usize],
) -> Result<Vec<BucketEngine>> {
    let mut engines = Vec::with_capacity(buckets.len());
    for &b in buckets {
        if b == 0 {
            return Err(anyhow!("bucket batch sizes must be non-zero"));
        }
        let exec = factory.build(b)?;
        if exec.batch() != b {
            return Err(anyhow!(
                "factory built a batch-{} engine for bucket {b}",
                exec.batch()
            ));
        }
        let (in_shape, in_dt) = exec.input_desc();
        let (out_shape, out_dt) = exec.output_desc();
        if in_shape.first() != Some(&b) || out_shape.first() != Some(&b) {
            return Err(anyhow!(
                "bucket {b} engine I/O is not batch-major: {in_shape:?} -> {out_shape:?}"
            ));
        }
        engines.push(BucketEngine {
            batch: b,
            input: TensorData::zeros(in_dt, in_shape),
            out: TensorData::zeros(out_dt, out_shape),
            exec,
            generation: 0,
        });
    }
    Ok(engines)
}

fn worker_loop<F: EngineFactory>(
    worker: usize,
    factory: Arc<F>,
    cfg: ServeConfig,
    buckets: Vec<usize>,
    queue: Arc<StdQueue<Job>>,
    stats: Arc<Mutex<ServerStats>>,
    telem: Option<Arc<Telemetry>>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) -> Result<()> {
    set_worker_id(Some(worker));
    // Compile every bucket engine up front (startup, not request path).
    let mut engines = match build_engines(&*factory, &buckets) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e}")));
            return Err(e);
        }
    };
    let _ = ready.send(Ok(()));
    drop(ready);

    let max_bucket = *buckets.last().expect("non-empty buckets");
    let max_batch = cfg.max_batch.min(max_bucket).max(1);

    // In-situ hot-swap: factories that expose an upgrade slot get their
    // engines replaced at batch boundaries.  `seen_gen` starts at 0 so
    // upgrades published before this worker's first batch are adopted
    // on the first poll.
    let upgrade_slot = factory.upgrade_slot();
    let mut seen_gen = 0u64;

    loop {
        // Swap point: strictly between batches, before blocking for the
        // next job, so no request ever straddles two engine generations.
        if let Some(slot) = &upgrade_slot {
            poll_upgrades(worker, &mut engines, slot, &mut seen_gen);
        }
        // Block for the first job — `q_pop` is the checked protocol pop:
        // drains remaining accepted work even after shutdown, returns
        // `None` only once the queue is shut down *and* empty.
        let first = match q_pop(&*queue) {
            Some(j) => j,
            None => return Ok(()),
        };
        let gather_t0 = Instant::now();
        let mut jobs = vec![first];
        // Gather until the batch fills or the timeout expires.  The
        // deadline-bounded pop is production-only (timing is outside the
        // model checker's scope); shutdown mid-gather just ends the
        // gather — the batch in hand is still served, and the next
        // `q_pop` drains or exits.
        let deadline = gather_t0 + cfg.batch_timeout;
        while jobs.len() < max_batch {
            match queue.pop_until(deadline) {
                PopTimed::Got(j) => jobs.push(j),
                PopTimed::TimedOut | PopTimed::Closed => break,
            }
        }
        if let Some(t) = &telem {
            // Publish the gather's shape before serving: time spent
            // filling the batch, per-job time-in-queue, and the queue
            // depth left behind (its high-water mark survives resets of
            // the instantaneous gauge).  All lock-free atomics.
            t.registry.record(HistId::GatherUs, gather_t0.elapsed().as_micros() as u64);
            for j in &jobs {
                t.registry.record(HistId::QueueWaitUs, j.enqueued.elapsed().as_micros() as u64);
            }
            let (_, depth) = queue.shed_and_depth();
            t.registry.gauge_set(GaugeId::QueueDepth, depth as u64);
            t.registry.gauge_max(GaugeId::QueueDepthMax, depth as u64);
            t.registry.record(HistId::QueueDepth, depth as u64);
            if let Some(gen) = engines.iter().map(|e| e.generation).max() {
                t.registry.gauge_max(GaugeId::EngineGeneration, gen);
            }
        }
        process_batch(&mut engines, &buckets, jobs, &stats, telem.as_deref());
    }
}

/// Adopt any newly published engine upgrades — called only at batch
/// boundaries (the worker loop's top), which is the whole swap-safety
/// argument: a batch in flight finishes on the engine that started it.
///
/// Each upgrade's builder runs on THIS worker's thread (engines may be
/// `!Send`).  A failed or malformed build keeps the old engine serving —
/// an in-situ tuner must never be able to take a healthy worker down —
/// and `seen_gen` advances regardless so a known-bad build is not
/// retried before every batch.
fn poll_upgrades(
    worker: usize,
    engines: &mut [BucketEngine],
    slot: &insitu::UpgradeSlot,
    seen_gen: &mut u64,
) {
    let gen = slot.generation();
    if gen == *seen_gen {
        return;
    }
    *seen_gen = gen;
    for eng in engines.iter_mut() {
        let Some(up) = slot.latest_for(eng.batch) else { continue };
        if up.generation <= eng.generation {
            continue;
        }
        match up.build_engine() {
            Ok(exec) => {
                let (in_shape, in_dt) = exec.input_desc();
                let (out_shape, out_dt) = exec.output_desc();
                if exec.batch() != eng.batch
                    || in_shape.first() != Some(&eng.batch)
                    || out_shape.first() != Some(&eng.batch)
                {
                    eprintln!(
                        "tvmq: worker {worker}: rejecting upgrade gen {} for bucket {}: \
                         built a batch-{} engine ({in_shape:?} -> {out_shape:?})",
                        up.generation,
                        eng.batch,
                        exec.batch()
                    );
                    continue;
                }
                // Buffers are re-allocated with the new engine (startup
                // path parity); this is swap-time work, not request-path
                // work — steady-state serving stays zero-alloc.
                eng.input = TensorData::zeros(in_dt, in_shape);
                eng.out = TensorData::zeros(out_dt, out_shape);
                eng.exec = exec;
                eng.generation = up.generation;
                eprintln!(
                    "tvmq: worker {worker}: hot-swapped bucket {} engine to gen {} ({})",
                    eng.batch, up.generation, up.describe
                );
            }
            Err(e) => {
                eprintln!(
                    "tvmq: worker {worker}: upgrade build failed for bucket {} \
                     (keeping gen {}): {e:#}",
                    eng.batch, eng.generation
                );
            }
        }
    }
}

/// Does one request image fit the engines' per-row input descriptor?
/// (All buckets share row geometry — `build_engines` verified batch-major
/// I/O — so validating against any one engine covers them all.)
fn image_fits(input: &TensorData, img: &TensorData) -> bool {
    img.dtype == input.dtype
        && img.shape.first() == Some(&1)
        && img.shape.get(1..) == input.shape.get(1..)
}

/// Copy the gathered job images into the engine's pre-allocated stacked
/// input (zeroing the padding rows) and run in place.  Jobs are already
/// validated; nothing in here allocates except what the engine's own
/// `run_into` does — zero for arena engines.
fn serve_batch(eng: &mut BucketEngine, jobs: &[Job]) -> Result<()> {
    let row_bytes = eng.input.byte_len() / eng.batch;
    for (i, job) in jobs.iter().enumerate() {
        eng.input.data[i * row_bytes..(i + 1) * row_bytes].copy_from_slice(&job.image.data);
    }
    eng.input.data[jobs.len() * row_bytes..].fill(0);
    let BucketEngine { exec, input, out, .. } = eng;
    exec.run_into(input, out)
}

/// Fail every job in the batch with the same message: count the errors
/// in one short critical section, send the replies outside the lock.
fn fail_batch(
    jobs: Vec<Job>,
    stats: &Arc<Mutex<ServerStats>>,
    telem: Option<&Telemetry>,
    e: anyhow::Error,
) {
    let msg = format!("{e}");
    lock_stats(stats).errors += jobs.len() as u64;
    if let Some(t) = telem {
        t.registry.count(CounterId::Errors, jobs.len() as u64);
    }
    for job in jobs {
        job.reply.send_err(anyhow!("batch failed: {msg}"));
    }
}

/// Run the engine, containing panics: an engine panic becomes a per-batch
/// error (the worker keeps serving) — except a [`FatalFault`]-carrying
/// panic, which is re-raised to model unrecoverable worker death.  The
/// batch's jobs are still owned by the caller either way, so their reply
/// channels drop (clients get prompt errors, never hangs) when the fatal
/// path unwinds the worker.
fn serve_batch_contained(eng: &mut BucketEngine, jobs: &[Job]) -> Result<()> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| serve_batch(eng, jobs))) {
        Ok(r) => r,
        Err(payload) => {
            if payload.is::<FatalFault>() {
                std::panic::resume_unwind(payload);
            }
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string payload>".into());
            Err(anyhow!("engine panicked: {msg}"))
        }
    }
}

/// Argmax over one logits row.  Ties resolve to the *highest* index —
/// exactly what `TensorData::argmax_last` does (`max_by` keeps the last
/// maximal element) — so the class computed here is bit-for-bit the one
/// the full-logits reply path and the interpreter oracle would report.
fn argmax_row(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn process_batch(
    engines: &mut [BucketEngine],
    buckets: &[usize],
    jobs: Vec<Job>,
    stats: &Arc<Mutex<ServerStats>>,
    telem: Option<&Telemetry>,
) {
    if jobs.is_empty() {
        return;
    }
    // Per-job validation against the engine input descriptor: one
    // malformed image fails only its own job — the innocents it was
    // co-gathered with stay in the batch.
    let row_desc = &engines[0].input;
    let (valid, invalid): (Vec<Job>, Vec<Job>) =
        jobs.into_iter().partition(|j| image_fits(row_desc, &j.image));
    if !invalid.is_empty() {
        lock_stats(stats).errors += invalid.len() as u64;
        if let Some(t) = telem {
            t.registry.count(CounterId::Errors, invalid.len() as u64);
        }
        for job in invalid {
            job.reply.send_err(anyhow!(
                "request image {:?}/{:?} does not fit engine input {:?}/{:?}",
                job.image.shape,
                job.image.dtype,
                row_desc.shape,
                row_desc.dtype
            ));
        }
    }
    let n = valid.len();
    if n == 0 {
        return;
    }
    let bucket = match pick_bucket(buckets, n) {
        Ok(b) => b,
        Err(e) => return fail_batch(valid, stats, telem, e),
    };
    let eng = match engines.iter_mut().find(|e| e.batch == bucket) {
        Some(e) => e,
        None => {
            return fail_batch(valid, stats, telem, anyhow!("no engine for bucket {bucket}"))
        }
    };
    if let Err(e) = serve_batch_contained(eng, &valid) {
        return fail_batch(valid, stats, telem, e);
    }

    let out_row = eng.out.byte_len() / eng.batch;
    let mut row_shape = eng.out.shape.clone();
    row_shape[0] = 1;
    let latencies: Vec<Duration> = valid.iter().map(|j| j.enqueued.elapsed()).collect();

    // One short critical section: counters and the reservoir only.  The
    // reply loop below — including any logits copies and the channel
    // sends — runs outside the lock, so N workers sharing these stats
    // don't serialize their reply fan-out on each other.
    {
        let mut s = lock_stats(stats);
        s.requests += n as u64;
        s.batches += 1;
        *s.batch_histogram.entry(bucket).or_insert(0) += 1;
        *s.gathered_histogram.entry(n).or_insert(0) += 1;
        s.padded_slots += (bucket - n) as u64;
        for l in &latencies {
            s.latencies.push(l.as_secs_f64() * 1e3);
        }
    }
    if let Some(t) = telem {
        // Registry publishes happen outside the stats lock — they are
        // lock-free atomics and the drift detector has its own mutex.
        t.registry.count(CounterId::Requests, n as u64);
        t.registry.count(CounterId::Batches, 1);
        t.registry.record(HistId::BatchSize, n as u64);
        for l in &latencies {
            t.observe_latency_us(l.as_micros() as u64);
        }
        if let Some(row) = valid.first().map(|j| &j.image.shape) {
            // Row shape minus the leading batch-1 dim, keyed by the
            // bucket that served it — the per-shape tuning-task feed.
            t.shapes.record(bucket, row.get(1..).unwrap_or(&[]));
        }
    }

    // Fast path: every engine in the repo emits f32 logits, so argmax
    // reads straight out of the shared output tensor — class-only
    // clients get their answer with no per-reply copy at all.
    let logits_f32: Option<&[f32]> = eng.out.as_f32_slice().ok();
    let row_elems = logits_f32.map(|f| f.len() / eng.batch).unwrap_or(0);
    for (i, (job, latency)) in valid.into_iter().zip(latencies).enumerate() {
        let class = match logits_f32 {
            Some(f) => argmax_row(&f[i * row_elems..(i + 1) * row_elems]),
            None => TensorData::new(
                eng.out.dtype,
                row_shape.clone(),
                eng.out.data[i * out_row..(i + 1) * out_row].to_vec(),
            )
            .ok()
            .and_then(|t| t.argmax_last().ok())
            .map(|v| v[0])
            .unwrap_or(0),
        };
        match job.reply {
            ReplySink::Full(tx) => {
                let logits = TensorData::new(
                    eng.out.dtype,
                    row_shape.clone(),
                    eng.out.data[i * out_row..(i + 1) * out_row].to_vec(),
                )
                .expect("row tensor");
                let _ = tx.send(Ok(InferenceReply { logits, class, batch: bucket, latency }));
            }
            ReplySink::Class(tx) => {
                let _ = tx.send(Ok(ClassReply { class, batch: bucket, latency }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_bucket_exact_fit() {
        assert_eq!(pick_bucket(&[1, 4, 8], 4).unwrap(), 4);
        assert_eq!(pick_bucket(&[1, 4, 8], 1).unwrap(), 1);
        assert_eq!(pick_bucket(&[1, 4, 8], 8).unwrap(), 8);
    }

    #[test]
    fn pick_bucket_next_up_fit() {
        assert_eq!(pick_bucket(&[1, 4, 8], 2).unwrap(), 4);
        assert_eq!(pick_bucket(&[1, 4, 8], 5).unwrap(), 8);
        assert_eq!(pick_bucket(&[2, 16], 1).unwrap(), 2);
    }

    #[test]
    fn pick_bucket_overflow_errors() {
        let err = pick_bucket(&[1, 4, 8], 9).unwrap_err().to_string();
        assert!(err.contains("no bucket fits"), "got: {err}");
        assert!(pick_bucket(&[], 1).is_err());
    }

    /// The padding-inflation regression: 3 requests served in bucket 4
    /// must report a mean gathered batch of 3, not 4.
    #[test]
    fn mean_batch_reports_gathered_not_padded_size() {
        let mut s = ServerStats::default();
        s.requests = 3;
        s.batches = 1;
        *s.batch_histogram.entry(4).or_insert(0) += 1;
        *s.gathered_histogram.entry(3).or_insert(0) += 1;
        s.padded_slots = 1;
        assert!((s.mean_batch() - 3.0).abs() < 1e-12, "got {}", s.mean_batch());
        assert_eq!(s.batch_histogram.get(&4), Some(&1));
        assert_eq!(s.gathered_histogram.get(&3), Some(&1));
    }

    #[test]
    fn argmax_row_matches_argmax_last_tie_behavior() {
        // Ties resolve to the last maximal index, as argmax_last does.
        let t = TensorData::from_f32(vec![1, 4], &[0.0, 3.0, 3.0, 1.0]).unwrap();
        assert_eq!(argmax_row(&[0.0, 3.0, 3.0, 1.0]), t.argmax_last().unwrap()[0]);
        assert_eq!(argmax_row(&[0.0, 3.0, 3.0, 1.0]), 2);
        assert_eq!(argmax_row(&[-2.0, -1.0, -3.0]), 1);
        assert_eq!(argmax_row(&[5.0]), 0);
    }

    #[test]
    fn rejected_and_wait_errors_downcast_through_anyhow() {
        let e = anyhow::Error::new(Rejected::Overloaded { depth: 8, bound: 8 });
        match e.downcast_ref::<Rejected>() {
            Some(Rejected::Overloaded { depth: 8, bound: 8 }) => {}
            other => panic!("bad downcast: {other:?}"),
        }
        assert!(e.to_string().contains("overloaded"), "got: {e}");
        let e = anyhow::Error::new(Rejected::Down);
        assert!(e.to_string().contains("down"), "got: {e}");
        let e = anyhow::Error::new(WaitError::Timeout);
        assert_eq!(e.downcast_ref::<WaitError>(), Some(&WaitError::Timeout));
        assert!(e.to_string().contains("timed out"), "got: {e}");
        let e = anyhow::Error::new(WaitError::WorkerDied);
        assert!(e.to_string().contains("dropped request"), "got: {e}");
    }

    #[test]
    fn latency_reservoir_is_exact_below_the_cap() {
        let mut r = LatencyReservoir::default();
        for i in 0..100 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 100);
        assert_eq!(r.samples().len(), 100);
        // Exact: every observation still present, so percentiles are true
        // and the snapshot says so.
        let snap = r.stats();
        assert_eq!(snap.samples_seen, 100);
        assert!(!snap.sampled, "below the cap the percentiles are exact");
        let stats = snap.stats.expect("non-empty reservoir has stats");
        assert_eq!(stats.p50_ms, 50.0);
        assert!((stats.mean_ms - 49.5).abs() < 1e-9);
    }

    /// An idle server reports "no data", never all-zero latencies.
    #[test]
    fn empty_reservoir_snapshot_is_typed_not_zero() {
        let r = LatencyReservoir::default();
        let snap = r.stats();
        assert_eq!(snap.samples_seen, 0);
        assert!(!snap.sampled);
        assert!(snap.stats.is_none());
    }

    /// A panic on a worker thread while holding the stats lock must not
    /// make every later `stats()` reader panic: `lock_stats` recovers the
    /// guard (counters are complete at every update, so there is no torn
    /// state to fear).
    #[test]
    fn stats_lock_recovers_from_poisoning() {
        crate::check::fault::silence_injected_faults();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        lock_stats(&stats).requests = 7;
        let poisoner = Arc::clone(&stats);
        let _ = std::thread::spawn(move || {
            let _g = poisoner.lock().unwrap();
            panic!("injected poisoning panic");
        })
        .join();
        assert!(stats.is_poisoned(), "the panic above must have poisoned the lock");
        assert_eq!(lock_stats(&stats).requests, 7);
        lock_stats(&stats).errors += 1;
        assert_eq!(lock_stats(&stats).errors, 1, "the recovered guard still writes");
    }

    #[test]
    fn latency_reservoir_is_bounded_above_the_cap() {
        let mut r = LatencyReservoir::default();
        for i in 0..(LATENCY_RESERVOIR_CAP * 3) {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), (LATENCY_RESERVOIR_CAP * 3) as u64);
        assert_eq!(r.samples().len(), LATENCY_RESERVOIR_CAP);
        let snap = r.stats();
        assert_eq!(snap.samples_seen, (LATENCY_RESERVOIR_CAP * 3) as u64);
        assert!(snap.sampled, "past the cap the percentiles are estimates");
        // The reservoir must contain late observations too (replacement
        // actually happens), not just the first `cap`.
        let late = r
            .samples()
            .iter()
            .filter(|&&v| v >= LATENCY_RESERVOIR_CAP as f64)
            .count();
        assert!(late > 0, "reservoir never replaced a sample");
    }
}
