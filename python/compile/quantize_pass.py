"""Post-training quantization pass: calibrate → annotate → realize.

TVM's ``relay.quantize`` pipeline, rebuilt for the segment model:

1. **calibrate** — run the fp32 model over a calibration batch, record the
   activation distribution at every quantization point (abs-max, the same
   ``global_scale``-free power-of-two-less scheme TVM's ``kind=global``
   calibration approximates);
2. **annotate** — the tap names emitted by ``model.forward_fp32_with_taps``
   *are* the annotation: one scale per quantize site, weights get per-tensor
   scales at realize time;
3. **realize** — ``model.build_segments(cfg, params, scales)`` rewrites the
   graph into quantize → int8-conv(int32) → dequantize chains with the
   scales baked in as fp32 constants.

Also provides the quantization-quality metrics (SQNR, cosine similarity,
top-1 agreement) recorded into the artifact manifest — the paper reports no
accuracy numbers, so these serve as the "acceptable model accuracy" check
its §1.1.1 presumes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref


def calibration_batch(cfg: M.ModelConfig, batch: int = 8, seed: int = 42):
    """Synthetic calibration data: seeded, normalized Gaussian images.

    Stands in for the paper's ImageNet validation batches (DESIGN.md
    §Substitutions): scale calibration only needs representative activation
    magnitudes, which the fp32 forward produces for any input distribution.
    """
    rng = np.random.default_rng(seed)
    shape = (
        (batch, cfg.in_channels, cfg.image_size, cfg.image_size)
        if cfg.layout == "NCHW"
        else (batch, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def calibrate(cfg: M.ModelConfig, params: dict, calib_x=None) -> dict:
    """Abs-max calibration over every quantization point.

    Returns ``{tap_name: float_scale}``; keys match what
    ``model.build_segments`` expects.
    """
    if calib_x is None:
        calib_x = calibration_batch(cfg)
    _, taps = M.forward_fp32_with_taps(cfg, params, calib_x)
    return {name: float(ref.abs_max_scale(act)) for name, act in taps.items()}


# ---------------------------------------------------------------------------
# Quantization quality metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantReport:
    sqnr_db: float
    cosine: float
    top1_agreement: float
    max_abs_err: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def quant_report(cfg: M.ModelConfig, params: dict, scales: dict,
                 eval_x=None) -> QuantReport:
    """Compare int8 vs fp32 model outputs on an evaluation batch."""
    if eval_x is None:
        eval_x = calibration_batch(cfg, batch=16, seed=77)
    fcfg = dataclasses.replace(cfg, precision="fp32", schedule="reference")
    ref_logits = np.asarray(M.fused_forward(fcfg, params)(eval_x))
    q_logits = np.asarray(M.fused_forward(cfg, params, scales)(eval_x))

    err = q_logits - ref_logits
    sig = float(np.mean(ref_logits**2))
    noise = float(np.mean(err**2))
    sqnr = 10.0 * np.log10(sig / max(noise, 1e-20))
    cos = float(
        np.sum(ref_logits * q_logits)
        / max(np.linalg.norm(ref_logits) * np.linalg.norm(q_logits), 1e-20)
    )
    top1 = float(np.mean(np.argmax(ref_logits, -1) == np.argmax(q_logits, -1)))
    return QuantReport(
        sqnr_db=float(sqnr),
        cosine=cos,
        top1_agreement=top1,
        max_abs_err=float(np.abs(err).max()),
    )
