"""HLO-text lowering: the jax → rust interchange layer.

The interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Functions are lowered single-output (``return_tuple=False``) so PJRT hands
back plain array buffers the VM can chain device-to-device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

_DTYPES = {"f32": jnp.float32, "s8": jnp.int8, "s32": jnp.int32}


def dtype_of(tag: str):
    return _DTYPES[tag]


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: every module here is single-output, and untupled
    # results let the VM chain device buffers directly (PJRT returns the
    # tuple as one opaque 8-byte buffer otherwise).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_fn(fn, in_specs, batch: int) -> str:
    """Lower ``fn(*xs) -> y`` at a concrete batch size to HLO text.

    ``in_specs`` is a list of ``(shape, dtype_tag)``; shapes use -1 for the
    batch dimension.
    """
    specs = [
        jax.ShapeDtypeStruct(
            tuple(batch if d == -1 else d for d in shape), dtype_of(dtype)
        )
        for shape, dtype in in_specs
    ]
    lowered = jax.jit(lambda *xs: (fn(*xs),)).lower(*specs)
    return to_hlo_text(lowered)
