"""NHWC ``spatial_pack`` conv2d — the paper's worst-performing schedule.

In TVM's NHWC spatial pack "the data is WC-packed, and it only parallelizes
the H axis by a factor of 4 without additional blocking" (§3.2.1) — no
channel blocking, no K slabs, fp32 math.  The paper measures it at 35.15 ms
vs 13.29 ms for the NCHW packed schedule: the deliberate weak point of
Table 2, kept weak here for fidelity.

Structure: grid = (N, output-row tiles of 4) only.  Each step computes ALL K
output channels for its 4 rows in one un-blocked fp32 contraction — large
working set, no reuse slab, minimal parallel structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pallas_utils import INTERPRET, cdiv, round_up
from . import ref


def _nhwc_conv_kernel(x_ref, w_ref, o_ref, *, stride, R, S, OW, TH, C, K):
    """One (n, ht) grid step: a (TH, OW, K) output slab (all channels).

    x_ref: (1, Hp, Wp, C) fp32; w_ref: (R, S, C, K) fp32;
    o_ref: (1, TH, OW, K) fp32.
    """
    ht = pl.program_id(1)
    xb = x_ref[0]
    th_in = (TH - 1) * stride + R
    xwin = lax.dynamic_slice(xb, (ht * TH * stride, 0, 0), (th_in, xb.shape[1], C))
    wb = w_ref[...]

    acc = jnp.zeros((TH * OW, K), jnp.float32)
    for r in range(R):
        for s in range(S):
            patch = lax.slice(
                xwin,
                (r, s, 0),
                (r + (TH - 1) * stride + 1, s + (OW - 1) * stride + 1, C),
                (stride, stride, 1),
            )  # (TH, OW, C) — channels-last, no gather needed…
            acc = acc + lax.dot_general(
                patch.reshape(TH * OW, C),
                wb[r, s],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # …but fp32 and un-blocked over K.
    o_ref[0] = acc.reshape(TH, OW, K)


def conv2d_spatial_pack_nhwc(
    x,
    w,
    stride: int = 1,
    padding: int = 0,
    h_tile: int = 4,
):
    """NHWC spatial-pack conv2d (fp32).

    ``x``: (N, H, W, C) fp32; ``w``: (R, S, C, K) fp32 (HWIO).
    Returns (N, OH, OW, K) fp32.
    """
    N, H, W, C = x.shape
    R, S, Cw, K = w.shape
    assert C == Cw

    OH = ref.conv_out_size(H, R, stride, padding)
    OW = ref.conv_out_size(W, S, stride, padding)
    TH = min(h_tile, OH)
    OHt = cdiv(OH, TH)

    need_h = (OHt * TH - 1) * stride + R
    hp_total = max(H + 2 * padding, need_h)
    xp = jnp.pad(
        x, ((0, 0), (padding, hp_total - H - padding), (padding, padding), (0, 0))
    )
    Hp, Wp = xp.shape[1], xp.shape[2]

    kernel = functools.partial(
        _nhwc_conv_kernel, stride=stride, R=R, S=S, OW=OW, TH=TH, C=C, K=K
    )
    out = pl.pallas_call(
        kernel,
        grid=(N, OHt),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda n, ht: (n, 0, 0, 0)),
            pl.BlockSpec((R, S, C, K), lambda n, ht: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TH, OW, K), lambda n, ht: (n, ht, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, OHt * TH, OW, K), jnp.float32),
        interpret=INTERPRET,
    )(xp, w)
    return out[:, :OH]
