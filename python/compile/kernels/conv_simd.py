"""``simd`` int8 conv2d — the paper's vmlal dot-product schedule.

TVM's ARM ``simd`` int8 schedule uses ``vmlal`` (widening multiply-
accumulate): 4 int8 elements are dotted into each of 4 int32 lanes, so the
reduction axis is walked in groups of 4 and the ideal speedup is 16×
(Table 2).  Unlike ``nchw_spatial_pack`` there is *no* layout packing: the
kernel works on plain NCHW, which forces a channel gather per filter tap —
exactly the memory-access inefficiency the spatial-pack schedule removes,
and why the paper measures simd (11.36 ms) behind packed int8 (8.27 ms).

TPU re-expression: the group-of-4 reduction becomes a ``dot_general`` whose
contraction runs over a ``(C/4, 4)`` reshaped axis pair — the exact dataflow
of a vmlal chain — with int8 operands and an int32 preferred element type
(the MXU's s8s8s32 mode on real hardware).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pallas_utils import EXACT_CHUNK, INTERPRET, cdiv, pad_axis_to, round_up
from . import ref

DOT_WIDTH = 4  # int8 elements per int32 lane, as in vmlal.s8


def _simd_conv_kernel(x_ref, w_ref, o_ref, *, stride, R, S, OH, OW, C, kt):
    """One (n, kt-slab) grid step.

    x_ref: (1, C, Hp, Wp) int8 — plain NCHW, *unpacked*.
    w_ref: (kt, C, R, S) int8
    o_ref: (1, kt, OH, OW) int32
    """
    # Widen once per grid step (exact f32 emulation; cold traffic stays s8).
    xb = x_ref[0].astype(jnp.float32)  # (C, Hp, Wp)
    wb = w_ref[...].astype(jnp.float32)  # (kt, C, R, S)
    Cg = C // DOT_WIDTH

    acc = jnp.zeros((OH * OW, kt), jnp.int32)
    for r in range(R):
        for s in range(S):
            patch = lax.slice(
                xb,
                (0, r, s),
                (C, r + (OH - 1) * stride + 1, s + (OW - 1) * stride + 1),
                (1, stride, stride),
            )  # (C, OH, OW)
            # Unpacked layout: every tap pays a (C, oh, ow) -> (ohw, C)
            # gather before the lanes line up.
            pt = patch.transpose(1, 2, 0).reshape(OH * OW, Cg, DOT_WIDTH)
            # (kt, C) -> (Cg, 4, kt): group the reduction by DOT_WIDTH.
            wrs = wb[:, :, r, s].transpose(1, 0).reshape(Cg, DOT_WIDTH, kt)
            # vmlal analogue: contract (group, lane) jointly; narrow each
            # tap to int32 so accumulation stays exact (see pallas_utils).
            tap = lax.dot_general(
                pt, wrs, (((1, 2), (0, 1)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc = acc + tap.astype(jnp.int32)
    o_ref[0] = acc.reshape(OH, OW, kt).transpose(2, 0, 1)


def conv2d_simd_int8(
    x,
    w,
    stride: int = 1,
    padding: int = 0,
    k_tile: int = 16,
):
    """vmlal-style int8 conv2d, NCHW in / NCHW out, int32 accumulators.

    ``x``: (N, C, H, W) int8; ``w``: (K, C, R, S) int8.
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    N, C, H, W = x.shape
    K, Cw, R, S = w.shape
    assert C == Cw

    OH = ref.conv_out_size(H, R, stride, padding)
    OW = ref.conv_out_size(W, S, stride, padding)
    assert C <= EXACT_CHUNK, f"int8 simd: C={C} exceeds the exact range"

    # Reduction must be a multiple of the dot width (zero-pad is exact for
    # symmetric int8); K must tile by kt.
    Cp = round_up(C, DOT_WIDTH)
    kt = min(k_tile, K)
    Kp = round_up(K, kt)
    xq = pad_axis_to(x, 1, Cp)
    wq = pad_axis_to(pad_axis_to(w, 1, Cp), 0, Kp)

    xq = jnp.pad(xq, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    Hp, Wp = xq.shape[2], xq.shape[3]

    kernel = functools.partial(
        _simd_conv_kernel, stride=stride, R=R, S=S, OH=OH, OW=OW, C=Cp, kt=kt
    )
    out = pl.pallas_call(
        kernel,
        grid=(N, Kp // kt),
        in_specs=[
            pl.BlockSpec((1, Cp, Hp, Wp), lambda n, ko: (n, 0, 0, 0)),
            pl.BlockSpec((kt, Cp, R, S), lambda n, ko: (ko, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kt, OH, OW), lambda n, ko: (n, ko, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Kp, OH, OW), jnp.int32),
        interpret=INTERPRET,
    )(xq, wq)
    return out[:, :K]
