"""L1: Pallas kernels — the tensor-optimization layer (TVM schedules, rebuilt).

One kernel per (schedule × precision) conv2d strategy the paper benchmarks
(Table 2), plus the qnn boundary operators and dense.  Everything here is
lowered with ``interpret=True`` (see ``pallas_utils.INTERPRET``) so the HLO
runs on the rust CPU PJRT client; ``ref.py`` holds the pure-jnp oracles.
"""

from .conv_interleaved import conv2d_quantized_interleaved_nhwc, im2col_nhwc
from .conv_nhwc import conv2d_spatial_pack_nhwc
from .conv_simd import conv2d_simd_int8
from .conv_spatial_pack import conv2d_spatial_pack_nchw
from .nn_ops import (
    add,
    bias_add,
    dense,
    global_avgpool,
    maxpool2d,
    relu,
)
from .qdq import dequantize, quantize, requantize, requantize_fixed_point

__all__ = [
    "conv2d_quantized_interleaved_nhwc",
    "conv2d_simd_int8",
    "conv2d_spatial_pack_nchw",
    "conv2d_spatial_pack_nhwc",
    "im2col_nhwc",
    "add",
    "bias_add",
    "dense",
    "global_avgpool",
    "maxpool2d",
    "relu",
    "quantize",
    "dequantize",
    "requantize",
    "requantize_fixed_point",
]
