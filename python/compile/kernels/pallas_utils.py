"""Shared plumbing for the Pallas kernel layer.

All kernels in this package are lowered with ``interpret=True``: the CPU PJRT
plugin cannot execute Mosaic custom-calls, and interpret-mode ``pallas_call``
lowers to plain HLO that any backend (including the rust runtime's
``PjRtClient::cpu()``) runs.  On a real TPU the same kernels would be lowered
with ``interpret=False`` — BlockSpecs are already shaped for VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Single switch for the whole kernel library; real-TPU builds flip this off.
INTERPRET = True

# Element count per grid step for element-wise kernels.  8192 * 4 B = 32 KiB
# per block — comfortably inside a VMEM budget and large enough to amortize
# grid overhead on CPU.
ELEMWISE_BLOCK = 8192


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def elementwise_call(body, x, out_dtype):
    """Run ``body(x_block) -> out_block`` over ``x`` tiled in 1-D blocks.

    ``x`` may have any shape; it is flattened, zero-padded to a block
    multiple, processed on a 1-D grid, and reshaped back.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    blk = min(ELEMWISE_BLOCK, round_up(max(n, 1), 128))
    padded = round_up(max(n, 1), blk)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))

    def kernel(x_ref, o_ref):
        o_ref[...] = body(x_ref[...])

    out = pl.pallas_call(
        kernel,
        grid=(padded // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), out_dtype),
        interpret=INTERPRET,
    )(flat)
    return out[:n].reshape(shape)


# ---------------------------------------------------------------------------
# int8 contraction strategy
# ---------------------------------------------------------------------------
# The deployment runtime is xla_extension 0.5.1, whose CPU backend has no
# fast s8×s8→s32 GEMM (it falls back to a naive loop ~5× slower than f32).
# This is the substrate analogue of "hardware without int8 SIMD".  We
# therefore lower int8 contractions as f32 GEMMs over the int8 operands —
# EXACT as long as every partial sum stays below 2^24 (each int8×int8
# product ≤ 127² = 16129 is exactly representable; f32 integer arithmetic is
# exact up to 2^24).  Contractions longer than _EXACT_CHUNK taps are split
# and accumulated in int32, preserving bit-exactness unconditionally.  The
# int8 *storage* advantage (4× smaller operands through memory and cache)
# is preserved, which is the mechanism this substrate can honestly express;
# see DESIGN.md §Hardware-Adaptation.
#
# 1040 * 127 * 127 < 2^24 ≤ 1041 * 127 * 127.
_EXACT_CHUNK = 1024
EXACT_CHUNK = _EXACT_CHUNK

import jax.lax as _lax


def int8_matmul(a, b):
    """(M, K) int8 × (K, N) int8 → (M, N) int32, bit-exact.

    Contraction is chunked so each f32 partial sum stays in the exact
    integer range; chunks accumulate in int32.
    """
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    m, k = a.shape
    _, n = b.shape
    dims = (((1,), (0,)), ((), ()))

    def one(a_c, b_c):
        r = _lax.dot_general(
            a_c.astype(jnp.float32), b_c.astype(jnp.float32), dims,
            preferred_element_type=jnp.float32,
        )
        return r.astype(jnp.int32)

    if k <= _EXACT_CHUNK:
        return one(a, b)
    acc = jnp.zeros((m, n), jnp.int32)
    for start in range(0, k, _EXACT_CHUNK):
        stop = min(start + _EXACT_CHUNK, k)
        acc = acc + one(a[:, start:stop], b[start:stop, :])
    return acc


def int8_dot_general(a, b, dimension_numbers, contraction_size: int):
    """General int8 contraction → int32 via exact f32 emulation.

    ``contraction_size`` is the total number of reduced elements; it must be
    within the exact range (callers with longer reductions use
    :func:`int8_matmul`'s chunking or split themselves).
    """
    assert contraction_size <= _EXACT_CHUNK, (
        f"contraction {contraction_size} exceeds exact f32 range; chunk it"
    )
    r = _lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32), dimension_numbers,
        preferred_element_type=jnp.float32,
    )
    return r.astype(jnp.int32)


def pad_axis_to(x, axis: int, size: int):
    """Zero-pad ``x`` along ``axis`` up to ``size`` (no-op if already there)."""
    cur = x.shape[axis]
    if cur == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(x, pads)
