"""Remaining network operators: dense (Pallas), pooling, residual add.

The paper's analysis centres on conv2d ("the most computationally intensive
task in our model", §3.2.1); dense is the only other MXU-shaped op in
ResNet and gets Pallas kernels in both precisions.  Pooling and element-wise
ops are bandwidth-bound and stay plain XLA ops — exactly as TVM leaves them
to generic schedules.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pallas_utils import INTERPRET, cdiv, int8_matmul, pad_axis_to, round_up
from . import ref


def _dense_kernel(x_ref, w_ref, o_ref, *, accum_dtype):
    if accum_dtype == jnp.int32:
        o_ref[...] = int8_matmul(x_ref[...], w_ref[...])
    else:
        o_ref[...] = lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=accum_dtype,
        )


def dense(x, w, m_tile: int = 128):
    """Tiled matmul: (M, K) @ (K, N) -> (M, N).

    fp32 -> fp32; int8 -> int32 accumulators (operands stay int8 in the dot).
    """
    M, K = x.shape
    Kw, N = w.shape
    assert K == Kw
    int8_in = x.dtype == jnp.int8
    accum_dtype = jnp.int32 if int8_in else jnp.float32

    TM = min(m_tile, M)
    Mp = round_up(M, TM)
    xq = pad_axis_to(x, 0, Mp)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, accum_dtype=accum_dtype),
        grid=(Mp // TM,),
        in_specs=[
            pl.BlockSpec((TM, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TM, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), accum_dtype),
        interpret=INTERPRET,
    )(xq, w)
    return out[:M]


# ---------------------------------------------------------------------------
# Bandwidth-bound ops (plain XLA, both layouts)
# ---------------------------------------------------------------------------

def maxpool2d(x, window: int, stride: int, padding: int = 0, layout: str = "NCHW"):
    if layout == "NCHW":
        dims, strides = (1, 1, window, window), (1, 1, stride, stride)
        pads = [(0, 0), (0, 0), (padding, padding), (padding, padding)]
    else:  # NHWC
        dims, strides = (1, window, window, 1), (1, stride, stride, 1)
        pads = [(0, 0), (padding, padding), (padding, padding), (0, 0)]
    return lax.reduce_window(x, -jnp.inf, lax.max, dims, strides, pads)


def global_avgpool(x, layout: str = "NCHW"):
    axes = (2, 3) if layout == "NCHW" else (1, 2)
    return jnp.mean(x, axis=axes)


def add(a, b):
    return a + b


def relu(x):
    return jnp.maximum(x, 0)


def bias_add(x, bias, layout: str = "NCHW"):
    """Add a per-output-channel bias to a conv result."""
    if layout == "NCHW":
        return x + bias[None, :, None, None]
    return x + bias[None, None, None, :]
