"""Quantize / dequantize / requantize Pallas kernels.

These are TVM's qnn boundary operators, rebuilt: the paper (§3.2.2) observes
that TVM's quantized graphs are stitched out of exactly two memory-traffic
patterns — "one operator reads int8 values and writes fp32 values into
memory, while the other reads fp32 and writes int8" — and that scales stay
fp32.  These kernels are those operators.

Scales are *static* Python floats: after calibration the quantization pass
bakes them into the graph as constants, exactly as TVM's ``relay.quantize``
realize step does.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .pallas_utils import elementwise_call

QMIN = ref.QMIN
QMAX = ref.QMAX


def quantize(x, scale: float):
    """fp32 -> int8 at per-tensor symmetric ``scale`` (reads fp32, writes int8)."""
    inv = float(1.0 / scale)

    def body(v):
        return jnp.clip(jnp.round(v * inv), QMIN, QMAX).astype(jnp.int8)

    return elementwise_call(body, x, jnp.int8)


def dequantize(q, scale: float):
    """int8/int32 -> fp32 at ``scale`` (reads int, writes fp32)."""
    s = float(scale)

    def body(v):
        return v.astype(jnp.float32) * s

    return elementwise_call(body, q, jnp.float32)


def requantize(acc, in_scale: float, out_scale: float):
    """int32 accumulator at ``in_scale`` -> int8 at ``out_scale``.

    Float rescale path (TVM also offers this via ``rounding="UPWARD"`` float
    fallback); the pure-integer path is :func:`requantize_fixed_point`.
    """
    m = float(in_scale / out_scale)

    def body(v):
        return jnp.clip(jnp.round(v.astype(jnp.float32) * m), QMIN, QMAX).astype(
            jnp.int8
        )

    return elementwise_call(body, acc, jnp.int8)


def requantize_fixed_point(acc, multiplier: int, shift: int):
    """Pure-integer requantize (Q31 fixed-point), no float ops on the path.

    Matches :func:`ref.requantize_fixed_point` bit-for-bit; use
    :func:`ref.choose_quant_multiplier` to derive ``(multiplier, shift)``.
    The Q31 product needs 62 bits, so tracing runs under ``enable_x64``
    (dtypes are baked into the jaxpr; the surrounding program stays 32-bit).
    """
    from jax.experimental import enable_x64

    mult = int(multiplier)
    total = 31 - int(shift)
    if total <= 0:
        raise ValueError(f"shift={shift} too large (total={total})")
    rounding = 1 << (total - 1)

    def body(v):
        acc64 = v.astype(jnp.int64) * jnp.int64(mult)
        r = jnp.where(acc64 >= 0, jnp.int64(rounding), jnp.int64(rounding - 1))
        q = (acc64 + r) >> total
        return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)

    with enable_x64():
        return elementwise_call(body, acc, jnp.int8)
