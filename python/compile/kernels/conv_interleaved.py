"""``quantized_interleaved`` int8 conv2d — TVM's NHWC 4×4 MMLA schedule.

TVM's ``conv2d_NHWC_quantized_interleaved`` rewrites the conv as a GEMM:
activations are im2col'ed and *interleaved* into A[4][K] row panels, weights
into B[4][K] panels, and a sequence of NEON intrinsics computes a 4×4 int8
matmul-accumulate tile (≈ the smmla instruction), fusing the NH dimension and
vectorizing it by 4 (§3.2.1, 12.09 ms in Table 2).

TPU re-expression: the im2col interleave is an explicit transform in the
wrapper (its bandwidth cost is the schedule's real price — the reason it
trails packed NCHW despite the same 16× ideal), and the 4×4 intrinsic tile
becomes a BlockSpec GEMM tile whose dimensions are multiples of 4, contracted
in one int8×int8→int32 ``dot_general`` (the MXU analogue of the MMLA chain).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pallas_utils import EXACT_CHUNK, INTERPRET, cdiv, pad_axis_to, round_up
from . import ref

TILE = 4  # the 4×4 intrinsic tile edge


def _gemm_tile_kernel(a_ref, b_ref, o_ref, *, L):
    """One (mt, nt) grid step: an (TM, TN) int32 tile = A_panel · B_panel.

    TM and TN are multiples of 4: each step is a (TM/4)×(TN/4) raster of the
    4×4 intrinsic tile.  Operands arrive pre-widened (f32 holding int8
    values); the contraction is chunked so every partial sum stays in the
    exact f32 integer range, with int32 accumulation across chunks.
    """
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    for start in range(0, L, EXACT_CHUNK):
        stop = min(start + EXACT_CHUNK, L)
        part = lax.dot_general(
            a[:, start:stop], b[start:stop, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc + part.astype(jnp.int32)
    o_ref[...] = acc


def im2col_nhwc(x, R: int, S: int, stride: int, padding: int):
    """(N, H, W, C) -> (N*OH*OW, R*S*C) patch matrix (the interleave step)."""
    N, H, W, C = x.shape
    OH = ref.conv_out_size(H, R, stride, padding)
    OW = ref.conv_out_size(W, S, stride, padding)
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    taps = []
    for r in range(R):
        for s in range(S):
            taps.append(
                lax.slice(
                    xp,
                    (0, r, s, 0),
                    (N, r + (OH - 1) * stride + 1, s + (OW - 1) * stride + 1, C),
                    (1, stride, stride, 1),
                )  # (N, OH, OW, C)
            )
    # (N, OH, OW, R*S, C) -> rows are output pixels, cols are taps×channels.
    cols = jnp.stack(taps, axis=3)
    return cols.reshape(N * OH * OW, R * S * C), OH, OW


def conv2d_quantized_interleaved_nhwc(
    x,
    w,
    stride: int = 1,
    padding: int = 0,
    m_tile: int = 64,
    n_tile: int = 64,
):
    """Interleaved int8 GEMM conv2d, NHWC in / NHWC out, int32 accumulators.

    ``x``: (N, H, W, C) int8; ``w``: (R, S, C, K) int8 (HWIO).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    N, H, W, C = x.shape
    R, S, Cw, K = w.shape
    assert C == Cw

    a, OH, OW = im2col_nhwc(x, R, S, stride, padding)  # (M, L) int8
    b = w.reshape(R * S * C, K)  # (L, K) int8
    M, L = a.shape
    # Widen once (the interleave/im2col transform already materialized the
    # panels; this is the schedule's bandwidth price, as in TVM).
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    TM = round_up(min(m_tile, M), TILE)
    TN = round_up(min(n_tile, K), TILE)
    Mp, Np = round_up(M, TM), round_up(K, TN)
    a = pad_axis_to(a, 0, Mp)
    b = pad_axis_to(b, 1, Np)

    out = pl.pallas_call(
        functools.partial(_gemm_tile_kernel, L=L),
        grid=(Mp // TM, Np // TN),
        in_specs=[
            pl.BlockSpec((TM, L), lambda mt, nt: (mt, 0)),
            pl.BlockSpec((L, TN), lambda mt, nt: (0, nt)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda mt, nt: (mt, nt)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=INTERPRET,
    )(a, b)
    return out[:M, :K].reshape(N, OH, OW, K)
