"""``nchw_spatial_pack`` conv2d — the paper's NCHW{c} blocked schedule (Fig. 1).

TVM's ``nchw_spatial_pack`` converts activations to NCHW{c} (channel-blocked)
and weights to OIHW{i}{o}, so the innermost loops walk contiguous ``c_block``
lanes, and parallelizes H in tiles of 4.  The TPU/Pallas re-expression
(DESIGN.md §Hardware-Adaptation):

- the channel block becomes the minor-most (lane) dimension of the packed
  arrays — a single cheap gather per grid step instead of one per filter tap;
- the H×4 parallelism becomes a grid axis over output-row tiles;
- the K (output channel) blocking becomes a grid axis over ``k_block`` slabs;
- the filter-tap loop is unrolled into R*S strided-slice + matmul pairs whose
  contraction runs over the *packed-contiguous* channel axis.

Both precisions share one kernel body.  The int8 variant keeps tensors s8
through memory (the storage/bandwidth advantage this substrate can express)
and contracts via the exact f32 emulation described in ``pallas_utils`` —
the deployment XLA (0.5.1 CPU) has no s8 GEMM fast path, so the ALU-width
speedup is modelled analytically (perfmodel), not executed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pallas_utils import EXACT_CHUNK, INTERPRET, cdiv, pad_axis_to, round_up
from . import ref


def _packed_conv_kernel(
    x_ref, w_ref, o_ref, *, stride, R, S, OW, TH, C, kb, accum_dtype
):
    """One (n, ko, ht) grid step: a (TH, OW, kb) output tile.

    x_ref: (1, Hp, Wp, C)   — sample ``n``, channel-packed (co*cb flattened)
    w_ref: (1, R, S, C, kb) — weight slab ``ko``
    o_ref: (1, 1, TH, OW, kb)
    """
    ht = pl.program_id(2)
    xb = x_ref[0]  # (Hp, Wp, C)
    th_in = (TH - 1) * stride + R
    hin0 = ht * TH * stride
    # Input row window for this output-row tile.  The wrapper pads H so this
    # slice is always in bounds (dynamic_slice clamping would mis-align rows).
    xwin = lax.dynamic_slice(xb, (hin0, 0, 0), (th_in, xb.shape[1], C))
    wb = w_ref[0]  # (R, S, C, kb)

    int8_in = accum_dtype == jnp.int32
    if int8_in:
        # int8 path (exact f32 emulation, see pallas_utils): the s8 window
        # is widened ONCE — all nine overlapping tap reads then hit the
        # cache-resident f32 copy, while the cold-memory traffic stayed s8.
        # Tap results are narrowed to int32 before accumulation so partial
        # sums never leave the exact range (9 taps × C ≤ 1040 × 127² can
        # exceed 2^24 in f32, one tap cannot).
        xwin = xwin.astype(jnp.float32)
        wb = wb.astype(jnp.float32)
    acc = jnp.zeros((TH * OW, kb), accum_dtype)
    for r in range(R):
        for s in range(S):
            patch = lax.slice(
                xwin,
                (r, s, 0),
                (r + (TH - 1) * stride + 1, s + (OW - 1) * stride + 1, C),
                (stride, stride, 1),
            )  # (TH, OW, C)
            pm = patch.reshape(TH * OW, C)
            tap = lax.dot_general(
                pm, wb[r, s], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc = acc + (tap.astype(jnp.int32) if int8_in else tap)
    o_ref[0, 0] = acc.reshape(TH, OW, kb)


def conv2d_spatial_pack_nchw(
    x,
    w,
    stride: int = 1,
    padding: int = 0,
    c_block: int = 16,
    k_block: int = 16,
    h_tile: int = 4,
):
    """Spatially-packed conv2d, NCHW in / NCHW out.

    ``x``: (N, C, H, W) fp32 or int8; ``w``: (K, C, R, S) same dtype.
    Returns (N, K, OH, OW) — fp32 for fp32 inputs, int32 accumulators for
    int8 inputs (requantization is a separate graph operator, as in TVM).
    """
    N, C, H, W = x.shape
    K, Cw, R, S = w.shape
    assert C == Cw, f"channel mismatch {C} vs {Cw}"
    int8_in = x.dtype == jnp.int8
    accum_dtype = jnp.int32 if int8_in else jnp.float32
    if int8_in:
        assert C <= EXACT_CHUNK, (
            f"int8 spatial_pack: C={C} exceeds the exact f32-emulation range"
        )

    OH = ref.conv_out_size(H, R, stride, padding)
    OW = ref.conv_out_size(W, S, stride, padding)
    TH = min(h_tile, OH)
    OHt = cdiv(OH, TH)
    kb = min(k_block, K)
    Kp = round_up(K, kb)

    # Channel-pack: pad C to the block, move the block to the minor axis.
    cb = min(c_block, C)
    Cp = round_up(C, cb)
    xq = pad_axis_to(x, 1, Cp)
    wq = pad_axis_to(pad_axis_to(w, 1, Cp), 0, Kp)

    # NCHW -> N H W (Co*cb): the Figure-1 packed layout with the co/cb pair
    # flattened so kernels contract over one contiguous axis.
    xp = (
        xq.reshape(N, Cp // cb, cb, H, W)
        .transpose(0, 3, 4, 1, 2)
        .reshape(N, H, W, Cp)
    )
    # Weights -> (Ko, R, S, Co*cb, kb), co-major to match the activation pack.
    wp = (
        wq.reshape(Kp // kb, kb, Cp // cb, cb, R, S)
        .transpose(0, 4, 5, 2, 3, 1)
        .reshape(Kp // kb, R, S, Cp, kb)
    )

    # Spatial pad; extend H so every output-row tile's input window is
    # in-bounds (see kernel comment).
    need_h = (OHt * TH - 1) * stride + R
    hp_total = max(H + 2 * padding, need_h)
    xp = jnp.pad(
        xp,
        ((0, 0), (padding, hp_total - H - padding), (padding, padding), (0, 0)),
    )
    Hp, Wp = xp.shape[1], xp.shape[2]

    kernel = functools.partial(
        _packed_conv_kernel,
        stride=stride,
        R=R,
        S=S,
        OW=OW,
        TH=TH,
        C=Cp,
        kb=kb,
        accum_dtype=accum_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(N, Kp // kb, OHt),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cp), lambda n, ko, ht: (n, 0, 0, 0)),
            pl.BlockSpec((1, R, S, Cp, kb), lambda n, ko, ht: (ko, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, TH, OW, kb), lambda n, ko, ht: (n, ko, ht, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((N, Kp // kb, OHt * TH, OW, kb), accum_dtype),
        interpret=INTERPRET,
    )(xp, wp)

    # Unpack NKhw{k} -> NKHW and strip padding.
    out = out.transpose(0, 1, 4, 2, 3).reshape(N, Kp, OHt * TH, OW)
    return out[:, :K, :OH, :]
