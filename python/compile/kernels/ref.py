"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: slow, obvious, layout-naive
implementations that the Pallas kernels (and the L2 model built from them)
are tested against.  Integer paths are bit-exact (int32 accumulation); float
paths are compared with ``assert_allclose``.

Conventions
-----------
- NCHW activations are ``(N, C, H, W)``; weights are OIHW ``(K, C, R, S)``.
- NHWC activations are ``(N, H, W, C)``; weights are HWIO ``(R, S, C, K)``.
- ``padding`` is a single symmetric spatial pad; ``stride`` is isotropic.
- Quantization is per-tensor symmetric int8: ``q = clip(round(x/s), -127, 127)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

QMIN = -127
QMAX = 127


# ---------------------------------------------------------------------------
# Convolutions
# ---------------------------------------------------------------------------

def conv2d_nchw(x, w, stride: int = 1, padding: int = 0):
    """fp32 reference conv, NCHW/OIHW."""
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_nchw_int8(x, w, stride: int = 1, padding: int = 0):
    """Bit-exact int8 conv: int8 x, int8 w -> int32 accumulator.

    Widened to int32 *before* the convolution so the result is exact; this is
    the oracle only — production kernels keep operands int8 for speed.
    """
    return lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32), (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_nhwc(x, w, stride: int = 1, padding: int = 0):
    """fp32 reference conv, NHWC/HWIO."""
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_nhwc_int8(x, w, stride: int = 1, padding: int = 0):
    """Bit-exact int8 NHWC conv -> int32."""
    return lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32), (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_out_size(size: int, r: int, stride: int, padding: int) -> int:
    return (size + 2 * padding - r) // stride + 1


# ---------------------------------------------------------------------------
# Quantize / dequantize / requantize
# ---------------------------------------------------------------------------

def quantize(x, scale):
    """fp32 -> int8, per-tensor symmetric."""
    return jnp.clip(jnp.round(x / scale), QMIN, QMAX).astype(jnp.int8)


def dequantize(q, scale):
    """int8 (or int32 accumulator) -> fp32."""
    return q.astype(jnp.float32) * scale


def requantize(acc, in_scale, out_scale):
    """int32 accumulator at ``in_scale`` -> int8 at ``out_scale``."""
    return jnp.clip(
        jnp.round(acc.astype(jnp.float32) * (in_scale / out_scale)), QMIN, QMAX
    ).astype(jnp.int8)


def requantize_fixed_point(acc, multiplier: int, shift: int):
    """Pure-integer requantize: ``(acc * m) >> (31 - shift)`` with
    round-half-away-from-zero, as TVM's qnn.requantize does it.

    ``multiplier`` is a Q31 fixed-point mantissa in [2^30, 2^31); ``shift``
    is the (possibly negative) exponent from :func:`choose_quant_multiplier`.
    """
    acc64 = acc.astype(jnp.int64) * jnp.int64(multiplier)
    total = 31 - shift
    rounding = jnp.int64(1) << (total - 1)
    q = (acc64 + jnp.where(acc64 >= 0, rounding, rounding - 1)) >> total
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def choose_quant_multiplier(real_multiplier: float) -> tuple[int, int]:
    """Decompose a positive real multiplier into (Q31 mantissa, shift)."""
    import math

    if real_multiplier <= 0:
        raise ValueError("multiplier must be positive")
    mant, exp = math.frexp(real_multiplier)  # mant in [0.5, 1)
    q = int(round(mant * (1 << 31)))
    if q == (1 << 31):  # rounding overflow: mant was ~1.0
        q //= 2
        exp += 1
    return q, exp


def abs_max_scale(x, bits: int = 8) -> jnp.ndarray:
    """Calibration: symmetric per-tensor scale from the absolute maximum."""
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax


# ---------------------------------------------------------------------------
# Dense / pooling / misc
# ---------------------------------------------------------------------------

def dense(x, w):
    """fp32 matmul reference: (M, K) @ (K, N)."""
    return x @ w


def dense_int8(x, w):
    """Bit-exact int8 matmul -> int32."""
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def maxpool2d_nchw(x, window: int, stride: int, padding: int = 0):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, window, window), (1, 1, stride, stride),
        [(0, 0), (0, 0), (padding, padding), (padding, padding)],
    )


def avgpool2d_nchw(x, window: int, stride: int):
    s = lax.reduce_window(
        x, 0.0, lax.add, (1, 1, window, window), (1, 1, stride, stride), "VALID"
    )
    return s / (window * window)


def global_avgpool_nchw(x):
    return jnp.mean(x, axis=(2, 3))


def relu(x):
    return jnp.maximum(x, 0)


# ---------------------------------------------------------------------------
# Layout packing (Figure 1: NCHW -> NCHW{c})
# ---------------------------------------------------------------------------

def pack_nchw_to_nchwc(x, c_block: int):
    """(N, C, H, W) -> (N, C//cb, H, W, cb).  C must divide by c_block."""
    n, c, h, w = x.shape
    assert c % c_block == 0, f"C={c} not divisible by c_block={c_block}"
    return x.reshape(n, c // c_block, c_block, h, w).transpose(0, 1, 3, 4, 2)


def unpack_nchwc_to_nchw(xp):
    """(N, Co, H, W, cb) -> (N, Co*cb, H, W)."""
    n, co, h, w, cb = xp.shape
    return xp.transpose(0, 1, 4, 2, 3).reshape(n, co * cb, h, w)


def pack_oihw_to_oihwio(w, c_block: int, k_block: int):
    """(K, C, R, S) -> (K//kb, C//cb, R, S, cb, kb)."""
    k, c, r, s = w.shape
    assert c % c_block == 0 and k % k_block == 0
    return (
        w.reshape(k // k_block, k_block, c // c_block, c_block, r, s)
        .transpose(0, 2, 4, 5, 3, 1)
    )
