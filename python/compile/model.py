"""L2: ResNet in JAX, built from the L1 schedule kernels.

The model is expressed as a list of *segments* — the unit of partitioning
that the paper's executor analysis revolves around (§3.1):

- the **graph executor** path composes all segments into one jax function and
  lowers it to a single fused HLO module (static graph, every op pre-defined);
- the **VM executor** path lowers each segment to its own HLO module, and the
  rust VM interpreter dispatches them one instruction at a time with dynamic
  allocation — TVM's default for quantized models, the paper's bug.

For int8 models the segment boundaries carry int8 tensors ("the quantized
data space"): a *prefix* segment quantizes the input, *middle* segments are
the core quantized network, and the *suffix* dequantizes into logits —
exactly the three-way split the paper describes.  Inside segments the
quantized conv unit follows TVM's realized pattern (§3.2.2): int8 conv with
int32 accumulators, dequantize to fp32 for bias/relu/residual arithmetic,
re-quantize at the next boundary; scales stay fp32 throughout.

Weights are baked into the lowered modules as constants, mirroring the graph
executor's parameter binding; batch-norm is assumed folded (inference).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels as K
from .kernels import ref

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# (channels, num_blocks, first_stride) per stage.
ARCHS = {
    # CIFAR-scale: the default bench model (fast enough for interpret-mode
    # Pallas through the whole table sweep).
    "resnet10": dict(
        stem_kernel=3, stem_stride=1, stem_pool=False,
        stages=[(16, 1, 1), (32, 1, 2), (64, 1, 2), (128, 1, 2)],
    ),
    # The paper's model, spatially scaled (DESIGN.md §Substitutions): full
    # basic-block layout, 7x7 stem + maxpool.
    "resnet18": dict(
        stem_kernel=7, stem_stride=2, stem_pool=True,
        stages=[(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)],
    ),
    # Minimal arch for fast unit tests.
    "resnet4": dict(
        stem_kernel=3, stem_stride=1, stem_pool=False,
        stages=[(8, 1, 2)],
    ),
}

SCHEDULES = ("spatial_pack", "simd", "interleaved", "reference")
LAYOUTS = ("NCHW", "NHWC")
PRECISIONS = ("fp32", "int8")

# (layout, schedule, precision) combinations TVM actually provides — the
# paper's point that "different settings map to different schedules".
VALID_COMBOS = {
    ("NCHW", "spatial_pack", "fp32"),   # Table 2 row 1 (TVM fp32 default)
    ("NCHW", "spatial_pack", "int8"),   # Table 2 row 2 (best)
    ("NCHW", "simd", "int8"),           # Table 2 row 3 (vmlal)
    ("NHWC", "spatial_pack", "fp32"),   # Table 2 row 4 (worst)
    ("NHWC", "interleaved", "int8"),    # Table 2 row 5 (MMLA)
    ("NCHW", "reference", "fp32"),      # eager baseline (PyTorch row)
    ("NHWC", "reference", "fp32"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str = "resnet10"
    image_size: int = 32
    in_channels: int = 3
    num_classes: int = 10
    layout: str = "NCHW"
    schedule: str = "spatial_pack"
    precision: str = "fp32"
    c_block: int = 16
    k_block: int = 16
    h_tile: int = 4

    def __post_init__(self):
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}")
        combo = (self.layout, self.schedule, self.precision)
        if combo not in VALID_COMBOS:
            raise ValueError(
                f"no TVM schedule for {combo}; valid: {sorted(VALID_COMBOS)}"
            )

    @property
    def variant_id(self) -> str:
        return (
            f"{self.arch}_{self.image_size}_{self.layout.lower()}"
            f"_{self.schedule}_{self.precision}"
        )


# ---------------------------------------------------------------------------
# Parameters (canonical storage: OIHW fp32; layout applied at build time)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """He-initialized fp32 parameters; BN assumed pre-folded."""
    rng = np.random.default_rng(seed)
    arch = ARCHS[cfg.arch]

    def conv_w(k_out, k_in, r):
        std = float(np.sqrt(2.0 / (k_in * r * r)))
        return rng.standard_normal((k_out, k_in, r, r)).astype(np.float32) * std

    def bias(k):
        return rng.standard_normal((k,)).astype(np.float32) * 0.05

    params: dict = {}
    r0 = arch["stem_kernel"]
    c0 = arch["stages"][0][0]
    params["stem"] = {"w": conv_w(c0, cfg.in_channels, r0), "b": bias(c0)}

    blocks = []
    in_ch = c0
    for ch, nblocks, first_stride in arch["stages"]:
        for i in range(nblocks):
            stride = first_stride if i == 0 else 1
            blk = {
                "conv1": {"w": conv_w(ch, in_ch, 3), "b": bias(ch)},
                "conv2": {"w": conv_w(ch, ch, 3), "b": bias(ch)},
                "stride": stride,
            }
            if stride != 1 or in_ch != ch:
                blk["down"] = {"w": conv_w(ch, in_ch, 1), "b": bias(ch)}
            blocks.append(blk)
            in_ch = ch
    params["blocks"] = blocks
    params["head"] = {
        "w": rng.standard_normal((in_ch, cfg.num_classes)).astype(np.float32)
        * float(np.sqrt(1.0 / in_ch)),
        "b": bias(cfg.num_classes),
    }
    return params


def param_count(params: dict) -> int:
    n = params["stem"]["w"].size + params["stem"]["b"].size
    for blk in params["blocks"]:
        for key in ("conv1", "conv2", "down"):
            if key in blk:
                n += blk[key]["w"].size + blk[key]["b"].size
    n += params["head"]["w"].size + params["head"]["b"].size
    return int(n)


def weight_scale(w: np.ndarray) -> float:
    """Per-tensor symmetric weight scale (abs-max calibration)."""
    return float(np.maximum(np.abs(np.asarray(w, np.float32)).max(), 1e-8) / 127.0)


def quantize_weight(w: np.ndarray, s_w: float) -> np.ndarray:
    return np.clip(np.round(np.asarray(w, np.float32) / s_w), -127, 127).astype(
        np.int8
    )


# ---------------------------------------------------------------------------
# Conv dispatch: one entry point per (layout, schedule, precision)
# ---------------------------------------------------------------------------

def _conv_fp32(x, w_oihw, stride, padding, cfg: ModelConfig):
    """fp32 conv in the configured layout/schedule.  x in cfg.layout."""
    if cfg.layout == "NCHW":
        if cfg.schedule == "reference":
            return ref.conv2d_nchw(x, w_oihw, stride, padding)
        return K.conv2d_spatial_pack_nchw(
            x, w_oihw, stride, padding,
            c_block=cfg.c_block, k_block=cfg.k_block, h_tile=cfg.h_tile,
        )
    w_hwio = jnp.transpose(w_oihw, (2, 3, 1, 0))
    if cfg.schedule == "reference":
        return ref.conv2d_nhwc(x, w_hwio, stride, padding)
    return K.conv2d_spatial_pack_nhwc(x, w_hwio, stride, padding, h_tile=cfg.h_tile)


def _conv_int8(x_q, w_q_oihw, stride, padding, cfg: ModelConfig):
    """int8 conv -> int32 accumulators in the configured schedule."""
    if cfg.schedule == "spatial_pack":
        return K.conv2d_spatial_pack_nchw(
            x_q, w_q_oihw, stride, padding,
            c_block=cfg.c_block, k_block=cfg.k_block, h_tile=cfg.h_tile,
        )
    if cfg.schedule == "simd":
        return K.conv2d_simd_int8(x_q, w_q_oihw, stride, padding, k_tile=cfg.k_block)
    if cfg.schedule == "interleaved":
        w_hwio = jnp.transpose(w_q_oihw, (2, 3, 1, 0))
        return K.conv2d_quantized_interleaved_nhwc(x_q, w_hwio, stride, padding)
    raise ValueError(f"no int8 schedule {cfg.schedule!r}")


def conv_unit_fp32(x, p, stride, padding, cfg, relu=True):
    y = _conv_fp32(x, jnp.asarray(p["w"]), stride, padding, cfg)
    y = K.bias_add(y, jnp.asarray(p["b"]), cfg.layout)
    return K.relu(y) if relu else y


def conv_unit_int8(x_q, p, s_in, stride, padding, cfg, relu=True):
    """TVM's realized quantized conv unit: int8 in, fp32 out.

    ``x_q`` is int8 at scale ``s_in``; the weight is quantized at build time
    with its own per-tensor abs-max scale; the int32 accumulator is
    dequantized at ``s_in * s_w`` — the "reads int8, writes fp32" operator of
    §3.2.2.
    """
    s_w = weight_scale(p["w"])
    w_q = jnp.asarray(quantize_weight(p["w"], s_w))
    acc = _conv_int8(x_q, w_q, stride, padding, cfg)
    y = K.dequantize(acc, float(s_in) * s_w)
    y = K.bias_add(y, jnp.asarray(p["b"]), cfg.layout)
    return K.relu(y) if relu else y


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Segment:
    """One partition unit: a jax function plus its boundary specs.

    Shapes use -1 for the batch dimension; it is resolved at lowering time.
    """

    name: str
    fn: Callable
    in_shape: tuple
    in_dtype: str   # "f32" | "s8"
    out_shape: tuple
    out_dtype: str
    role: str       # "prefix" | "middle" | "suffix"


def _spatial(cfg: ModelConfig, n: int, c: int, hw: int) -> tuple:
    if cfg.layout == "NCHW":
        return (n, c, hw, hw)
    return (n, hw, hw, c)


def _block_specs(cfg: ModelConfig):
    specs = []
    for ch, nblocks, first_stride in ARCHS[cfg.arch]["stages"]:
        for i in range(nblocks):
            specs.append((ch, first_stride if i == 0 else 1))
    return specs


def _trace_shapes(cfg: ModelConfig):
    """(name, channels, spatial) at every segment boundary (post-segment)."""
    arch = ARCHS[cfg.arch]
    hw = cfg.image_size
    hw = ref.conv_out_size(hw, arch["stem_kernel"], arch["stem_stride"],
                           arch["stem_kernel"] // 2)
    if arch["stem_pool"]:
        hw = ref.conv_out_size(hw, 3, 2, 1)
    shapes = [("stem", arch["stages"][0][0], hw)]
    for bi, (ch, stride) in enumerate(_block_specs(cfg)):
        hw = ref.conv_out_size(hw, 3, stride, 1)
        shapes.append((f"block{bi}", ch, hw))
    return shapes


def _maxpool_if_needed(x, cfg):
    if ARCHS[cfg.arch]["stem_pool"]:
        return K.maxpool2d(x, 3, 2, 1, layout=cfg.layout)
    return x


def _basic_block_fp32(x, blk, cfg):
    stride = blk["stride"]
    y = conv_unit_fp32(x, blk["conv1"], stride, 1, cfg, relu=True)
    y = conv_unit_fp32(y, blk["conv2"], 1, 1, cfg, relu=False)
    if "down" in blk:
        skip = conv_unit_fp32(x, blk["down"], stride, 0, cfg, relu=False)
    else:
        skip = x
    return K.relu(K.add(y, skip))


def _basic_block_int8(x_q, blk, scales, name, cfg):
    """int8-boundary residual block: int8@s_in -> int8@s_out."""
    stride = blk["stride"]
    s_in = float(scales[name + ".conv1.in"])
    y = conv_unit_int8(x_q, blk["conv1"], s_in, stride, 1, cfg, relu=True)
    # Second conv re-enters the quantized space at the mid-block scale.
    s_mid = float(scales[name + ".conv2.in"])
    y_q = K.quantize(y, s_mid)
    y = conv_unit_int8(y_q, blk["conv2"], s_mid, 1, 1, cfg, relu=False)
    if "down" in blk:
        skip = conv_unit_int8(x_q, blk["down"], s_in, stride, 0, cfg, relu=False)
    else:
        skip = K.dequantize(x_q, s_in)
    z = K.relu(K.add(y, skip))
    return K.quantize(z, float(scales[name + ".out"]))


def build_segments(cfg: ModelConfig, params: dict, scales: dict | None = None):
    """Return the list of :class:`Segment` for this config.

    fp32 models exchange fp32 tensors; int8 models exchange int8 tensors with
    a quantizing prefix and a dequantizing suffix (the paper's VM partition).
    """
    if cfg.precision == "int8" and scales is None:
        raise ValueError("int8 model requires calibration scales")
    arch = ARCHS[cfg.arch]
    n = -1
    bshapes = _trace_shapes(cfg)
    img_shape = _spatial(cfg, n, cfg.in_channels, cfg.image_size)
    segs: list[Segment] = []
    stem_pad = arch["stem_kernel"] // 2

    if cfg.precision == "fp32":
        def stem_fn(x, _p=params["stem"]):
            y = conv_unit_fp32(x, _p, arch["stem_stride"], stem_pad, cfg)
            return _maxpool_if_needed(y, cfg)

        segs.append(Segment(
            "stem", stem_fn, img_shape, "f32",
            _spatial(cfg, n, bshapes[0][1], bshapes[0][2]), "f32", "middle",
        ))
        for bi, blk in enumerate(params["blocks"]):
            def blk_fn(x, _blk=blk):
                return _basic_block_fp32(x, _blk, cfg)
            segs.append(Segment(
                f"block{bi}", blk_fn,
                _spatial(cfg, n, bshapes[bi][1], bshapes[bi][2]), "f32",
                _spatial(cfg, n, bshapes[bi + 1][1], bshapes[bi + 1][2]), "f32",
                "middle",
            ))

        def head_fn(x, _p=params["head"]):
            pooled = K.global_avgpool(x, cfg.layout)
            return K.dense(pooled, jnp.asarray(_p["w"])) + jnp.asarray(_p["b"])

        segs.append(Segment(
            "head", head_fn,
            _spatial(cfg, n, bshapes[-1][1], bshapes[-1][2]), "f32",
            (n, cfg.num_classes), "f32", "suffix",
        ))
        return segs

    # ---- int8: prefix / middle / suffix over int8 boundaries ----
    s_img = float(scales["input"])

    def prefix_fn(x):
        return K.quantize(x, s_img)

    segs.append(Segment(
        "prefix", prefix_fn, img_shape, "f32", img_shape, "s8", "prefix",
    ))

    def stem_fn_q(x_q, _p=params["stem"]):
        y = conv_unit_int8(x_q, _p, s_img, arch["stem_stride"], stem_pad, cfg)
        y = _maxpool_if_needed(y, cfg)
        return K.quantize(y, float(scales["stem.out"]))

    segs.append(Segment(
        "stem", stem_fn_q, img_shape, "s8",
        _spatial(cfg, n, bshapes[0][1], bshapes[0][2]), "s8", "middle",
    ))

    for bi, blk in enumerate(params["blocks"]):
        def blk_fn_q(x_q, _blk=blk, _name=f"block{bi}"):
            return _basic_block_int8(x_q, _blk, scales, _name, cfg)
        segs.append(Segment(
            f"block{bi}", blk_fn_q,
            _spatial(cfg, n, bshapes[bi][1], bshapes[bi][2]), "s8",
            _spatial(cfg, n, bshapes[bi + 1][1], bshapes[bi + 1][2]), "s8",
            "middle",
        ))

    def head_fn_q(x_q, _p=params["head"]):
        x = K.dequantize(x_q, float(scales["head.in"]))
        pooled = K.global_avgpool(x, cfg.layout)
        s_h = float(scales["head.dense.in"])
        p_q = K.quantize(pooled, s_h)
        s_w = weight_scale(_p["w"])
        w_q = jnp.asarray(quantize_weight(_p["w"], s_w))
        acc = K.dense(p_q, w_q)
        return K.dequantize(acc, s_h * s_w) + jnp.asarray(_p["b"])

    segs.append(Segment(
        "head", head_fn_q,
        _spatial(cfg, n, bshapes[-1][1], bshapes[-1][2]), "s8",
        (n, cfg.num_classes), "f32", "suffix",
    ))
    return segs


def fused_forward(cfg: ModelConfig, params: dict, scales: dict | None = None):
    """The graph-executor view: all segments composed into one function."""
    segs = build_segments(cfg, params, scales)

    def fwd(x):
        for seg in segs:
            x = seg.fn(x)
        return x

    return fwd


# ---------------------------------------------------------------------------
# Calibration taps (fp32 forward that records conv-unit inputs)
# ---------------------------------------------------------------------------

def forward_fp32_with_taps(cfg: ModelConfig, params: dict, x):
    """Run the fp32 model recording activations at every quantization point.

    Returns (logits, taps): taps map scale names to activations, mirroring
    the int8 model's quantize sites exactly.  Calibration runs against the
    reference schedule so scales are schedule-independent.
    """
    fcfg = dataclasses.replace(cfg, precision="fp32", schedule="reference")
    arch = ARCHS[fcfg.arch]
    taps: dict = {"input": x}

    y = conv_unit_fp32(x, params["stem"], arch["stem_stride"],
                       arch["stem_kernel"] // 2, fcfg)
    y = _maxpool_if_needed(y, fcfg)
    taps["stem.out"] = y

    for bi, blk in enumerate(params["blocks"]):
        name = f"block{bi}"
        taps[name + ".conv1.in"] = y
        stride = blk["stride"]
        m = conv_unit_fp32(y, blk["conv1"], stride, 1, fcfg, relu=True)
        taps[name + ".conv2.in"] = m
        m = conv_unit_fp32(m, blk["conv2"], 1, 1, fcfg, relu=False)
        if "down" in blk:
            skip = conv_unit_fp32(y, blk["down"], stride, 0, fcfg, relu=False)
        else:
            skip = y
        y = K.relu(K.add(m, skip))
        taps[name + ".out"] = y

    taps["head.in"] = y
    pooled = K.global_avgpool(y, fcfg.layout)
    taps["head.dense.in"] = pooled
    logits = K.dense(pooled, jnp.asarray(params["head"]["w"])) + jnp.asarray(
        params["head"]["b"]
    )
    return logits, taps


# ---------------------------------------------------------------------------
# Op-level units (the VM executor's instruction granularity)
# ---------------------------------------------------------------------------
# TVM's relay VM dispatches one InvokePacked instruction per primitive
# function; the paper's VM slowdown is paid at THIS granularity, not at the
# coarse prefix/middle/suffix level (those name the partition's roles).
# ``build_op_units`` decomposes the model into that instruction stream: a
# DAG of small functions over value ids (value 0 = the model input), which
# aot.py lowers one module each and the rust VM executes instruction by
# instruction with dynamic allocation.


@dataclasses.dataclass
class OpUnit:
    """One VM instruction: ``fn(*args)`` over earlier value ids."""

    name: str
    fn: Callable
    arg_ids: list          # value ids (0 = model input; i>0 = unit i-1's out)
    in_specs: list         # [(shape, dtype_tag)] per arg
    out_shape: tuple
    out_dtype: str
    role: str              # "prefix" | "middle" | "suffix"


def build_op_units(cfg: ModelConfig, params: dict, scales: dict | None = None):
    """Decompose the model into per-op units (VM instruction granularity)."""
    if cfg.precision == "int8" and scales is None:
        raise ValueError("int8 model requires calibration scales")
    arch = ARCHS[cfg.arch]
    n = -1
    bshapes = _trace_shapes(cfg)
    img = _spatial(cfg, n, cfg.in_channels, cfg.image_size)
    stem_pad = arch["stem_kernel"] // 2
    units: list[OpUnit] = []

    def emit(name, fn, arg_ids, in_specs, out_shape, out_dtype, role="middle"):
        units.append(OpUnit(name, fn, list(arg_ids), list(in_specs),
                            tuple(out_shape), out_dtype, role))
        return len(units)  # value id produced by this unit

    if cfg.precision == "fp32":
        def stem_fn(x, _p=params["stem"]):
            y = conv_unit_fp32(x, _p, arch["stem_stride"], stem_pad, cfg)
            return _maxpool_if_needed(y, cfg)

        cur_shape = _spatial(cfg, n, bshapes[0][1], bshapes[0][2])
        cur = emit("stem", stem_fn, [0], [(img, "f32")], cur_shape, "f32")

        for bi, blk in enumerate(params["blocks"]):
            name = f"block{bi}"
            in_shape = _spatial(cfg, n, bshapes[bi][1], bshapes[bi][2])
            out_shape = _spatial(cfg, n, bshapes[bi + 1][1], bshapes[bi + 1][2])
            stride = blk["stride"]

            def c1(x, _blk=blk, _s=stride):
                return conv_unit_fp32(x, _blk["conv1"], _s, 1, cfg, relu=True)

            v1 = emit(f"{name}.conv1", c1, [cur], [(in_shape, "f32")], out_shape, "f32")

            def c2(y, _blk=blk):
                return conv_unit_fp32(y, _blk["conv2"], 1, 1, cfg, relu=False)

            v2 = emit(f"{name}.conv2", c2, [v1], [(out_shape, "f32")], out_shape, "f32")

            def sk(y, x, _blk=blk, _s=stride):
                if "down" in _blk:
                    skip = conv_unit_fp32(x, _blk["down"], _s, 0, cfg, relu=False)
                else:
                    skip = x
                return K.relu(K.add(y, skip))

            cur = emit(f"{name}.skip_add", sk, [v2, cur],
                       [(out_shape, "f32"), (in_shape, "f32")], out_shape, "f32")

        def head_fn(x, _p=params["head"]):
            pooled = K.global_avgpool(x, cfg.layout)
            return K.dense(pooled, jnp.asarray(_p["w"])) + jnp.asarray(_p["b"])

        last_shape = _spatial(cfg, n, bshapes[-1][1], bshapes[-1][2])
        emit("head", head_fn, [cur], [(last_shape, "f32")],
             (n, cfg.num_classes), "f32", role="suffix")
        return units

    # ---- int8 ----
    s_img = float(scales["input"])
    cur = emit("quantize_input", lambda x: K.quantize(x, s_img), [0],
               [(img, "f32")], img, "s8", role="prefix")

    def stem_fn_q(x_q, _p=params["stem"]):
        y = conv_unit_int8(x_q, _p, s_img, arch["stem_stride"], stem_pad, cfg)
        y = _maxpool_if_needed(y, cfg)
        return K.quantize(y, float(scales["stem.out"]))

    cur_shape = _spatial(cfg, n, bshapes[0][1], bshapes[0][2])
    cur = emit("stem", stem_fn_q, [cur], [(img, "s8")], cur_shape, "s8")

    for bi, blk in enumerate(params["blocks"]):
        name = f"block{bi}"
        in_shape = _spatial(cfg, n, bshapes[bi][1], bshapes[bi][2])
        out_shape = _spatial(cfg, n, bshapes[bi + 1][1], bshapes[bi + 1][2])
        stride = blk["stride"]
        s_in = float(scales[name + ".conv1.in"])
        s_mid = float(scales[name + ".conv2.in"])
        s_out = float(scales[name + ".out"])

        def c1(x_q, _blk=blk, _s=stride, _si=s_in, _sm=s_mid):
            y = conv_unit_int8(x_q, _blk["conv1"], _si, _s, 1, cfg, relu=True)
            return K.quantize(y, _sm)

        v1 = emit(f"{name}.conv1", c1, [cur], [(in_shape, "s8")], out_shape, "s8")

        def c2(y_q, _blk=blk, _sm=s_mid):
            return conv_unit_int8(y_q, _blk["conv2"], _sm, 1, 1, cfg, relu=False)

        v2 = emit(f"{name}.conv2", c2, [v1], [(out_shape, "s8")], out_shape, "f32")

        def sk(z, x_q, _blk=blk, _s=stride, _si=s_in, _so=s_out):
            if "down" in _blk:
                skip = conv_unit_int8(x_q, _blk["down"], _si, _s, 0, cfg, relu=False)
            else:
                skip = K.dequantize(x_q, _si)
            return K.quantize(K.relu(K.add(z, skip)), _so)

        cur = emit(f"{name}.skip_add", sk, [v2, cur],
                   [(out_shape, "f32"), (in_shape, "s8")], out_shape, "s8")

    def head_fn_q(x_q, _p=params["head"]):
        x = K.dequantize(x_q, float(scales["head.in"]))
        pooled = K.global_avgpool(x, cfg.layout)
        s_h = float(scales["head.dense.in"])
        p_q = K.quantize(pooled, s_h)
        s_w = weight_scale(_p["w"])
        w_q = jnp.asarray(quantize_weight(_p["w"], s_w))
        acc = K.dense(p_q, w_q)
        return K.dequantize(acc, s_h * s_w) + jnp.asarray(_p["b"])

    last_shape = _spatial(cfg, n, bshapes[-1][1], bshapes[-1][2])
    emit("head", head_fn_q, [cur], [(last_shape, "s8")],
         (n, cfg.num_classes), "f32", role="suffix")
    return units


def op_units_forward(units, x):
    """Execute the unit DAG in python (consistency oracle for tests)."""
    values = [x]
    for u in units:
        args = [values[i] for i in u.arg_ids]
        values.append(u.fn(*args))
    return values[-1]
