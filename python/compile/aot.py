"""AOT compile driver: lower every artifact variant to HLO text + manifest.

This is the ONLY place Python runs in the system — at build time
(``make artifacts``).  The rust coordinator loads the emitted
``artifacts/manifest.json`` and ``artifacts/hlo/*.hlo.txt`` and is fully
self-contained afterwards; Python is never on the request path.

Artifact inventory (DESIGN.md §5):
- **graph bundles**: one fused HLO module per (layout, schedule, precision)
  combo — the graph-executor path (Tables 1-3 "graph" rows);
- **vm bundles**: per-segment HLO modules (prefix / middle… / suffix) — the
  VM-executor path, i.e. TVM's default-quantization bug (Table 1), plus the
  eager fp32 baseline (the PyTorch row);
- batch-size variants for the memory-bound sweep (Table 3) and the serving
  coordinator's bucket batcher.

Weights are baked in as constants (graph-executor parameter binding); scales
come from the calibration pass and are recorded in the manifest alongside
quantization-quality metrics.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

import numpy as np

from . import model as M
from . import quantize_pass as Q
from .hlo import lower_fn

# The five Table-2 rows plus the eager reference, keyed for bundle ids.
TABLE2_COMBOS = [
    ("NCHW", "spatial_pack", "fp32"),
    ("NCHW", "spatial_pack", "int8"),
    ("NCHW", "simd", "int8"),
    ("NHWC", "spatial_pack", "fp32"),
    ("NHWC", "interleaved", "int8"),
]
BEST_COMBO = ("NCHW", "spatial_pack", "int8")
FP32_COMBO = ("NCHW", "spatial_pack", "fp32")
EAGER_COMBO = ("NCHW", "reference", "fp32")


def _resolve(shape, batch):
    return [batch if d == -1 else d for d in shape]


def _weight_bytes(params) -> tuple[int, int]:
    n = M.param_count(params)
    return 4 * n, n  # fp32 bytes, int8 bytes (scales/biases ignored: tiny)


class Emitter:
    def __init__(self, out_dir: str, cfg_base: M.ModelConfig, seed: int):
        self.out_dir = out_dir
        self.hlo_dir = os.path.join(out_dir, "hlo")
        os.makedirs(self.hlo_dir, exist_ok=True)
        self.cfg_base = cfg_base
        self.params = M.init_params(cfg_base, seed)
        self.bundles: list[dict] = []
        self._scales_cache: dict = {}
        self._quant_cache: dict = {}
        self._module_cache: dict = {}  # (variant, seg, batch) -> file

    def _cfg(self, layout, schedule, precision) -> M.ModelConfig:
        return dataclasses.replace(
            self.cfg_base, layout=layout, schedule=schedule, precision=precision
        )

    def _scales(self, cfg: M.ModelConfig):
        key = cfg.layout  # calibration depends on layout only
        if key not in self._scales_cache:
            t0 = time.time()
            self._scales_cache[key] = Q.calibrate(cfg, self.params)
            print(f"  calibrated ({cfg.layout}) in {time.time()-t0:.1f}s")
        return self._scales_cache[key]

    def _quant_report(self, cfg: M.ModelConfig, scales):
        if cfg.variant_id not in self._quant_cache:
            t0 = time.time()
            rep = Q.quant_report(cfg, self.params, scales)
            self._quant_cache[cfg.variant_id] = rep.as_dict()
            print(f"  quant report {cfg.variant_id}: "
                  f"sqnr={rep.sqnr_db:.1f}dB top1-agree={rep.top1_agreement:.2f} "
                  f"({time.time()-t0:.1f}s)")
        return self._quant_cache[cfg.variant_id]

    def _emit_module(self, name: str, fn, in_specs, out_shape,
                     out_dtype, batch: int, file_stem: str,
                     arg_ids=None) -> dict:
        """Lower ``fn(*args)`` (one arg per in_spec) to one HLO module.

        ``in_specs`` is a list of ``(shape, dtype)``; ``arg_ids`` records
        which bundle value feeds each argument (0 = bundle input, i>0 =
        output of module i-1) — the VM's register wiring.
        """
        fname = f"{file_stem}.hlo.txt"
        path = os.path.join(self.hlo_dir, fname)
        if file_stem not in self._module_cache:
            t0 = time.time()
            text = lower_fn(fn, in_specs, batch)
            with open(path, "w") as f:
                f.write(text)
            self._module_cache[file_stem] = fname
            print(f"  lowered {fname} ({len(text)/1e6:.2f} MB, {time.time()-t0:.1f}s)")
        return {
            "name": name,
            "file": f"hlo/{fname}",
            "args": list(arg_ids) if arg_ids is not None else [0],
            "inputs": [
                {"shape": _resolve(shape, batch), "dtype": dtype}
                for shape, dtype in in_specs
            ],
            "output": {"shape": _resolve(out_shape, batch), "dtype": out_dtype},
        }

    def emit_graph_bundle(self, combo, batch: int, quant_metrics: bool = True):
        """One fused module = the graph-executor artifact."""
        layout, schedule, precision = combo
        cfg = self._cfg(layout, schedule, precision)
        scales = self._scales(cfg) if precision == "int8" else None
        bundle_id = f"{cfg.variant_id}_b{batch}_graph"
        if any(b["id"] == bundle_id for b in self.bundles):
            return
        print(f"bundle {bundle_id}")
        segs = M.build_segments(cfg, self.params, scales)
        fwd = M.fused_forward(cfg, self.params, scales)
        mod = self._emit_module(
            "main", fwd, [(segs[0].in_shape, segs[0].in_dtype)],
            segs[-1].out_shape, segs[-1].out_dtype, batch,
            f"{cfg.variant_id}_b{batch}_fused",
        )
        wb_f32, wb_i8 = _weight_bytes(self.params)
        self.bundles.append({
            "id": bundle_id,
            "config": dataclasses.asdict(cfg),
            "executor": "graph",
            "batch": batch,
            "modules": [mod],
            "quant": (self._quant_report(cfg, scales)
                      if precision == "int8" and quant_metrics else None),
            "weight_bytes": wb_i8 if precision == "int8" else wb_f32,
        })

    def emit_vm_bundle(self, combo, batch: int):
        """Per-OP modules = the VM-executor artifact (the paper's bug).

        One module per relay primitive, as TVM's VM dispatches them: a
        quantizing prefix, the quantized core ops, a dequantizing suffix.
        """
        layout, schedule, precision = combo
        cfg = self._cfg(layout, schedule, precision)
        scales = self._scales(cfg) if precision == "int8" else None
        bundle_id = f"{cfg.variant_id}_b{batch}_vm"
        if any(b["id"] == bundle_id for b in self.bundles):
            return
        print(f"bundle {bundle_id}")
        units = M.build_op_units(cfg, self.params, scales)
        mods = []
        for u in units:
            mod = self._emit_module(
                u.name, u.fn, u.in_specs, u.out_shape, u.out_dtype, batch,
                f"{cfg.variant_id}_b{batch}_op_{u.name.replace('.', '_')}",
                arg_ids=u.arg_ids,
            )
            mod["role"] = u.role
            mods.append(mod)
        wb_f32, wb_i8 = _weight_bytes(self.params)
        self.bundles.append({
            "id": bundle_id,
            "config": dataclasses.asdict(cfg),
            "executor": "vm",
            "batch": batch,
            "modules": mods,
            "quant": None,
            "weight_bytes": wb_i8 if precision == "int8" else wb_f32,
        })

    def write_manifest(self, extra: dict):
        manifest = {
            "version": 1,
            "generated_by": "compile.aot",
            "arch": self.cfg_base.arch,
            "image_size": self.cfg_base.image_size,
            "in_channels": self.cfg_base.in_channels,
            "num_classes": self.cfg_base.num_classes,
            "param_count": M.param_count(self.params),
            "scales": {k: float(v) for k, v in
                       self._scales_cache.get("NCHW", {}).items()},
            "bundles": self.bundles,
            **extra,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote {path} ({len(self.bundles)} bundles)")


def input_fingerprint() -> str:
    """Hash of every compile-path source file — the no-op rebuild check."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(root, fname), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--arch", default="resnet10", choices=sorted(M.ARCHS))
    p.add_argument("--image-size", type=int, default=32)
    p.add_argument("--num-classes", type=int, default=10)
    p.add_argument("--batches", default="1,4,16,64",
                   help="memory-bound sweep + serve bucket batch sizes")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--skip-quant-report", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    fp = input_fingerprint() + f"|{args.arch}|{args.image_size}|{args.batches}|{args.seed}"
    stamp_path = os.path.join(args.out_dir, ".stamp")
    if not args.force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == fp:
                print("artifacts up to date (stamp matches); skipping")
                return

    batches = sorted({int(b) for b in args.batches.split(",")})
    cfg_base = M.ModelConfig(
        arch=args.arch, image_size=args.image_size, num_classes=args.num_classes
    )
    em = Emitter(args.out_dir, cfg_base, args.seed)
    t0 = time.time()

    # --- Table 1: executor comparison at batch 1 ---
    em.emit_vm_bundle(EAGER_COMBO, 1)      # "PyTorch" eager row
    em.emit_graph_bundle(FP32_COMBO, 1)    # TVM fp32
    # TVM-Quant (the bug): the VM partition loses graph-level optimization
    # (§3.1 "the problem existed at the graph level optimization") — in
    # particular AlterOpLayout, which the packed schedule requires — so the
    # quantized VM path runs the unpacked simd schedule per-op.
    em.emit_vm_bundle(("NCHW", "simd", "int8"), 1)
    em.emit_graph_bundle(BEST_COMBO, 1)    # TVM-Quant-Graph (the fix)
    em.emit_vm_bundle(BEST_COMBO, 1)       # ablation: VM overhead, same schedule
    em.emit_vm_bundle(FP32_COMBO, 1)       # ablation: VM overhead on fp32

    # --- Table 2: schedule sweep at batch 1 (fused graph modules) ---
    for combo in TABLE2_COMBOS:
        em.emit_graph_bundle(combo, 1, quant_metrics=not args.skip_quant_report)

    # --- Table 3 + serving buckets: best setup across batch sizes ---
    for b in batches:
        em.emit_graph_bundle(FP32_COMBO, b)
        em.emit_graph_bundle(BEST_COMBO, b)

    em.write_manifest({"batches": batches})
    with open(stamp_path, "w") as f:
        f.write(fp)
    print(f"AOT done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
