"""Dense kernel, pooling ops, and the Figure-1 layout packing."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

RNG = np.random.default_rng(11)


class TestDense:
    @pytest.mark.parametrize("m,k,n", [(1, 8, 10), (37, 24, 10), (128, 64, 100), (200, 16, 3)])
    def test_f32(self, m, k, n):
        x = jnp.array(RNG.standard_normal((m, k)), jnp.float32)
        w = jnp.array(RNG.standard_normal((k, n)), jnp.float32)
        np.testing.assert_allclose(K.dense(x, w), ref.dense(x, w), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [(1, 8, 10), (37, 24, 10), (130, 64, 100)])
    def test_int8_bit_exact(self, m, k, n):
        x = jnp.array(RNG.integers(-127, 128, (m, k)), jnp.int8)
        w = jnp.array(RNG.integers(-127, 128, (k, n)), jnp.int8)
        got = K.dense(x, w)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(got, ref.dense_int8(x, w))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 300), st.integers(1, 64), st.integers(1, 40), st.integers(8, 256))
    def test_hypothesis_tiles(self, m, k, n, m_tile):
        x = jnp.array(RNG.integers(-127, 128, (m, k)), jnp.int8)
        w = jnp.array(RNG.integers(-127, 128, (k, n)), jnp.int8)
        np.testing.assert_array_equal(K.dense(x, w, m_tile=m_tile), ref.dense_int8(x, w))


class TestPooling:
    def test_maxpool_nchw(self):
        x = jnp.array(RNG.standard_normal((2, 4, 12, 12)), jnp.float32)
        np.testing.assert_allclose(
            K.maxpool2d(x, 3, 2, 1, layout="NCHW"), ref.maxpool2d_nchw(x, 3, 2, 1)
        )

    def test_maxpool_layouts_agree(self):
        x = jnp.array(RNG.standard_normal((2, 4, 12, 12)), jnp.float32)
        a = K.maxpool2d(x, 2, 2, 0, layout="NCHW")
        b = K.maxpool2d(jnp.transpose(x, (0, 2, 3, 1)), 2, 2, 0, layout="NHWC")
        np.testing.assert_allclose(jnp.transpose(b, (0, 3, 1, 2)), a)

    def test_global_avgpool(self):
        x = jnp.array(RNG.standard_normal((3, 7, 5, 5)), jnp.float32)
        np.testing.assert_allclose(
            K.global_avgpool(x, "NCHW"), ref.global_avgpool_nchw(x), rtol=1e-6
        )

    def test_bias_add_layouts(self):
        x = jnp.array(RNG.standard_normal((2, 6, 4, 4)), jnp.float32)
        b = jnp.array(RNG.standard_normal((6,)), jnp.float32)
        a = K.bias_add(x, b, "NCHW")
        c = K.bias_add(jnp.transpose(x, (0, 2, 3, 1)), b, "NHWC")
        np.testing.assert_allclose(jnp.transpose(c, (0, 3, 1, 2)), a)


class TestLayoutPacking:
    """Figure 1: NCHW <-> NCHW{c} packing."""

    @pytest.mark.parametrize("cb", [1, 2, 4, 8, 16])
    def test_roundtrip(self, cb):
        x = jnp.array(RNG.standard_normal((2, 16, 5, 7)), jnp.float32)
        xp = ref.pack_nchw_to_nchwc(x, cb)
        assert xp.shape == (2, 16 // cb, 5, 7, cb)
        np.testing.assert_array_equal(ref.unpack_nchwc_to_nchw(xp), x)

    def test_pack_layout_semantics(self):
        """Packed element (n, co, h, w, ci) == original (n, co*cb + ci, h, w)."""
        x = jnp.arange(1 * 8 * 2 * 2, dtype=jnp.float32).reshape(1, 8, 2, 2)
        xp = np.asarray(ref.pack_nchw_to_nchwc(x, 4))
        xo = np.asarray(x)
        for co in range(2):
            for ci in range(4):
                np.testing.assert_array_equal(xp[0, co, :, :, ci], xo[0, co * 4 + ci])

    def test_pack_rejects_indivisible(self):
        x = jnp.zeros((1, 6, 2, 2), jnp.float32)
        with pytest.raises(AssertionError):
            ref.pack_nchw_to_nchwc(x, 4)

    def test_weight_pack_shape(self):
        w = jnp.array(RNG.standard_normal((32, 16, 3, 3)), jnp.float32)
        wp = ref.pack_oihw_to_oihwio(w, 8, 16)
        assert wp.shape == (2, 2, 3, 3, 8, 16)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.sampled_from([4, 8, 16]), st.integers(1, 6), st.integers(1, 6), st.integers(1, 4))
    def test_hypothesis_roundtrip(self, n, cb, h, w, comult):
        c = cb * comult
        x = jnp.array(RNG.standard_normal((n, c, h, w)), jnp.float32)
        np.testing.assert_array_equal(
            ref.unpack_nchwc_to_nchw(ref.pack_nchw_to_nchwc(x, cb)), x
        )
