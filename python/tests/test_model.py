"""L2 model layer: segments, op-units, quantization pass, and lowering."""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantize_pass as Q
from compile.hlo import lower_fn


def small_cfg(**kw):
    return M.ModelConfig(arch="resnet4", image_size=16, **kw)


class TestConfig:
    def test_rejects_invalid_combo(self):
        with pytest.raises(ValueError):
            M.ModelConfig(layout="NHWC", schedule="simd", precision="int8")
        with pytest.raises(ValueError):
            M.ModelConfig(layout="NCHW", schedule="interleaved", precision="int8")
        with pytest.raises(ValueError):
            M.ModelConfig(arch="resnet999")

    def test_all_valid_combos_construct(self):
        for (lay, sched, prec) in M.VALID_COMBOS:
            cfg = M.ModelConfig(layout=lay, schedule=sched, precision=prec)
            assert cfg.variant_id

    def test_param_count_scales_with_arch(self):
        p10 = M.init_params(M.ModelConfig(arch="resnet10"))
        p4 = M.init_params(small_cfg())
        assert M.param_count(p10) > M.param_count(p4) > 0


class TestSegmentsAndUnits:
    @pytest.mark.parametrize("combo", sorted(M.VALID_COMBOS))
    def test_segments_compose_to_fused(self, combo):
        lay, sched, prec = combo
        cfg = small_cfg(layout=lay, schedule=sched, precision=prec)
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params) if prec == "int8" else None
        x = Q.calibration_batch(cfg, batch=2, seed=1)
        fused = M.fused_forward(cfg, params, scales)(x)
        z = x
        for seg in M.build_segments(cfg, params, scales):
            z = seg.fn(z)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(z))

    @pytest.mark.parametrize("prec,sched", [("fp32", "spatial_pack"), ("int8", "spatial_pack"), ("int8", "simd")])
    def test_op_units_compose_to_fused(self, prec, sched):
        cfg = small_cfg(precision=prec, schedule=sched)
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params) if prec == "int8" else None
        x = Q.calibration_batch(cfg, batch=1, seed=2)
        fused = M.fused_forward(cfg, params, scales)(x)
        units = M.build_op_units(cfg, params, scales)
        got = M.op_units_forward(units, x)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(got))

    def test_int8_units_have_prefix_middle_suffix(self):
        cfg = small_cfg(precision="int8")
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params)
        units = M.build_op_units(cfg, params, scales)
        roles = [u.role for u in units]
        assert roles[0] == "prefix" and roles[-1] == "suffix"
        assert roles.count("middle") >= 3
        # Boundary dtypes: prefix emits s8 (the quantized data space).
        assert units[0].out_dtype == "s8"
        assert units[-1].out_dtype == "f32"

    def test_unit_dag_wiring_is_topological(self):
        cfg = M.ModelConfig(precision="int8")
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params)
        units = M.build_op_units(cfg, params, scales)
        for i, u in enumerate(units):
            assert len(u.arg_ids) == len(u.in_specs)
            assert all(a <= i for a in u.arg_ids), f"{u.name} uses later value"
        # residual blocks consume two values
        assert any(len(u.arg_ids) == 2 for u in units)


class TestQuantizePass:
    def test_calibration_covers_expected_taps(self):
        cfg = M.ModelConfig(arch="resnet10")
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params)
        assert "input" in scales and "stem.out" in scales and "head.dense.in" in scales
        for bi in range(4):
            for tap in (".conv1.in", ".conv2.in", ".out"):
                assert f"block{bi}{tap}" in scales
        assert all(s > 0 for s in scales.values())

    def test_quant_report_quality(self):
        cfg = M.ModelConfig(arch="resnet10", precision="int8")
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params)
        rep = Q.quant_report(cfg, params, scales)
        assert rep.sqnr_db > 20
        assert rep.cosine > 0.99
        assert rep.top1_agreement >= 0.9

    def test_calibration_deterministic(self):
        cfg = M.ModelConfig()
        params = M.init_params(cfg)
        a = Q.calibrate(cfg, params)
        b = Q.calibrate(cfg, params)
        assert a == b

    def test_weight_quantization_exact_range(self):
        w = np.linspace(-2, 2, 101).astype(np.float32)
        s = M.weight_scale(w)
        q = M.quantize_weight(w, s)
        assert q.min() >= -127 and q.max() == 127


class TestLowering:
    def test_lower_fn_emits_hlo_text(self):
        cfg = small_cfg()
        params = M.init_params(cfg)
        segs = M.build_segments(cfg, params)
        text = lower_fn(segs[0].fn, [(segs[0].in_shape, segs[0].in_dtype)], 1)
        assert "HloModule" in text
        assert "ENTRY" in text
        # Single (non-tuple) root: the VM chains raw buffers.
        assert "f32[" in text

    def test_lower_multi_arg_unit(self):
        cfg = small_cfg(precision="int8")
        params = M.init_params(cfg)
        scales = Q.calibrate(cfg, params)
        units = M.build_op_units(cfg, params, scales)
        two_arg = next(u for u in units if len(u.arg_ids) == 2)
        text = lower_fn(two_arg.fn, two_arg.in_specs, 1)
        assert text.count("parameter(") >= 2

    def test_batch_dim_resolution(self):
        cfg = small_cfg()
        params = M.init_params(cfg)
        segs = M.build_segments(cfg, params)
        t4 = lower_fn(segs[-1].fn, [(segs[-1].in_shape, segs[-1].in_dtype)], 4)
        assert "f32[4," in t4
