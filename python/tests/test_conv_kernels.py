"""L1 conv kernels vs the pure-jnp oracle.

int8 paths must be bit-exact (int32 accumulation is associative); fp32 paths
use allclose.  Hypothesis sweeps shapes, strides, paddings and filter sizes —
including the awkward ones (C/K not multiples of the blocks, 1x1 filters,
stride > filter, inputs barely larger than the filter).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def f32(*shape):
    return jnp.array(RNG.standard_normal(shape), jnp.float32)


def i8(*shape):
    return jnp.array(RNG.integers(-127, 128, shape), jnp.int8)


def to_nhwc(x):
    return jnp.transpose(x, (0, 2, 3, 1))


def to_hwio(w):
    return jnp.transpose(w, (2, 3, 1, 0))


# Shared strategy: valid conv configs with small sizes (interpret mode).
conv_cfgs = st.tuples(
    st.integers(1, 2),               # N
    st.integers(1, 24),              # C
    st.sampled_from([1, 3, 5, 7]),   # R (=S)
    st.integers(1, 2),               # stride
    st.integers(0, 3),               # padding
    st.integers(1, 20),              # K
    st.integers(0, 6),               # H slack beyond minimum
).filter(lambda t: t[2] + 2 * t[4] >= t[2])  # always true; placeholder guard


def hw_for(r, stride, pad, slack):
    """Smallest H that yields >= 1 output, plus slack."""
    h = max(r - 2 * pad, 1) + slack
    # ensure at least one full window
    while (h + 2 * pad - r) < 0:
        h += 1
    return h


class TestSpatialPackNCHW:
    @pytest.mark.parametrize("stride,pad,r", [(1, 1, 3), (2, 1, 3), (2, 3, 7), (1, 0, 1)])
    def test_f32_matches_ref(self, stride, pad, r):
        x, w = f32(2, 16, 12, 12), f32(32, 16, r, r)
        got = K.conv2d_spatial_pack_nchw(x, w, stride, pad)
        np.testing.assert_allclose(got, ref.conv2d_nchw(x, w, stride, pad), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("stride,pad,r", [(1, 1, 3), (2, 1, 3), (2, 3, 7), (1, 0, 1)])
    def test_int8_bit_exact(self, stride, pad, r):
        x, w = i8(2, 16, 12, 12), i8(32, 16, r, r)
        got = K.conv2d_spatial_pack_nchw(x, w, stride, pad)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(got, ref.conv2d_nchw_int8(x, w, stride, pad))

    @pytest.mark.parametrize("c_block,k_block,h_tile", [(4, 4, 2), (8, 16, 4), (16, 8, 3), (32, 32, 8)])
    def test_block_sizes_dont_change_result(self, c_block, k_block, h_tile):
        x, w = i8(1, 24, 10, 10), i8(20, 24, 3, 3)
        got = K.conv2d_spatial_pack_nchw(x, w, 1, 1, c_block=c_block, k_block=k_block, h_tile=h_tile)
        np.testing.assert_array_equal(got, ref.conv2d_nchw_int8(x, w, 1, 1))

    def test_non_divisible_channels(self):
        # C=5, K=7: neither divides the default blocks -> zero-pad path.
        x, w = i8(1, 5, 9, 9), i8(7, 5, 3, 3)
        np.testing.assert_array_equal(
            K.conv2d_spatial_pack_nchw(x, w, 1, 1), ref.conv2d_nchw_int8(x, w, 1, 1)
        )

    @settings(max_examples=25, deadline=None)
    @given(conv_cfgs)
    def test_hypothesis_int8(self, cfg):
        n, c, r, stride, pad, k, slack = cfg
        h = hw_for(r, stride, pad, slack)
        if h + 2 * pad < r:
            h = r  # guarantee one window
        x, w = i8(n, c, h, h), i8(k, c, r, r)
        np.testing.assert_array_equal(
            K.conv2d_spatial_pack_nchw(x, w, stride, pad),
            ref.conv2d_nchw_int8(x, w, stride, pad),
        )

    @settings(max_examples=15, deadline=None)
    @given(conv_cfgs)
    def test_hypothesis_f32(self, cfg):
        n, c, r, stride, pad, k, slack = cfg
        h = max(hw_for(r, stride, pad, slack), r)
        x, w = f32(n, c, h, h), f32(k, c, r, r)
        np.testing.assert_allclose(
            K.conv2d_spatial_pack_nchw(x, w, stride, pad),
            ref.conv2d_nchw(x, w, stride, pad),
            rtol=1e-3, atol=1e-3,
        )


class TestSimdInt8:
    @pytest.mark.parametrize("stride,pad,r", [(1, 1, 3), (2, 1, 3), (2, 3, 7), (1, 0, 1)])
    def test_bit_exact(self, stride, pad, r):
        x, w = i8(2, 16, 12, 12), i8(32, 16, r, r)
        np.testing.assert_array_equal(
            K.conv2d_simd_int8(x, w, stride, pad), ref.conv2d_nchw_int8(x, w, stride, pad)
        )

    def test_channels_not_multiple_of_dot_width(self):
        x, w = i8(1, 6, 8, 8), i8(8, 6, 3, 3)
        np.testing.assert_array_equal(
            K.conv2d_simd_int8(x, w, 1, 1), ref.conv2d_nchw_int8(x, w, 1, 1)
        )

    @pytest.mark.parametrize("k_tile", [4, 8, 32])
    def test_k_tile_invariance(self, k_tile):
        x, w = i8(1, 8, 8, 8), i8(24, 8, 3, 3)
        np.testing.assert_array_equal(
            K.conv2d_simd_int8(x, w, 1, 1, k_tile=k_tile),
            ref.conv2d_nchw_int8(x, w, 1, 1),
        )

    @settings(max_examples=25, deadline=None)
    @given(conv_cfgs)
    def test_hypothesis(self, cfg):
        n, c, r, stride, pad, k, slack = cfg
        h = max(hw_for(r, stride, pad, slack), r)
        x, w = i8(n, c, h, h), i8(k, c, r, r)
        np.testing.assert_array_equal(
            K.conv2d_simd_int8(x, w, stride, pad),
            ref.conv2d_nchw_int8(x, w, stride, pad),
        )


class TestSpatialPackNHWC:
    @pytest.mark.parametrize("stride,pad,r", [(1, 1, 3), (2, 1, 3), (2, 3, 7), (1, 0, 1)])
    def test_matches_ref(self, stride, pad, r):
        x, w = f32(2, 12, 12, 16), f32(r, r, 16, 32)
        np.testing.assert_allclose(
            K.conv2d_spatial_pack_nhwc(x, w, stride, pad),
            ref.conv2d_nhwc(x, w, stride, pad),
            rtol=1e-4, atol=1e-4,
        )

    @pytest.mark.parametrize("h_tile", [1, 2, 4, 7])
    def test_h_tile_invariance(self, h_tile):
        x, w = f32(1, 9, 9, 8), f32(3, 3, 8, 12)
        np.testing.assert_allclose(
            K.conv2d_spatial_pack_nhwc(x, w, 1, 1, h_tile=h_tile),
            ref.conv2d_nhwc(x, w, 1, 1),
            rtol=1e-4, atol=1e-4,
        )

    @settings(max_examples=15, deadline=None)
    @given(conv_cfgs)
    def test_hypothesis(self, cfg):
        n, c, r, stride, pad, k, slack = cfg
        h = max(hw_for(r, stride, pad, slack), r)
        x, w = f32(n, h, h, c), f32(r, r, c, k)
        np.testing.assert_allclose(
            K.conv2d_spatial_pack_nhwc(x, w, stride, pad),
            ref.conv2d_nhwc(x, w, stride, pad),
            rtol=1e-3, atol=1e-3,
        )


class TestQuantizedInterleaved:
    @pytest.mark.parametrize("stride,pad,r", [(1, 1, 3), (2, 1, 3), (2, 3, 7), (1, 0, 1)])
    def test_bit_exact(self, stride, pad, r):
        x, w = i8(2, 12, 12, 16), i8(r, r, 16, 32)
        np.testing.assert_array_equal(
            K.conv2d_quantized_interleaved_nhwc(x, w, stride, pad),
            ref.conv2d_nhwc_int8(x, w, stride, pad),
        )

    @pytest.mark.parametrize("m_tile,n_tile", [(4, 4), (16, 8), (64, 64), (128, 32)])
    def test_tile_invariance(self, m_tile, n_tile):
        x, w = i8(1, 8, 8, 8), i8(3, 3, 8, 24)
        np.testing.assert_array_equal(
            K.conv2d_quantized_interleaved_nhwc(x, w, 1, 1, m_tile=m_tile, n_tile=n_tile),
            ref.conv2d_nhwc_int8(x, w, 1, 1),
        )

    def test_im2col_shape(self):
        x = i8(2, 10, 10, 6)
        a, oh, ow = K.im2col_nhwc(x, 3, 3, 2, 1)
        assert (oh, ow) == (5, 5)
        assert a.shape == (2 * 5 * 5, 3 * 3 * 6)

    @settings(max_examples=25, deadline=None)
    @given(conv_cfgs)
    def test_hypothesis(self, cfg):
        n, c, r, stride, pad, k, slack = cfg
        h = max(hw_for(r, stride, pad, slack), r)
        x, w = i8(n, h, h, c), i8(r, r, c, k)
        np.testing.assert_array_equal(
            K.conv2d_quantized_interleaved_nhwc(x, w, stride, pad),
            ref.conv2d_nhwc_int8(x, w, stride, pad),
        )


class TestCrossSchedule:
    """All int8 schedules agree with each other on the same problem."""

    def test_all_int8_schedules_identical(self):
        x, w = i8(2, 16, 14, 14), i8(24, 16, 3, 3)
        a = K.conv2d_spatial_pack_nchw(x, w, 1, 1)
        b = K.conv2d_simd_int8(x, w, 1, 1)
        c = K.conv2d_quantized_interleaved_nhwc(to_nhwc(x), to_hwio(w), 1, 1)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(np.transpose(np.asarray(c), (0, 3, 1, 2)), a)

    def test_layouts_agree_f32(self):
        x, w = f32(1, 8, 10, 10), f32(12, 8, 3, 3)
        a = K.conv2d_spatial_pack_nchw(x, w, 2, 1)
        b = K.conv2d_spatial_pack_nhwc(to_nhwc(x), to_hwio(w), 2, 1)
        np.testing.assert_allclose(np.transpose(np.asarray(b), (0, 3, 1, 2)), a, rtol=1e-4, atol=1e-4)
