import os
import sys

# Make the `compile` package importable whether pytest runs from python/ or
# the repo root.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PYROOT = os.path.dirname(_HERE)
if _PYROOT not in sys.path:
    sys.path.insert(0, _PYROOT)
