"""qnn boundary operators: quantize / dequantize / requantize."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from compile import kernels as K
from compile.kernels import ref

RNG = np.random.default_rng(7)


class TestQuantize:
    def test_matches_ref(self):
        x = jnp.array(RNG.standard_normal((4, 9, 3)) * 5, jnp.float32)
        s = float(ref.abs_max_scale(x))
        np.testing.assert_array_equal(K.quantize(x, s), ref.quantize(x, s))

    def test_saturates(self):
        x = jnp.array([1e9, -1e9, 0.0], jnp.float32)
        q = np.asarray(K.quantize(x, 0.1))
        assert q.tolist() == [127, -127, 0]

    def test_abs_max_scale_covers_range(self):
        x = jnp.array(RNG.standard_normal((128,)) * 3, jnp.float32)
        s = float(ref.abs_max_scale(x))
        q = np.asarray(ref.quantize(x, s))
        # abs-max calibration must not saturate anything except the max itself
        assert np.abs(q).max() == 127

    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(1, 4096),
        st.floats(1e-4, 1e3, allow_nan=False, allow_infinity=False),
    )
    def test_hypothesis_shapes_scales(self, n, scale):
        x = jnp.array(RNG.standard_normal((n,)) * scale * 10, jnp.float32)
        np.testing.assert_array_equal(K.quantize(x, scale), ref.quantize(x, scale))

    def test_roundtrip_error_bound(self):
        """|dequantize(quantize(x)) - x| <= scale/2 for unsaturated x."""
        x = jnp.array(RNG.uniform(-1, 1, (1000,)), jnp.float32)
        s = float(ref.abs_max_scale(x))
        err = np.abs(np.asarray(K.dequantize(K.quantize(x, s), s)) - np.asarray(x))
        assert err.max() <= s / 2 + 1e-7


class TestDequantize:
    def test_matches_ref_int8(self):
        q = jnp.array(RNG.integers(-127, 128, (33,)), jnp.int8)
        np.testing.assert_allclose(K.dequantize(q, 0.05), ref.dequantize(q, 0.05))

    def test_matches_ref_int32_accumulator(self):
        acc = jnp.array(RNG.integers(-(2**20), 2**20, (17, 5)), jnp.int32)
        np.testing.assert_allclose(
            K.dequantize(acc, 1.7e-4), ref.dequantize(acc, 1.7e-4), rtol=1e-6
        )


class TestRequantize:
    def test_matches_ref(self):
        acc = jnp.array(RNG.integers(-50000, 50000, (64,)), jnp.int32)
        np.testing.assert_array_equal(
            K.requantize(acc, 0.001, 0.07), ref.requantize(acc, 0.001, 0.07)
        )

    @settings(max_examples=30, deadline=None)
    @given(
        st.floats(1e-5, 1.0, allow_nan=False),
        st.floats(1e-3, 1.0, allow_nan=False),
    )
    def test_hypothesis_scales(self, s_in, s_out):
        acc = jnp.array(RNG.integers(-100000, 100000, (256,)), jnp.int32)
        np.testing.assert_array_equal(
            K.requantize(acc, s_in, s_out), ref.requantize(acc, s_in, s_out)
        )


class TestRequantizeFixedPoint:
    @pytest.mark.parametrize("rm", [0.9, 1.0 / 70, 1.7, 3e-5, 0.5])
    def test_bit_exact_vs_ref(self, rm):
        acc = jnp.array(RNG.integers(-(2**30), 2**30, (128,)), jnp.int32)
        m, sh = ref.choose_quant_multiplier(rm)
        with enable_x64():
            want = ref.requantize_fixed_point(acc, m, sh)
        np.testing.assert_array_equal(K.requantize_fixed_point(acc, m, sh), want)

    @pytest.mark.parametrize("rm", [0.9, 1.0 / 70, 3e-5])
    def test_agrees_with_float_path(self, rm):
        """The integer-only path may differ from float rescale by at most 1
        LSB, and only at exact .5 rounding boundaries (rare)."""
        acc = jnp.array(RNG.integers(-100000, 100000, (4096,)), jnp.int32)
        m, sh = ref.choose_quant_multiplier(rm)
        fx = np.asarray(K.requantize_fixed_point(acc, m, sh), np.int32)
        fl = np.asarray(ref.requantize(acc, rm, 1.0), np.int32)
        assert np.abs(fx - fl).max() <= 1
        assert np.mean(fx != fl) < 0.001

    def test_multiplier_decomposition(self):
        for rm in [1e-6, 0.3, 0.999, 1.0, 7.3, 1000.0]:
            m, sh = ref.choose_quant_multiplier(rm)
            assert 2**30 <= m <= 2**31
            np.testing.assert_allclose(m * 2.0 ** (sh - 31), rm, rtol=1e-8)

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            ref.choose_quant_multiplier(0.0)
