//! Minimal in-tree shim of the `anyhow` crate for the offline build.
//!
//! Implements exactly the surface this workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait.  Error sources are captured as a message chain (no
//! downcasting); `Display` renders the full chain `outer: inner: ...` so
//! diagnostics stay informative without backtrace support.

use std::fmt;

/// A string-chained error value.  Like `anyhow::Error`, this type does NOT
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message (without the source chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

/// Any std error converts implicitly (the `?` operator's conversion path).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

/// Context extension for results.  The blanket `E: Display` bound covers
/// both std errors and [`Error`] itself (which is `Display` but not
/// `std::error::Error`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: context.to_string(),
            source: Some(Box::new(Error::msg(e))),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            msg: f().to_string(),
            source: Some(Box::new(Error::msg(e))),
        })
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_displays() {
        let e = fails_io().unwrap_err();
        let shown = e.to_string();
        assert!(shown.starts_with("reading config: "), "got {shown:?}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let inline = 7;
        let e2 = anyhow!("inline {inline}");
        assert_eq!(e2.to_string(), "inline 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }
}
