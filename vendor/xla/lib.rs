//! Offline stub of the `xla` PJRT bridge crate (xla_extension 0.5.1).
//!
//! The real crate wraps the PJRT C API; this container has no
//! xla_extension build, so the PJRT entry points (`PjRtClient::cpu`,
//! compile, execute) return a descriptive error and the artifact-backed
//! executors report "unavailable" instead of failing to link.  [`Literal`]
//! is implemented for real (typed shape + bytes) so host-side conversion
//! code paths stay exercised by tests.
//!
//! Swap this path dependency for the vendored xla_extension bridge to get
//! real PJRT execution; the API surface below matches what `tvmq` uses.

use std::fmt;

/// Stub error: message-only, `Display`-compatible with the call sites'
/// `map_err(|e| anyhow!("...: {e}"))` pattern.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: offline xla stub (link the vendored xla_extension bridge for PJRT execution)"
    ))
}

/// Element dtypes the tvmq pipeline moves across the boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S8,
    S32,
}

impl ElementType {
    pub fn size_bytes(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::S8 => 1,
        }
    }
}

/// A host literal: element type + dims + raw bytes.  Fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.size_bytes();
        if data.len() != want {
            return Err(Error(format!(
                "literal data length {} != shape {:?} ({} bytes)",
                data.len(),
                dims,
                want
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Element type of a non-tuple literal (tuples never occur in the stub).
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Tuple decomposition — stub literals are never tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is not a tuple".to_string()))
    }

    /// Copy the raw bytes into a typed destination slice.
    pub fn copy_raw_to<T: Copy>(&self, dst: &mut [T]) -> Result<()> {
        let dst_bytes = std::mem::size_of::<T>() * dst.len();
        if dst_bytes != self.data.len() {
            return Err(Error(format!(
                "copy_raw_to: destination {} bytes != literal {} bytes",
                dst_bytes,
                self.data.len()
            )));
        }
        // Raw byte copy; T is Copy and the caller picked the matching type.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
        }
        Ok(())
    }
}

/// Parsed HLO module — the stub cannot parse HLO text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A PJRT device handle (opaque in the stub).
pub struct PjRtDevice;

/// A PJRT device buffer (opaque; unconstructible through the stub's
/// failing entry points, so its methods are unreachable at runtime).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// The PJRT client.  `cpu()` fails in the stub; everything downstream is
/// therefore unreachable but type-checks against the real bridge.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT cpu client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("host-to-device transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals: [f32; 4] = [1.0, -2.0, 3.5, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
                .unwrap();
        assert_eq!(lit.size_bytes(), 16);
        let mut out = [0f32; 4];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, vals);
    }

    #[test]
    fn literal_rejects_bad_length() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::S8,
            &[3],
            &[0u8; 2]
        )
        .is_err());
    }

    #[test]
    fn pjrt_entry_points_fail_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline xla stub"));
    }
}
