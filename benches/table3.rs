//! `cargo bench --bench table3` — regenerates Table 3: the fp32-vs-int8
//! batch sweep under the best layout/schedule, with the memory column from
//! the footprint model (intermediates fp32 in both precisions, §3.2.2).

use tvmq::bench::{table3, BenchCtx, BenchOpts};
use tvmq::executor::{EngineKind, EngineSpec};

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts {
        epochs: std::env::var("TVMQ_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(110),
        warmup: 10,
    };
    let ctx = BenchCtx::new(&tvmq::default_artifacts_dir(), opts)?;
    let batches = ctx.manifest.batch_buckets(EngineSpec::new(EngineKind::Graph));
    let (table, rows) = table3(&ctx, &batches)?;
    table.print();
    // Shape: int8 improvement grows (or at least does not shrink much) with
    // batch size — the memory-bandwidth story.
    let imp: Vec<(usize, f64)> = batches
        .iter()
        .map(|&b| {
            let r = rows
                .iter()
                .find(|r| r.label == format!("b{b}/int8"))
                .expect("int8 row");
            (b, r.improvement_pct)
        })
        .collect();
    println!("int8 improvement by batch: {imp:?}");
    if let (Some(first), Some(last)) = (imp.first(), imp.last()) {
        println!(
            "shape check: improvement b{}({:.1}%) -> b{}({:.1}%) {}",
            first.0, first.1, last.0, last.1,
            if last.1 >= first.1 * 0.9 { "HOLDS (grows/holds)" } else { "VIOLATED (shrinks)" }
        );
    }
    Ok(())
}
