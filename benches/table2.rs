//! `cargo bench --bench table2` — regenerates Table 2: the five
//! schedule × layout × precision rows at batch 1 plus the ideal-speedup
//! column from the analytic perfmodel.

use tvmq::bench::{table2, BenchCtx, BenchOpts};

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts {
        epochs: std::env::var("TVMQ_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(110),
        warmup: 10,
    };
    let ctx = BenchCtx::new(&tvmq::default_artifacts_dir(), opts)?;
    let (table, rows) = table2(&ctx)?;
    table.print();
    // Shape: NCHW sp int8 fastest int8; NHWC sp fp32 slowest overall.
    let ms = |l: &str, s: &str, p: &str| {
        rows.iter()
            .find(|r| r.layout == l && r.schedule == s && r.precision == p)
            .map(|r| r.mean_ms)
            .unwrap_or(f64::NAN)
    };
    let best = ms("NCHW", "spatial_pack", "int8");
    let worst = ms("NHWC", "spatial_pack", "fp32");
    let fp32 = ms("NCHW", "spatial_pack", "fp32");
    let holds = best < fp32
        && worst > fp32
        && best <= ms("NCHW", "simd", "int8")
        && best <= ms("NHWC", "interleaved", "int8");
    println!(
        "shape check: packed-int8({best:.2}) fastest, NHWC-fp32({worst:.2}) slowest => {}",
        if holds { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}
