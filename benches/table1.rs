//! `cargo bench --bench table1` — regenerates Table 1 (executor comparison,
//! batch 1): eager fp32 / graph fp32 / VM int8 (the bug) / graph int8 (the
//! fix), under the paper's 110-epoch protocol.
//!
//! Offline build: no criterion; the in-tree harness (`tvmq::metrics`)
//! provides the measurement protocol and table rendering.

use tvmq::bench::{table1, BenchCtx, BenchOpts};

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts {
        epochs: std::env::var("TVMQ_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(110),
        warmup: 10,
    };
    let ctx = BenchCtx::new(&tvmq::default_artifacts_dir(), opts)?;
    let (table, rows) = table1(&ctx)?;
    table.print();
    // Shape assertions from DESIGN.md §5: int8+VM slower than fp32+graph,
    // int8+graph faster; eager slowest.
    let ms = |label: &str| {
        rows.iter().find(|r| r.label.contains(label)).map(|r| r.mean_ms).unwrap_or(f64::NAN)
    };
    let (eager, fp32, vm, fix) =
        (ms("Eager"), ms("tvmq"), ms("tvmq-Quant"), ms("tvmq-Quant-Graph"));
    println!(
        "shape check: eager({eager:.2}) > vm-int8({vm:.2}) > fp32({fp32:.2}) > graph-int8({fix:.2})  => {}",
        if eager > fp32 && vm > fp32 && fix < fp32 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}
