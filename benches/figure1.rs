//! `cargo bench --bench figure1` — regenerates Figure 1: NCHW{c} spatial
//! packing.  Measures the locality effect directly (packed vs unpacked conv
//! of identical math in the rust interpreter) plus pack/unpack transform
//! costs across block sizes.

use std::time::Instant;

use tvmq::layout::{pack_nchwc, unpack_nchwc, Nchw};
use tvmq::metrics::Table;

fn main() -> anyhow::Result<()> {
    let reps = std::env::var("TVMQ_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let table = tvmq::bench::figure1(reps)?;
    table.print();

    // Transform micro-costs.
    let (n, c, h, w) = (1usize, 64usize, 32usize, 32usize);
    let d = Nchw { n, c, h, w };
    let x: Vec<f32> = (0..n * c * h * w).map(|i| (i % 97) as f32 * 0.01).collect();
    let mut t = Table::new(
        "Figure 1 (cont.) — pack/unpack transform cost",
        &["c_block", "pack (µs)", "unpack (µs)"],
    );
    for cb in [4usize, 8, 16] {
        let t0 = Instant::now();
        let mut xp = Vec::new();
        for _ in 0..50 {
            xp = pack_nchwc(&x, d, cb)?;
        }
        let pack_us = t0.elapsed().as_secs_f64() * 1e6 / 50.0;
        let t1 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(unpack_nchwc(&xp, d, cb)?);
        }
        let unpack_us = t1.elapsed().as_secs_f64() * 1e6 / 50.0;
        t.row(vec![cb.to_string(), format!("{pack_us:.1}"), format!("{unpack_us:.1}")]);
    }
    t.print();
    Ok(())
}
